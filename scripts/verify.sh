#!/usr/bin/env bash
# One reproducible gate for builders: docs link check + tier-1 tests +
# a CPU smoke of the full repro.api lifecycle (quantize -> save -> load
# -> generate), including the sharded serving engine on a forced
# host-device mesh.
#
#   scripts/verify.sh            # everything
#   scripts/verify.sh --fast     # skip the launcher smoke
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== docs gate: links + module references =="
python scripts/check_docs.py

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== kernel differential fuzz (full profile, >=200 generated cases) =="
  # tier-1 above already ran tests/test_kernel_diff.py at its default
  # (small) example counts; this pass rescales every property to the
  # full fuzz budget. Failures print the replay seed.
  NQ_FUZZ_EXAMPLES=30 python -m pytest tests/test_kernel_diff.py \
    -x -q -m "not slow"
fi

if [[ "${1:-}" != "--fast" ]]; then
  echo "== CPU smoke: quantize -> save =="
  OUT="${TMPDIR:-/tmp}/nq-verify-$$"
  python -m repro.launch.quantize --arch qwen1.5-0.5b \
    --teacher-steps 30 --calib-samples 4 --calib-seq 32 \
    --admm-iters 6 --t-pre 2 --t-post 2 --t-glob 2 --out "$OUT"
  echo "== CPU smoke: load artifact -> generate =="
  python -m repro.launch.serve --quantized-ckpt "$OUT" \
    --requests 2 --prompt-len 8 --max-new 4 --max-batch 2
  rm -rf "$OUT"
  echo "== CPU smoke: serving scheduler (wave vs continuous) + sharded engine + paged KV + speculative decode =="
  # also gates the paged-vs-rectangular memory-pressure race (token
  # identity, <=50% KV-pool bytes, higher admitted concurrency) and the
  # speculative-decode race (greedy token identity at every
  # (spec_rank_frac, k) point incl. the tp=2 chain; smoke writes
  # BENCH_serve_spec_smoke.json, never the full-run baseline)
  XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
    python -m benchmarks.serve_bench --smoke --tp 2
  echo "== CPU smoke: prefix cache (shared pages + COW) race =="
  # prefix-on vs prefix-off at the same overcommitted pool budget:
  # greedy token identity (incl. tp=2 chain + speculative compose row),
  # strictly higher admitted concurrency; writes
  # BENCH_serve_prefix_smoke.json, never the full-run baseline
  XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
    python -m benchmarks.serve_bench --prefix --smoke --tp 2
  echo "== CPU smoke: chaos (seeded fault injection) race =="
  # seeded FaultPlan over the full serving stack (cancel at a tick /
  # mid-prefill / mid-spec-rollback, deadline storm, dry pool, prefix
  # eviction in the gate, preemption storm, injected decode device
  # error, poison request) with per-tick page-accounting audits:
  # survivors token-identical, structured terminal statuses, replay
  # bit-for-bit, zero page leaks, drain -> snapshot -> restore
  # identity; the seed is recorded in BENCH_serve_chaos_smoke.json's
  # meta block (never overwrites the full-run baseline)
  python -m benchmarks.serve_bench --chaos --smoke
  echo "== CPU smoke: quantization chaos (kill -> resume -> bit-identical) =="
  # five deterministic QuantFaultPlan races: journaled baseline,
  # crash-at-block-start + resume (bit-identical artifact), crash in
  # the orphan-checkpoint window, NaN init -> fallback ladder (switch
  # recorded in report + journal, artifact loads/generates finite),
  # corrupted journal entry -> resume refuses naming the block; writes
  # BENCH_quant_chaos_smoke.json, never the full-run baseline
  python -m benchmarks.quant_chaos --smoke
  echo "== CPU smoke: kernel wall-clock (two-call vs fused) =="
  python -m benchmarks.kernel_bench --smoke
  echo "== regression-gate negative: injected 20% slowdown must fail =="
  # the benches above all passed their checked-in-baseline gates; prove
  # the gates actually bite by rerunning the cheapest one with a
  # simulated 20% slowdown and requiring a nonzero exit
  if NQ_BENCH_INJECT_SLOWDOWN=0.2 python -m benchmarks.kernel_bench \
      --smoke >/dev/null 2>&1; then
    echo "regression gate FAILED to catch an injected 20% slowdown" >&2
    exit 1
  fi
  echo "gate correctly rejected the injected slowdown"
fi

echo "verify: OK"
