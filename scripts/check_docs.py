#!/usr/bin/env python
"""Docs gate: every internal link and every ``src/repro/**`` /
``repro.*`` module referenced from ``docs/*.md`` (and README.md) must
exist. Runs with no third-party deps (stdlib only) so it can gate
scripts/verify.sh before anything imports jax.

Checked:

- markdown links ``[text](target)`` whose target is not an http(s) URL
  or a pure ``#anchor``: the target path (relative to the referencing
  file, ``#fragment`` stripped) must exist;
- inline-code path references `` `src/repro/...` `` (and `scripts/`,
  `benchmarks/`, `tests/`, `examples/` paths): the file or directory
  must exist;
- inline-code dotted module references `` `repro.x.y[.attr]` ``: some
  prefix of at least two segments must resolve to a module file or
  package under ``src/`` (trailing attribute names are allowed).
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_PREFIXES = ("src/repro/", "scripts/", "benchmarks/", "tests/",
                 "examples/", "docs/")
MODULE_RE = re.compile(r"^~?(repro(?:\.\w+)+)")


def module_exists(dotted: str) -> bool:
    """True if some >=2-segment prefix of `dotted` is a module/package
    under src/ (so `repro.kernels.ops.KernelPolicy` passes via the
    `repro.kernels.ops` prefix, while `repro.kernels.nonexistent`
    fails)."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        base = os.path.join(ROOT, "src", *parts[:end])
        if os.path.isfile(base + ".py") or os.path.isdir(base):
            return True
    return False


def check_file(path: str) -> list:
    errors = []
    text = open(path, encoding="utf-8").read()
    here = os.path.dirname(path)
    # strip fenced code blocks: links/backticks inside them are code,
    # not references (but keep inline code, which we do want to check)
    text_nofence = re.sub(r"```.*?```", "", text, flags=re.S)

    for m in LINK_RE.finditer(text_nofence):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(here, rel))):
            errors.append(f"{path}: broken link -> {target}")

    for m in CODE_RE.finditer(text_nofence):
        ref = m.group(1).strip()
        if ref.startswith(PATH_PREFIXES):
            rel = ref.split("#", 1)[0].rstrip("/")
            # tolerate `path` with trailing qualifiers like `x.py --flag`
            rel = rel.split(" ", 1)[0]
            if not os.path.exists(os.path.join(ROOT, rel)):
                errors.append(f"{path}: missing path reference -> {ref}")
            continue
        mm = MODULE_RE.match(ref)
        if mm and not module_exists(mm.group(1)):
            errors.append(f"{path}: unknown module reference -> {ref}")
    return errors


def main() -> int:
    docs = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if not os.path.isdir(docs_dir):
        print("check_docs: no docs/ directory", file=sys.stderr)
        return 1
    docs += sorted(os.path.join(docs_dir, f) for f in os.listdir(docs_dir)
                   if f.endswith(".md"))
    errors = []
    for path in docs:
        errors += check_file(path)
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(docs)
    if errors:
        print(f"check_docs: {len(errors)} error(s) in {n_files} file(s)",
              file=sys.stderr)
        return 1
    print(f"check_docs: OK ({n_files} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
