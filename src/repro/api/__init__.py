"""``repro.api`` — the single public surface of the NanoQuant repro.

Lifecycle::

    from repro import api

    cfg   = api.get_smoke("llama3.2-1b")
    model = api.NanoQuantModel.quantize(params, cfg, calib,
                                        api.QuantConfig(target_bpw=1.0))
    model.save("/ckpt/nq")
    model = api.NanoQuantModel.load("/ckpt/nq")
    outs  = model.generate(prompts, max_new_tokens=32)
    eng   = model.engine()                   # continuous-batching server
    eng   = model.engine(mesh=mesh)          # ... tensor-parallel (docs/serving.md)
    handle = eng.submit(api.Request(0, prompt))
    ppl   = model.perplexity()

Extension points::

    @api.register_init_method("my_init")     # paper Table 5 ablations
    def my_init(w, d_in, d_out, *, rank, admm, key): ...

    @api.register_arch("my-model-1b")        # new architectures
    def _spec(): return api.ArchSpec(...)

    with api.kernel_policy(api.KernelPolicy(mode="pallas")):
        ...                                  # explicit kernel dispatch

Everything here is re-exported from the implementing layer; downstream
code (launchers, examples, benchmarks) should import only this module.
"""
from repro.api.archs import (  # noqa: F401
    ARCHS, ArchSpec, get_arch, get_config, get_smoke, list_archs,
    register_arch, shapes_for)
from repro.api.init_methods import (  # noqa: F401
    INIT_METHODS, get_init_method, list_init_methods, register_init_method)
from repro.api.model import (  # noqa: F401
    MANIFEST_NAME, MANIFEST_VERSION, NanoQuantModel)
from repro.api.registry import Registry, UnknownNameError  # noqa: F401
from repro.checkpoint.journal import JournalError, QuantJournal  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.core.admm import QuantizationError  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    QuantConfig, nanoquant_quantize, tune_scales_kd)
from repro.kernels.ops import (  # noqa: F401
    KernelPolicy, current_kernel_policy, kernel_policy,
    lowrank_binary_matmul, lowrank_binary_matmul_expert,
    lowrank_binary_matmul_merged, set_kernel_policy)
from repro.kernels.tuning import (  # noqa: F401
    load_block_table, load_paged_table)
from repro.quant.faults import (  # noqa: F401
    InjectedPipelineCrash, QuantFault, QuantFaultPlan)
from repro.quant.preflight import PreflightError, preflight  # noqa: F401
from repro.quant.surgery import (  # noqa: F401
    abstract_quantized_params, merge_projection_groups, packed_model_bytes,
    place_cache_on_mesh, place_on_mesh, quantizable_paths)
from repro.sharding.rules import ShardingPolicy  # noqa: F401
from repro.serve.batcher import BatchServer  # noqa: F401  (deprecated shim)
from repro.serve.engine import (  # noqa: F401
    InferenceEngine, RequestError, RequestHandle, ServeConfig,
    TERMINAL_STATUSES)
from repro.serve.faults import Fault, FaultPlan  # noqa: F401
from repro.serve.paging import (  # noqa: F401
    PageAccountingError, PagedKVState)
from repro.serve import recovery  # noqa: F401
from repro.serve.scheduler import Request  # noqa: F401

__all__ = [
    # artifact
    "NanoQuantModel", "MANIFEST_NAME", "MANIFEST_VERSION",
    # pipeline
    "QuantConfig", "nanoquant_quantize", "tune_scales_kd",
    # registries
    "Registry", "UnknownNameError",
    "ARCHS", "ArchSpec", "register_arch", "get_arch", "get_config",
    "get_smoke", "list_archs", "shapes_for",
    "INIT_METHODS", "register_init_method", "get_init_method",
    "list_init_methods",
    # kernels
    "KernelPolicy", "kernel_policy", "current_kernel_policy",
    "set_kernel_policy", "lowrank_binary_matmul",
    "lowrank_binary_matmul_merged", "lowrank_binary_matmul_expert",
    "load_block_table", "load_paged_table",
    # surgery / storage / sharding
    "abstract_quantized_params", "merge_projection_groups",
    "packed_model_bytes", "quantizable_paths",
    "place_on_mesh", "place_cache_on_mesh", "ShardingPolicy",
    # serving / persistence
    "InferenceEngine", "RequestHandle", "Request", "ServeConfig",
    "PagedKVState", "BatchServer", "CheckpointManager",
    # failure handling (docs/serving.md §Failure handling)
    "RequestError", "TERMINAL_STATUSES", "PageAccountingError",
    "Fault", "FaultPlan", "recovery",
    # fault-tolerant quantization (docs/quantization.md)
    "QuantizationError", "QuantJournal", "JournalError",
    "QuantFault", "QuantFaultPlan", "InjectedPipelineCrash",
    "preflight", "PreflightError",
]
