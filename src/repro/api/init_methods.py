"""Pluggable low-rank-binary initialization methods (paper Table 5).

An init method maps one FP linear to the latent factor dict the STE
refinement phase consumes::

    @register_init_method("my_init")
    def my_init(w, d_in, d_out, *, rank, admm, key):
        # w: (d_in, d_out) weights; d_in/d_out: diagonal K-FAC
        # preconditioners; admm: repro.core.admm.ADMMConfig
        return {"lu": ..., "lv": ..., "s1": ..., "s2": ...}

``core.pipeline`` resolves ``QuantConfig.init_method`` through this
registry, so new ablations plug in without touching pipeline internals.
The built-ins migrate the former hardcoded ``if/elif`` dispatch:
``lb_admm`` (the paper's method), ``dual_svid`` (LittleBit-style) and
``dbf_admm`` (DBF-flavoured, no Hessian preconditioning).
"""
from __future__ import annotations

from typing import Callable, List

from repro.api.registry import Registry
from repro.core import baselines, quantize

INIT_METHODS = Registry("init method")
register_init_method = INIT_METHODS.register


def get_init_method(name: str) -> Callable:
    return INIT_METHODS.get(name)


def list_init_methods() -> List[str]:
    return INIT_METHODS.names()


@register_init_method("lb_admm")
def lb_admm_init(w, d_in, d_out, *, rank, admm, key):
    """Paper §3.2: preconditioned LB-ADMM + magnitude balancing."""
    lat, _ = quantize.quantize_weight(w, d_in, d_out, rank, admm, key)
    return lat


@register_init_method("dual_svid")
def dual_svid_init(w, d_in, d_out, *, rank, admm, key):
    """LittleBit-style truncated-SVD init (ignores preconditioners)."""
    return baselines.dual_svid_init(w, rank)


@register_init_method("dbf_admm")
def dbf_admm_init(w, d_in, d_out, *, rank, admm, key):
    """DBF-flavoured ADMM: plain sign/global-scale proxy, no Hessian."""
    return baselines.dbf_admm_init(w, rank, iters=admm.iters, key=key)
