"""Architecture registry: ``@register_arch`` replaces the static
module-name table that used to live in ``repro.configs.__init__``.

Each ``repro/configs/<arch>.py`` self-registers a zero-arg factory
producing an :class:`ArchSpec`; external packages can register their own
archs the same way::

    from repro.api import ArchSpec, register_arch

    @register_arch("my-model-1b")
    def _spec():
        return ArchSpec("my-model-1b", config=CONFIG, smoke=SMOKE,
                        shapes=("train_4k", "decode_32k"))

Factories are resolved (and memoized) on first lookup, so registering is
cheap and the heavy ModelConfig construction stays import-time-trivial.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple, Union

from repro.api.registry import Registry
from repro.models.config import ModelConfig

ARCHS = Registry("arch")
register_arch = ARCHS.register


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: published config, smoke-scale config,
    and the input-shape cells it runs (``repro.configs.shapes``)."""
    name: str
    config: ModelConfig
    smoke: ModelConfig
    shapes: Tuple[str, ...]


_resolved: dict = {}


def _ensure_builtins() -> None:
    # importing repro.configs registers every built-in arch module
    import repro.configs  # noqa: F401


def get_arch(name: str) -> ArchSpec:
    _ensure_builtins()
    entry: Union[ArchSpec, Callable[[], ArchSpec]] = ARCHS.get(name)
    cached = _resolved.get(name)
    if cached is None or cached[0] is not entry:   # re-registered: refresh
        spec = entry() if callable(entry) else entry
        if not isinstance(spec, ArchSpec):
            raise TypeError(f"arch {name!r} registered a "
                            f"{type(spec).__name__}, expected ArchSpec")
        _resolved[name] = (entry, spec)
    return _resolved[name][1]


def list_archs() -> List[str]:
    _ensure_builtins()
    return ARCHS.names()


def get_config(name: str) -> ModelConfig:
    """Published full-scale config for `name`."""
    return get_arch(name).config


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke runs."""
    return get_arch(name).smoke


def shapes_for(name: str) -> List[str]:
    return list(get_arch(name).shapes)
