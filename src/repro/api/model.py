"""The NanoQuant model artifact: one object for the whole lifecycle.

    model = NanoQuantModel.quantize(params, cfg, calib, qcfg)
    model.save("/ckpt/nq")                      # packed params + manifest
    model = NanoQuantModel.load("/ckpt/nq")     # self-describing
    outs  = model.generate(prompts, max_new_tokens=32)
    ppl   = model.perplexity(eval_batches)
    model.size_report()                         # storage accounting

A saved artifact is a ``CheckpointManager`` checkpoint plus a versioned
``nanoquant.json`` manifest carrying the full model/quant configs, the
per-layer factorization ranks and the pipeline report — enough to
rebuild the restore template and the serving stack without the caller
re-wiring ``core.pipeline`` + ``quant.surgery`` + ``checkpoint`` +
``serve`` by hand.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.pipeline import QuantConfig, nanoquant_quantize
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant.surgery import abstract_quantized_params, packed_model_bytes
from repro.serve.batcher import BatchServer
from repro.serve.engine import InferenceEngine, ServeConfig
from repro.serve.scheduler import Request

MANIFEST_NAME = "nanoquant.json"
# v2: quant_config carries pack_k_align (tile-aligned packed operands);
# v1 manifests load fine (missing key = 32 = the old unaligned layout).
MANIFEST_VERSION = 2


@dataclasses.dataclass
class NanoQuantModel:
    """A (possibly) NanoQuant-packed model: params + configs + report."""
    params: Any
    cfg: ModelConfig
    qcfg: Optional[QuantConfig] = None      # None => FP (unquantized)
    report: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ---- lifecycle: quantize ---------------------------------------------

    @classmethod
    def quantize(cls, params, cfg: ModelConfig, calib,
                 qcfg: Optional[QuantConfig] = None,
                 verbose: bool = True, journal_dir: Optional[str] = None,
                 resume: bool = False, faults=None,
                 heartbeat=None) -> "NanoQuantModel":
        """Run the full pipeline (paper Alg. 1) on an FP teacher.

        `journal_dir` / `resume` make the run crash-safe and resumable
        through ``checkpoint.journal.QuantJournal`` (bit-identical to an
        uninterrupted run); `faults` injects a deterministic
        ``quant.faults.QuantFaultPlan``; `heartbeat` receives short
        progress strings at block boundaries — see docs/quantization.md."""
        qcfg = qcfg or QuantConfig()
        qparams, report = nanoquant_quantize(params, cfg, calib, qcfg,
                                             verbose=verbose,
                                             journal_dir=journal_dir,
                                             resume=resume, faults=faults,
                                             heartbeat=heartbeat)
        return cls(qparams, cfg, qcfg, report)

    @classmethod
    def from_fp(cls, params, cfg: ModelConfig) -> "NanoQuantModel":
        """Wrap unquantized params (FP baseline) in the same artifact."""
        return cls(params, cfg, None, {})

    @property
    def quantized(self) -> bool:
        return self.qcfg is not None

    @property
    def ranks(self) -> Dict[str, int]:
        return dict(self.report.get("ranks", {}))

    # ---- lifecycle: save / load ------------------------------------------

    def save(self, directory: str, step: int = 0) -> str:
        """Write packed params + versioned manifest; returns `directory`."""
        os.makedirs(directory, exist_ok=True)
        CheckpointManager(directory).save(step, self.params)
        manifest = {
            "format": "nanoquant-model",
            "version": MANIFEST_VERSION,
            "arch": self.cfg.name,
            "family": self.cfg.family,
            "quantized": self.quantized,
            "target_bpw": self.qcfg.target_bpw if self.quantized else 16.0,
            "model_config": dataclasses.asdict(self.cfg),
            "quant_config": (dataclasses.asdict(self.qcfg)
                             if self.quantized else None),
            "ranks": self.report.get("ranks", {}),
            "report": _json_safe(
                {k: v for k, v in self.report.items() if k != "ranks"}),
        }
        with open(os.path.join(directory, MANIFEST_NAME), "w") as f:
            json.dump(manifest, f, indent=1)
        return directory

    @classmethod
    def load(cls, directory: str) -> "NanoQuantModel":
        """Restore from :meth:`save` output. Self-describing: the
        manifest rebuilds the configs and the restore template."""
        path = os.path.join(directory, MANIFEST_NAME)
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"{path} not found — is {directory!r} a NanoQuantModel "
                f"artifact (written by NanoQuantModel.save)?")
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("format") != "nanoquant-model":
            raise ValueError(f"{path} is not a nanoquant-model manifest")
        if manifest["version"] > MANIFEST_VERSION:
            raise ValueError(
                f"manifest version {manifest['version']} is newer than "
                f"this build supports ({MANIFEST_VERSION})")
        cfg = ModelConfig(**manifest["model_config"])
        qcfg = (QuantConfig(**manifest["quant_config"])
                if manifest.get("quantized") else None)
        template = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                                _param_template(cfg, qcfg))
        try:
            restored = CheckpointManager(directory).restore_latest(
                template=template)
        except (ValueError, FileNotFoundError, KeyError, OSError) as e:
            raise ValueError(
                f"corrupt/truncated artifact {directory!r}: {e}") from e
        if restored is None:
            raise FileNotFoundError(f"no checkpoint steps in {directory!r}")
        _, params = restored
        report = dict(manifest.get("report", {}))
        report["ranks"] = manifest.get("ranks", {})
        return cls(params, cfg, qcfg, report)

    # ---- lifecycle: serve -------------------------------------------------

    def engine(self, scfg: Optional[ServeConfig] = None, max_batch: int = 8,
               max_len: int = 512, seed: int = 0,
               admission: str = "continuous", mesh=None,
               sharding_policy=None,
               spec_rank_frac: Optional[float] = None,
               spec_k: Optional[int] = None,
               prefix_cache: Optional[bool] = None,
               faults=None, clock=None) -> InferenceEngine:
        """The serving entry point: a slot-scheduled, continuously
        batched :class:`InferenceEngine` over this model
        (`submit(req) -> handle`, per-token streaming, `step()` /
        `run()`). `admission="wave"` reproduces the legacy
        drain-then-refill schedule for comparison.

        `mesh` (e.g. ``launch.mesh.make_serving_mesh(8)``) serves
        tensor-parallel: packed weights and the KV-cache pool are placed
        per ``sharding.rules`` and the fused kernels launch through
        shard_map — greedy outputs stay token-identical to the
        unsharded engine in f32 (bf16 near-ties can flip under
        partitioned-reduction reorder; see docs/serving.md).

        `spec_rank_frac` / `spec_k` switch on self-speculative decoding
        (serve.speculative): draft through a zero-copy rank-truncated
        view of the packed params, verify in one batched full-rank
        forward — greedy outputs stay token-identical. They override
        the matching ``ServeConfig`` fields (requires greedy=True and
        the paged cache).

        `prefix_cache` overrides ``ServeConfig.prefix_cache`` (shared
        prompt-prefix KV pages with copy-on-write; on by default for
        paged linear-table families — see docs/serving.md).

        `faults` (a ``serve.faults.FaultPlan``) injects a deterministic
        fault schedule; `clock` replaces the deadline clock (both for
        chaos testing — docs/serving.md §Failure handling)."""
        scfg = scfg or ServeConfig()
        if spec_rank_frac is not None:
            scfg = dataclasses.replace(scfg, spec_rank_frac=spec_rank_frac)
        if spec_k is not None:
            scfg = dataclasses.replace(scfg, spec_k=spec_k)
        if prefix_cache is not None:
            scfg = dataclasses.replace(scfg, prefix_cache=prefix_cache)
        return InferenceEngine(self.params, self.cfg,
                               scfg, max_batch=max_batch,
                               max_len=max_len, seed=seed,
                               admission=admission, mesh=mesh,
                               sharding_policy=sharding_policy,
                               faults=faults, clock=clock)

    def server(self, scfg: Optional[ServeConfig] = None, max_batch: int = 8,
               max_len: int = 512, seed: int = 0) -> BatchServer:
        """Deprecated: a wave-admission :class:`BatchServer` shim over
        the engine. Use :meth:`engine` for continuous batching."""
        return BatchServer(self.params, self.cfg, scfg or ServeConfig(),
                           max_batch=max_batch, max_len=max_len, seed=seed)

    def generate(self, prompts: Sequence[np.ndarray],
                 max_new_tokens: Optional[int] = None,
                 scfg: Optional[ServeConfig] = None, max_batch: int = 8,
                 seed: int = 0) -> List[np.ndarray]:
        """Batched generation on the continuous engine; returns one
        output array per prompt, in order. The token budget is
        `max_new_tokens` if given, else `scfg.max_new_tokens`."""
        if not prompts:
            raise ValueError("generate() needs at least one prompt")
        if max_new_tokens is None:
            max_new_tokens = (scfg or ServeConfig()).max_new_tokens
        scfg = scfg or ServeConfig(max_new_tokens=max_new_tokens)
        max_len = max(len(p) for p in prompts) + max_new_tokens
        eng = self.engine(scfg, max_batch=max_batch, max_len=max_len,
                          seed=seed)
        for uid, prompt in enumerate(prompts):
            eng.submit(Request(uid, np.asarray(prompt, np.int32),
                               max_new_tokens=max_new_tokens))
        done = eng.run()
        return [done[uid].output for uid in range(len(prompts))]

    # ---- lifecycle: evaluate ---------------------------------------------

    def perplexity(self, batches=None, n_samples: int = 8, seq: int = 64,
                   seed: int = 99) -> float:
        """exp(mean token NLL). `batches` defaults to a deterministic
        synthetic eval set (offline WikiText-2 stand-in)."""
        from repro.data.synthetic import calib_batches, eval_perplexity
        if batches is None:
            batches = calib_batches(self.cfg, n_samples, seq, seed=seed)
        return eval_perplexity(T.loss_fn, self.params, self.cfg, batches)

    def size_report(self) -> Dict[str, float]:
        """Full-scale storage accounting for this config/bpw (exact
        formulas — see ``quant.surgery.packed_model_bytes``)."""
        q = self.qcfg or QuantConfig()
        return packed_model_bytes(self.cfg, q.target_bpw, q.min_dim,
                                  q.rank_align,
                                  getattr(q, "pack_k_align", 32))


def _param_template(cfg: ModelConfig, qcfg: Optional[QuantConfig]):
    if qcfg is None:
        from repro.configs.shapes import param_specs
        return param_specs(cfg)
    return abstract_quantized_params(cfg, qcfg.target_bpw, qcfg.min_dim,
                                     qcfg.rank_align,
                                     getattr(qcfg, "pack_k_align", 32))


def _json_safe(obj):
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, (np.floating, np.integer)):
        return obj.item()
    return obj
