"""Generic decorator-based registries for the public API.

A :class:`Registry` maps string names to objects (or zero-arg factories)
and raises :class:`UnknownNameError` — a ``KeyError`` that lists every
available name — on a miss, so callers of ``repro.api`` always get an
actionable message instead of a bare dispatch failure.

This module is intentionally dependency-free (no jax, no repro imports):
it sits below every layer that registers into it (``configs``, ``core``,
``kernels``) and above none, which is what lets config/init-method
modules self-register without import cycles.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional


class UnknownNameError(KeyError):
    """Lookup miss in a registry; message carries the available names."""

    def __init__(self, kind: str, name: str, available: List[str]):
        self.kind, self.name, self.available = kind, name, available
        super().__init__(
            f"unknown {kind} {name!r}; available: {sorted(available)}")

    def __str__(self) -> str:  # KeyError quotes its arg; keep it readable
        return self.args[0]


class Registry:
    """Name -> object mapping with decorator registration.

    ``register`` can be used three ways::

        reg.register("name", obj)          # direct

        @reg.register("name")              # decorator with explicit name
        def obj(...): ...

        @reg.register                      # decorator, name = __name__
        def obj(...): ...
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, Any] = {}

    def register(self, name_or_obj: Any = None, obj: Any = None,
                 *, overwrite: bool = False) -> Any:
        if callable(name_or_obj) and obj is None \
                and not isinstance(name_or_obj, str):
            return self._add(name_or_obj.__name__, name_or_obj, overwrite)
        name = name_or_obj
        if obj is not None:
            return self._add(name, obj, overwrite)

        def deco(o):
            return self._add(name, o, overwrite)
        return deco

    def _add(self, name: str, obj: Any, overwrite: bool) -> Any:
        if not overwrite and name in self._items \
                and self._items[name] is not obj:
            raise ValueError(
                f"{self.kind} {name!r} already registered; pass "
                f"overwrite=True to replace it")
        self._items[name] = obj
        return obj

    def get(self, name: str) -> Any:
        try:
            return self._items[name]
        except KeyError:
            raise UnknownNameError(self.kind, name, self.names()) from None

    def unregister(self, name: str) -> None:
        self._items.pop(name, None)

    def names(self) -> List[str]:
        return list(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __len__(self) -> int:
        return len(self._items)
