"""Pytree utilities: path-predicate partitioning for selective training
(STE refinement tunes only latents+scales; Phase 3 tunes only scales)."""
from __future__ import annotations

from typing import Callable, Tuple

import jax


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def partition(tree, pred: Callable[[str], bool]) -> Tuple[dict, dict]:
    """Split a pytree into (selected, rest) by leaf-path predicate; both
    outputs keep the full structure with None placeholders."""
    sel = jax.tree_util.tree_map_with_path(
        lambda p, l: l if pred(_path_str(p)) else None, tree)
    rest = jax.tree_util.tree_map_with_path(
        lambda p, l: None if pred(_path_str(p)) else l, tree)
    return sel, rest


def combine(sel, rest):
    """Inverse of partition."""
    return jax.tree.map(lambda a, b: a if a is not None else b,
                        sel, rest, is_leaf=lambda x: x is None)


def tree_stack(trees):
    """Stack a list of same-structure pytrees along a new leading axis."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def tree_index(tree, i):
    """Extract element i along the leading axis of every leaf."""
    return jax.tree.map(lambda l: l[i], tree)


def tree_set(tree, i, sub):
    """Write sub into index i along the leading axis of every leaf."""
    return jax.tree.map(lambda l, s: l.at[i].set(s.astype(l.dtype)), tree, sub)
