"""Latent magnitude balancing + scale extraction (paper §3.2 Step 2-3,
Eq. 7–9; App. A).

Removes the η / η⁻¹ scale ambiguity of the factorization by equalizing
Frobenius norms (the minimum-energy representative, Prop. 1), then reads
channel scales off the balanced projections.
"""
from __future__ import annotations

import jax.numpy as jnp


def magnitude_balance(p_u, p_v, d_out, d_in):
    """p_u: (m, r), p_v: (n, r) ADMM consensus proxies; d_out: (m,),
    d_in: (n,) diagonal preconditioners.

    Returns (latent_u (m,r), latent_v (n,r), s1 (m,), s2 (n,)) such that
    W ≈ diag(s1)·sign(latent_u)·sign(latent_v)ᵀ·diag(s2)."""
    u_hat = p_u / d_out[:, None]            # D̃_out⁻¹ P_U
    v_hat = p_v / d_in[:, None]             # D̃_in⁻¹ P_V
    nu = jnp.maximum(jnp.linalg.norm(u_hat), 1e-12)
    nv = jnp.maximum(jnp.linalg.norm(v_hat), 1e-12)
    eta = jnp.sqrt(nv / nu)                 # Eq. 7
    lat_u = eta * u_hat                     # Eq. 9
    lat_v = v_hat / eta
    s1 = jnp.mean(jnp.abs(lat_u), axis=1)   # Eq. 8 (row means)
    s2 = jnp.mean(jnp.abs(lat_v), axis=1)
    return lat_u, lat_v, s1, s2


def reconstruct(lat_u, lat_v, s1, s2):
    """Ŵ (m, n) = diag(s1) sign(U) sign(V)ᵀ diag(s2)."""
    return (s1[:, None] * jnp.sign(lat_u)) @ (jnp.sign(lat_v).T * s2[None, :])
