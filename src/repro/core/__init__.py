# The paper's primary contribution: NanoQuant sub-1-bit PTQ.
from repro.core.admm import ADMMConfig, lb_admm  # noqa: F401
from repro.core.balance import magnitude_balance, reconstruct  # noqa: F401
from repro.core.bpw import (  # noqa: F401
    model_bpw, model_size_gb, nanoquant_bpw, rank_for_bpw)
from repro.core.packing import pack_quantized, pack_signs, unpack_signs  # noqa: F401
from repro.core.pipeline import QuantConfig, nanoquant_quantize  # noqa: F401
from repro.core.quantize import quantize_leaf, quantize_weight  # noqa: F401
from repro.core.svid import svid, svid_factors  # noqa: F401
