"""Param-tree layout shared by the concrete pipeline and the abstract
surgery — the single source of truth for *which* linears NanoQuant packs.

``core.pipeline`` (walks real weights) and ``quant.surgery`` (walks
ShapeDtypeStructs) must agree exactly on the selection rule, or the
serving dry-run template diverges from what the pipeline emits.
"""
from __future__ import annotations

# param-tree keys holding transformer blocks (per family)
BLOCK_STACKS = ("layers", "dense_layers", "self_layers", "cross_layers",
                "shared_attn")

# router: FP by design (paper; <0.01% of params). w_uk/w_uv: the MLA
# absorbed-decode path contracts these into the latent cache space — they
# stay FP (DESIGN.md §5; ~1% of deepseek params).
EXCLUDE_LINEARS = frozenset({"router", "w_uk", "w_uv"})

# sign bits are packed 32-per-uint32 along d_in, so only d_in % 32 == 0
# linears are packable
PACK_ALIGN = 32


def quantizable_linear(name: str, w_shape, min_dim: int) -> bool:
    """Selection rule for one linear leaf ``{"w": w_shape}`` named
    ``name``: not excluded, 2D (or stacked-expert 3D), both matmul dims
    >= ``min_dim``, and a packable d_in."""
    return (name not in EXCLUDE_LINEARS
            and len(w_shape) >= 2
            and min(w_shape[-2:]) >= min_dim
            and w_shape[-2] % PACK_ALIGN == 0)
