"""Effective bits-per-weight and model-size accounting (paper App. F).

Implements the exact storage formulas of NanoQuant and every baseline in
Tables 13–14, so `benchmarks/table13_storage.py` reproduces the paper's
bounds and extends them to the assigned architecture pool.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple


def rank_for_bpw(n: int, m: int, bpw: float, align: int = 32,
                 r_min: int = 32) -> int:
    """Largest rank whose NanoQuant storage stays <= target bpw
    (Eq. 59 inverted: r = bpw·nm/(n+m) − 16), floored to `align` for
    packing/MXU friendliness and clamped to r_min. Packing stores U
    transposed in 32-bit words, so the effective alignment is always a
    multiple of 32."""
    align = max(32, (align // 32) * 32 or 32)
    r = bpw * n * m / (n + m) - 16.0
    r = int(r // align) * align
    return max(max(r_min, 32), r)


def nanoquant_bits(n: int, m: int, r: int) -> int:
    """M_NanoQuant = r(n+m) + 16(n+m)   (Eq. 58)."""
    return r * (n + m) + 16 * (n + m)


def nanoquant_bpw(n: int, m: int, r: int) -> float:
    return nanoquant_bits(n, m, r) / (n * m)


def dbf_bits(n: int, m: int, r: int) -> int:
    """M_DBF = r(n+m) + 16(n+r+m)   (Eq. 55) — extra rank-wise scale."""
    return r * (n + m) + 16 * (n + r + m)


def billm_bits(n: int, m: int, c: int = 50, k: int = 128) -> int:
    """Eq. 44: n(2m+c) + m + 112 n ceil(m/k)."""
    return n * (2 * m + c) + m + 112 * n * math.ceil(m / k)


def stbllm_bits(n: int, m: int, N: int, M: int, c: int = 50, k: int = 128) -> int:
    """Eq. 46 with N:M structured sparsity."""
    idx_bits = math.ceil(math.log2(math.comb(M, N)))
    total = (2 * n * c + math.ceil(m / k) * 3 * n * 16
             + (N / M) * (n * (m - c) + 2 * n * m)
             + (n * (m - c) / M) * idx_bits
             + math.ceil(m / k) * 2 * n * 16 * 3
             + m)
    return int(total)


def arbllm_rc_bits(n: int, m: int, c: int = 50, k: int = 128) -> int:
    """Eq. 48: n(2m+c) + 33m + 64 n ceil(m/k)."""
    return n * (2 * m + c) + 33 * m + 64 * n * math.ceil(m / k)


def hbllm_row_bits(n: int, m: int, c: int = 50, k: int = 128) -> int:
    """Eq. 50: 2n(m+c) + m + 160 n ceil(m/k)."""
    return 2 * n * (m + c) + m + 160 * n * math.ceil(m / k)


def hbllm_col_bits(n: int, m: int, c: int = 50, k: int = 128) -> int:
    """Eq. 52: 2nm + m + 112 n ceil(m/k)."""
    return 2 * n * m + m + 112 * n * math.ceil(m / k)


METHODS = {
    "nanoquant": lambda n, m, r=None, bpw=1.0: nanoquant_bits(
        n, m, r if r is not None else rank_for_bpw(n, m, bpw)),
    "dbf": lambda n, m, r=None, bpw=1.0: dbf_bits(
        n, m, r if r is not None else rank_for_bpw(n, m, bpw)),
    "billm": lambda n, m, **_: billm_bits(n, m),
    "stbllm_4:8": lambda n, m, **_: stbllm_bits(n, m, 4, 8),
    "stbllm_6:8": lambda n, m, **_: stbllm_bits(n, m, 6, 8),
    "stbllm_8:8": lambda n, m, **_: stbllm_bits(n, m, 8, 8),
    "arbllm_rc": lambda n, m, **_: arbllm_rc_bits(n, m),
    "hbllm_row": lambda n, m, **_: hbllm_row_bits(n, m),
    "hbllm_col": lambda n, m, **_: hbllm_col_bits(n, m),
}


def model_bpw(layer_shapes: List[Tuple[int, int]], method: str,
              **kw) -> float:
    """Eq. 60: BPW over all quantized linear layers of a model.

    layer_shapes: list of (n=d_out, m=d_in) for every quantized linear."""
    fn = METHODS[method]
    total_bits = sum(fn(n, m, **kw) for n, m in layer_shapes)
    total_w = sum(n * m for n, m in layer_shapes)
    return total_bits / total_w


def model_size_gb(layer_shapes: List[Tuple[int, int]], method: str,
                  fp_params: int = 0, fp_bits: int = 16, **kw) -> float:
    """Checkpoint size in GB: quantized linears + FP16 residue (embeddings,
    norms, head — matching the paper's accounting)."""
    fn = METHODS[method]
    bits = sum(fn(n, m, **kw) for n, m in layer_shapes) + fp_params * fp_bits
    return bits / 8 / 1e9


def bpw_report(layer_shapes, fp_params: int = 0,
               target_bpw: float = 1.0) -> Dict[str, float]:
    out = {}
    for name in METHODS:
        kw = {"bpw": target_bpw} if name in ("nanoquant", "dbf") else {}
        out[name] = model_bpw(layer_shapes, name, **kw)
    return out
