"""LB-ADMM: latent binary factorization by ADMM (paper §3.2 Step 2-2,
App. B).

Minimizes ``½‖W̃ − U Vᵀ‖² + λ/2(‖U‖²+‖V‖²)  s.t.  U = Z_U, V = Z_V`` where
the proxies Z are SVID sign–value structures. The continuous U/V updates
are SPD ridge systems solved with a stabilized Cholesky factorization
(O(r³/3)); the proxy update is SVID; duals are scaled. A linear penalty
schedule ramps ρ over the solve (paper App. C / Fig. 9b).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.svid import svid


class QuantizationError(RuntimeError):
    """Structured per-block quantization failure.

    Raised by the pipeline's health guards instead of letting NaN/inf
    latents propagate into ``quant.surgery`` packing (where they would
    silently poison the artifact). Carries enough context for the
    fallback ladder / journal to record the decision and for a human to
    find the bad block in a multi-hour run.
    """

    def __init__(self, layer: Optional[str], block: Optional[str],
                 iteration: Optional[int], reason: str):
        self.layer = layer
        self.block = block
        self.iteration = iteration
        self.reason = reason
        where = f"block={block!r}"
        if layer is not None:
            where += f" layer={layer!r}"
        if iteration is not None:
            where += f" iteration={iteration}"
        super().__init__(f"quantization failed at {where}: {reason}")


class ADMMConfig(NamedTuple):
    rank: int
    iters: int = 40
    rho_init: float = 1e-2
    rho_final: float = 1.0
    lam: float = 1e-4
    svid_iters: int = 8
    # health guards (divergence detection + bounded rho adaptation):
    # a step whose updated factors go non-finite, or whose *relative*
    # residual exceeds divergence_factor (i.e. the factorization is
    # divergence_factor x worse than predicting zero — the residual is
    # non-monotone over the rho ramp, so best-seen is not a valid
    # reference) for divergence_patience consecutive iterations, is
    # rejected — factors keep their last good value, the scaled duals
    # restart at zero, and the penalty gets a bounded bump (x
    # rho_growth, total scale capped at rho_scale_max).
    rho_growth: float = 2.0
    rho_scale_max: float = 16.0
    divergence_factor: float = 10.0
    divergence_patience: int = 5


def _rand_range_init(key, w, r):
    """Randomized rank-r range finder init (scales to 8k×50k matrices where
    full SVD would not). When r exceeds min(m, n) — packing alignment can
    force r=32 on very small layers — the overcomplete tail is filled
    with scaled gaussian columns (QR caps orthonormal columns at m)."""
    m, n = w.shape
    k1, k2, k3 = jax.random.split(key, 3)
    omega = jax.random.normal(k1, (n, r), jnp.float32)
    y = w @ omega                                   # (m, r)
    q, _ = jnp.linalg.qr(y)                         # (m, min(m, r))
    b = w.T @ q                                     # (n, min(m, r))
    if q.shape[1] < r:
        extra = r - q.shape[1]
        q = jnp.concatenate(
            [q, jax.random.normal(k2, (m, extra)) * (jnp.std(q) + 1e-6)], 1)
        b = jnp.concatenate(
            [b, jax.random.normal(k3, (n, extra)) * (jnp.std(b) + 1e-6)], 1)
    # balance magnitudes between factors
    nb = jnp.maximum(jnp.linalg.norm(b), 1e-12)
    nq = jnp.maximum(jnp.linalg.norm(q), 1e-12)
    s = jnp.sqrt(nb / nq)
    return q * s, b / s


def _chol_solve_ridge(gram, rhs, shift):
    """Solve (gram + shift·I) X = rhs with stabilized Cholesky."""
    r = gram.shape[0]
    h = gram + (shift + 1e-8) * jnp.eye(r, dtype=gram.dtype)
    c = jnp.linalg.cholesky(h)
    y = jax.scipy.linalg.solve_triangular(c, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(c.T, y, lower=False)


def lb_admm(w_target: jnp.ndarray, cfg: ADMMConfig, key=None):
    """Run LB-ADMM on the preconditioned target (m, n).

    Returns dict with consensus variables P_U=(U+Λ_U), P_V=(V+Λ_V) (the
    pre-binary proxies consumed by magnitude balancing), plus the raw
    factors and per-iteration residual trace.
    """
    w = w_target.astype(jnp.float32)
    m, n = w.shape
    r = cfg.rank
    key = key if key is not None else jax.random.PRNGKey(0)
    u, v = _rand_range_init(key, w, r)

    zu, zv = svid(u, cfg.svid_iters), svid(v, cfg.svid_iters)
    lu = jnp.zeros_like(u)
    lv = jnp.zeros_like(v)
    rhos = jnp.linspace(cfg.rho_init, cfg.rho_final, cfg.iters)

    def step(carry, rho):
        u, v, zu, zv, lu, lv, rho_scale, best, bad, resets = carry
        # bounded rho adaptation: rejected steps (below) bump rho_scale
        rho_t = jnp.minimum(rho * rho_scale,
                            cfg.rho_final * cfg.rho_scale_max)
        # U update: (VᵀV + (ρ+λ)I) Uᵀ = Vᵀ W̃ᵀ + ρ (Z_U − Λ_U)ᵀ   (Eq. 5)
        # ρ is *scale-free*: the effective penalty is ρ x mean eigenvalue
        # of the data Gram, so the proxy pull is a fixed fraction of the
        # data term regardless of ‖W̃‖ (otherwise consensus never engages
        # for large-magnitude layers and the duals diverge).
        gram_v = v.T @ v
        rho_u = rho_t * jnp.trace(gram_v) / gram_v.shape[0]
        rhs_u = v.T @ w.T + rho_u * (zu - lu).T
        u2 = _chol_solve_ridge(gram_v, rhs_u, rho_u + cfg.lam).T
        # V update (symmetric)
        gram_u = u2.T @ u2
        rho_v = rho_t * jnp.trace(gram_u) / gram_u.shape[0]
        rhs_v = u2.T @ w + rho_v * (zv - lv).T
        v2 = _chol_solve_ridge(gram_u, rhs_v, rho_v + cfg.lam).T
        # proxy updates (Eq. 6)
        zu2 = svid(u2 + lu, cfg.svid_iters)
        zv2 = svid(v2 + lv, cfg.svid_iters)
        # scaled dual updates
        lu2 = lu + u2 - zu2
        lv2 = lv + v2 - zv2
        res = (jnp.linalg.norm(w - u2 @ v2.T)
               / jnp.maximum(jnp.linalg.norm(w), 1e-12))
        # ---- health guards ------------------------------------------------
        finite = (jnp.isfinite(u2).all() & jnp.isfinite(v2).all()
                  & jnp.isfinite(zu2).all() & jnp.isfinite(zv2).all()
                  & jnp.isfinite(res))
        bad = jnp.where(finite & (res > cfg.divergence_factor),
                        bad + 1, 0)
        reject = (~finite) | (bad >= cfg.divergence_patience)
        # rejected step: keep last good factors, restart the scaled
        # duals at zero, bump the penalty (bounded)
        u, v = jnp.where(reject, u, u2), jnp.where(reject, v, v2)
        zu, zv = jnp.where(reject, zu, zu2), jnp.where(reject, zv, zv2)
        lu = jnp.where(reject, jnp.zeros_like(lu), lu2)
        lv = jnp.where(reject, jnp.zeros_like(lv), lv2)
        rho_scale = jnp.where(
            reject, jnp.minimum(rho_scale * cfg.rho_growth,
                                cfg.rho_scale_max), rho_scale)
        resets = resets + reject.astype(jnp.int32)
        bad = jnp.where(reject, 0, bad)
        best = jnp.where(finite & ~reject, jnp.minimum(best, res), best)
        res = jnp.where(finite, res, jnp.float32(jnp.inf))
        carry = (u, v, zu, zv, lu, lv, rho_scale, best, bad, resets)
        return carry, res

    init = (u, v, zu, zv, lu, lv, jnp.float32(1.0), jnp.float32(jnp.inf),
            jnp.int32(0), jnp.int32(0))
    (u, v, zu, zv, lu, lv, rho_scale, best, _, resets), trace = \
        jax.lax.scan(step, init, rhos)
    nonfinite = ~(jnp.isfinite(u).all() & jnp.isfinite(v).all()
                  & jnp.isfinite(zu).all() & jnp.isfinite(zv).all())
    final_res = trace[-1]
    return {
        "p_u": u + lu,          # consensus proxies (paper: P_U^{(K)})
        "p_v": v + lv,
        "u": u, "v": v, "z_u": zu, "z_v": zv,
        "residual_trace": trace,
        # solve health for the pipeline's divergence guards: resets
        # counts rejected steps (non-finite factors / residual trend),
        # rho_scale is the final bounded penalty bump, diverged flags a
        # solve whose final residual never came back near its best
        "health": {
            "resets": resets,
            "rho_scale": rho_scale,
            "min_residual": best,
            "final_residual": final_res,
            "nonfinite": nonfinite,
            "diverged": (nonfinite | ~jnp.isfinite(final_res)
                         | (final_res > cfg.divergence_factor)),
        },
    }
