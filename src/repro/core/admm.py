"""LB-ADMM: latent binary factorization by ADMM (paper §3.2 Step 2-2,
App. B).

Minimizes ``½‖W̃ − U Vᵀ‖² + λ/2(‖U‖²+‖V‖²)  s.t.  U = Z_U, V = Z_V`` where
the proxies Z are SVID sign–value structures. The continuous U/V updates
are SPD ridge systems solved with a stabilized Cholesky factorization
(O(r³/3)); the proxy update is SVID; duals are scaled. A linear penalty
schedule ramps ρ over the solve (paper App. C / Fig. 9b).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.svid import svid


class ADMMConfig(NamedTuple):
    rank: int
    iters: int = 40
    rho_init: float = 1e-2
    rho_final: float = 1.0
    lam: float = 1e-4
    svid_iters: int = 8


def _rand_range_init(key, w, r):
    """Randomized rank-r range finder init (scales to 8k×50k matrices where
    full SVD would not). When r exceeds min(m, n) — packing alignment can
    force r=32 on very small layers — the overcomplete tail is filled
    with scaled gaussian columns (QR caps orthonormal columns at m)."""
    m, n = w.shape
    k1, k2, k3 = jax.random.split(key, 3)
    omega = jax.random.normal(k1, (n, r), jnp.float32)
    y = w @ omega                                   # (m, r)
    q, _ = jnp.linalg.qr(y)                         # (m, min(m, r))
    b = w.T @ q                                     # (n, min(m, r))
    if q.shape[1] < r:
        extra = r - q.shape[1]
        q = jnp.concatenate(
            [q, jax.random.normal(k2, (m, extra)) * (jnp.std(q) + 1e-6)], 1)
        b = jnp.concatenate(
            [b, jax.random.normal(k3, (n, extra)) * (jnp.std(b) + 1e-6)], 1)
    # balance magnitudes between factors
    nb = jnp.maximum(jnp.linalg.norm(b), 1e-12)
    nq = jnp.maximum(jnp.linalg.norm(q), 1e-12)
    s = jnp.sqrt(nb / nq)
    return q * s, b / s


def _chol_solve_ridge(gram, rhs, shift):
    """Solve (gram + shift·I) X = rhs with stabilized Cholesky."""
    r = gram.shape[0]
    h = gram + (shift + 1e-8) * jnp.eye(r, dtype=gram.dtype)
    c = jnp.linalg.cholesky(h)
    y = jax.scipy.linalg.solve_triangular(c, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(c.T, y, lower=False)


def lb_admm(w_target: jnp.ndarray, cfg: ADMMConfig, key=None):
    """Run LB-ADMM on the preconditioned target (m, n).

    Returns dict with consensus variables P_U=(U+Λ_U), P_V=(V+Λ_V) (the
    pre-binary proxies consumed by magnitude balancing), plus the raw
    factors and per-iteration residual trace.
    """
    w = w_target.astype(jnp.float32)
    m, n = w.shape
    r = cfg.rank
    key = key if key is not None else jax.random.PRNGKey(0)
    u, v = _rand_range_init(key, w, r)

    zu, zv = svid(u, cfg.svid_iters), svid(v, cfg.svid_iters)
    lu = jnp.zeros_like(u)
    lv = jnp.zeros_like(v)
    rhos = jnp.linspace(cfg.rho_init, cfg.rho_final, cfg.iters)

    def step(carry, rho):
        u, v, zu, zv, lu, lv = carry
        # U update: (VᵀV + (ρ+λ)I) Uᵀ = Vᵀ W̃ᵀ + ρ (Z_U − Λ_U)ᵀ   (Eq. 5)
        # ρ is *scale-free*: the effective penalty is ρ x mean eigenvalue
        # of the data Gram, so the proxy pull is a fixed fraction of the
        # data term regardless of ‖W̃‖ (otherwise consensus never engages
        # for large-magnitude layers and the duals diverge).
        gram_v = v.T @ v
        rho_u = rho * jnp.trace(gram_v) / gram_v.shape[0]
        rhs_u = v.T @ w.T + rho_u * (zu - lu).T
        u = _chol_solve_ridge(gram_v, rhs_u, rho_u + cfg.lam).T
        # V update (symmetric)
        gram_u = u.T @ u
        rho_v = rho * jnp.trace(gram_u) / gram_u.shape[0]
        rhs_v = u.T @ w + rho_v * (zv - lv).T
        v = _chol_solve_ridge(gram_u, rhs_v, rho_v + cfg.lam).T
        # proxy updates (Eq. 6)
        zu = svid(u + lu, cfg.svid_iters)
        zv = svid(v + lv, cfg.svid_iters)
        # scaled dual updates
        lu = lu + u - zu
        lv = lv + v - zv
        res = jnp.linalg.norm(w - u @ v.T) / jnp.maximum(jnp.linalg.norm(w), 1e-12)
        return (u, v, zu, zv, lu, lv), res

    (u, v, zu, zv, lu, lv), trace = jax.lax.scan(
        step, (u, v, zu, zv, lu, lv), rhos)
    return {
        "p_u": u + lu,          # consensus proxies (paper: P_U^{(K)})
        "p_v": v + lv,
        "u": u, "v": v, "z_u": zu, "z_v": zv,
        "residual_trace": trace,
    }
