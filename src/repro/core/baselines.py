"""Baseline binarizers and alternative low-rank-binary initializers.

- RTN / XNOR in-place binarization (paper Table 2 rows 1-2)
- Dual-SVID init (LittleBit, Lee et al. 2025a) — SVD factors, scales from
  row-mean magnitudes of each factor (Table 5)
- DBF-ADMM init (Boža & Macko 2026) — ADMM with a plain sign/global-scale
  proxy instead of the SVID rank-1 value structure, no Hessian
  preconditioning (Table 5)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.svid import svid


def rtn_binarize(w):
    """W ≈ α·sign(W), α = per-tensor mean |W| (w in (din,dout) layout)."""
    alpha = jnp.mean(jnp.abs(w.astype(jnp.float32)))
    return alpha * jnp.sign(w.astype(jnp.float32))


def xnor_binarize(w):
    """W ≈ diag(α)·sign(W) with per-output-channel α (XNOR-Net style).
    w: (din, dout) -> α over dout columns."""
    wf = w.astype(jnp.float32)
    alpha = jnp.mean(jnp.abs(wf), axis=0, keepdims=True)
    return alpha * jnp.sign(wf)


def dual_svid_init(w, rank: int):
    """LittleBit-style init: truncated SVD W ≈ A Bᵀ (A=UΣ^½, B=VΣ^½), then
    read scales/latents directly off the factors. w: (din, dout).
    Returns latent dict matching quantize_weight's output convention."""
    W = w.astype(jnp.float32).T                        # (dout, din)
    u, s, vt = jnp.linalg.svd(W, full_matrices=False)
    r = min(rank, s.shape[0])
    a = u[:, :r] * jnp.sqrt(s[:r])[None, :]            # (dout, r)
    b = vt[:r].T * jnp.sqrt(s[:r])[None, :]            # (din, r)
    s1 = jnp.mean(jnp.abs(a), axis=1)
    s2 = jnp.mean(jnp.abs(b), axis=1)
    return {"lu": a, "lv": b, "s1": s1, "s2": s2}


def dbf_admm_init(w, rank: int, iters: int = 40, rho: float = 1.0, key=None):
    """DBF-flavoured ADMM: same splitting as LB-ADMM but the proxy is a
    plain global-scale sign projection (Z = mean|P|·sign(P)) and the target
    is unpreconditioned. w: (din, dout)."""
    from repro.core.admm import _rand_range_init, _chol_solve_ridge

    W = w.astype(jnp.float32).T
    key = key if key is not None else jax.random.PRNGKey(0)
    u, v = _rand_range_init(key, W, rank)
    zu = jnp.mean(jnp.abs(u)) * jnp.sign(u)
    zv = jnp.mean(jnp.abs(v)) * jnp.sign(v)
    lu = jnp.zeros_like(u)
    lv = jnp.zeros_like(v)

    def step(carry, _):
        u, v, zu, zv, lu, lv = carry
        u = _chol_solve_ridge(v.T @ v, v.T @ W.T + rho * (zu - lu).T, rho).T
        v = _chol_solve_ridge(u.T @ u, u.T @ W + rho * (zv - lv).T, rho).T
        pu, pv = u + lu, v + lv
        zu = jnp.mean(jnp.abs(pu)) * jnp.sign(pu)
        zv = jnp.mean(jnp.abs(pv)) * jnp.sign(pv)
        lu = pu - zu
        lv = pv - zv
        return (u, v, zu, zv, lu, lv), None

    (u, v, zu, zv, lu, lv), _ = jax.lax.scan(
        step, (u, v, zu, zv, lu, lv), None, length=iters)
    pu, pv = u + lu, v + lv
    s1 = jnp.mean(jnp.abs(pu), axis=1)
    s2 = jnp.mean(jnp.abs(pv), axis=1)
    return {"lu": pu, "lv": pv, "s1": s1, "s2": s2}


def svid_rank1(w):
    """Rank-1 SVID of a full matrix (building block of BiLLM-family
    residual binarization; also used in tests as the optimality oracle)."""
    return svid(w.astype(jnp.float32))
