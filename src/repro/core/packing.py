"""Bit packing of ±1 factors (paper Fig. 2c): -1 -> 0, +1 -> 1, 32 values
per uint32 word. Re-exports the kernel-layer implementation so the packing
convention is defined in exactly one place.
"""
from repro.kernels.ref import pack_signs, unpack_signs  # noqa: F401

import jax.numpy as jnp


def pack_quantized(lat_u, lat_v, s1, s2, dtype=jnp.float32,
                   k_align: int = 32):
    """Finalize a quantized linear: latents -> packed param dict consumed by
    ``repro.models.layers.dense`` (weights layout (d_in, d_out), so
    U (d_out, r) is stored transposed as packed Uᵀ).

    k_align: pad the packed d_in (reduction) dim up to this multiple *at
    pack time*, so serving kernels never re-pad the stored operands per
    call (the padded s2 columns are 0, so the padding contributes
    exactly nothing). 32 (the packing word) is a no-op for any packable
    linear; set e.g. 512 to guarantee full K tiles on TPU. The output
    (d_out) and rank dims are never padded here — rank alignment comes
    from ``QuantConfig.rank_align`` at quantize time.
    """
    u = jnp.sign(jnp.where(lat_u == 0, 1.0, lat_u))     # (d_out, r)
    v = jnp.sign(jnp.where(lat_v == 0, 1.0, lat_v))     # (d_in, r)
    k_align = max(32, k_align)
    d_in = v.shape[0]
    kp = -(-d_in // k_align) * k_align
    if kp != d_in:
        # padded rows pack to 0-bits (unpack to -1); harmless because
        # the matching s2 entries are zero.
        v = jnp.pad(v, ((0, kp - d_in), (0, 0)))
        s2 = jnp.pad(s2.astype(dtype), (0, kp - d_in))
    return {
        "qu_t": pack_signs(u.T),                        # (r//32, d_out)
        "qv": pack_signs(v),                            # (kp//32, r)
        "s1": s1.astype(dtype),
        "s2": s2.astype(dtype),
    }


def packed_nbytes(q) -> int:
    """Actual storage bytes of a packed quantized linear (scales in fp16)."""
    return int(q["qu_t"].size * 4 + q["qv"].size * 4
               + (q["s1"].size + q["s2"].size) * 2)
