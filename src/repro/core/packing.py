"""Bit packing of ±1 factors (paper Fig. 2c): -1 -> 0, +1 -> 1, 32 values
per uint32 word. Re-exports the kernel-layer implementation so the packing
convention is defined in exactly one place.
"""
from repro.kernels.ref import pack_signs, unpack_signs  # noqa: F401

import jax.numpy as jnp


def pack_quantized(lat_u, lat_v, s1, s2, dtype=jnp.float32):
    """Finalize a quantized linear: latents -> packed param dict consumed by
    ``repro.models.layers.dense`` (weights layout (d_in, d_out), so
    U (d_out, r) is stored transposed as packed Uᵀ)."""
    u = jnp.sign(jnp.where(lat_u == 0, 1.0, lat_u))     # (d_out, r)
    v = jnp.sign(jnp.where(lat_v == 0, 1.0, lat_v))     # (d_in, r)
    return {
        "qu_t": pack_signs(u.T),                        # (r//32, d_out)
        "qv": pack_signs(v),                            # (d_in//32, r)
        "s1": s1.astype(dtype),
        "s2": s2.astype(dtype),
    }


def packed_nbytes(q) -> int:
    """Actual storage bytes of a packed quantized linear (scales in fp16)."""
    return int(q["qu_t"].size * 4 + q["qv"].size * 4
               + (q["s1"].size + q["s2"].size) * 2)
