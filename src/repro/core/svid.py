"""Sign-Value Independent Decomposition (paper Eq. 6; Pouransari'20, Xu'24).

SVID(P) = sign(P) ⊙ (a bᵀ) where a bᵀ is the best rank-1 approximation of
|P|. Since |P| is elementwise non-negative, its leading singular vectors
are non-negative (Perron–Frobenius), so a few power iterations converge
fast and the result is the optimal sign-structure-preserving rank-1 proxy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def svid(p: jnp.ndarray, n_iter: int = 12) -> jnp.ndarray:
    """Best rank-1 sign-value proxy of p (m, n)."""
    a, b = svid_factors(p, n_iter)
    return jnp.sign(jnp.where(p == 0, 1.0, p)) * jnp.outer(a, b)


def svid_factors(p: jnp.ndarray, n_iter: int = 12):
    """Return (a, b) with |p| ≈ a bᵀ via power iteration on |p|.

    The iteration is seeded with the column sums of |p| (= one free
    half-step of power iteration, and — being data-derived — it keeps
    the scan carry's varying-axes type consistent under shard_map)."""
    ab = jnp.abs(p).astype(jnp.float32)
    m, n = ab.shape
    b0 = ab.sum(axis=0) + 1e-12
    b = b0 / jnp.maximum(jnp.linalg.norm(b0), 1e-12)

    def body(b, _):
        a = ab @ b
        a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
        b = ab.T @ a
        return b / jnp.maximum(jnp.linalg.norm(b), 1e-12), None

    b, _ = jax.lax.scan(body, b, None, length=n_iter)
    a = ab @ b
    sigma = jnp.linalg.norm(a)
    a = a / jnp.maximum(sigma, 1e-12)
    # split sigma evenly so both factors carry comparable magnitude
    s = jnp.sqrt(jnp.maximum(sigma, 1e-12))
    return a * s, b * s
