"""NanoQuant end-to-end pipeline (paper Alg. 1).

Phase 1  global calibration      -> diagonal K-FAC stats via model taps
Phase 2  block reconstruction    -> per block: TuneFP (error-propagation
         mitigation) -> LB-ADMM init + magnitude balancing -> STE latent
         refinement -> bit packing
Phase 3  model reconstruction    -> KL-distillation of the packed model,
         tuning only the floating-point scales {s1, s2}

Two activation streams are maintained (paper §3.2 Step 1): X_q flows
through the already-compressed prefix, X_fp through the FP teacher; the
per-block target is always Y = B_fp(X_fp), so TuneFP genuinely absorbs
accumulated quantization error instead of fitting a zero residual.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, precond, quantize, util
from repro.core.admm import ADMMConfig, QuantizationError
from repro.core.layout import EXCLUDE_LINEARS, quantizable_linear
from repro.models import layers as L
from repro.models import transformer as T
from repro.train.optim import AdamW, cosine_schedule


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    target_bpw: float = 1.0
    rank_align: int = 32
    # pack-time tile alignment of the packed d_in dim (stored operands
    # are padded ONCE here instead of per kernel call; 32 = packing
    # word, i.e. no extra padding — see core.packing.pack_quantized)
    pack_k_align: int = 32
    admm_iters: int = 40
    rho_init: float = 0.01
    rho_final: float = 1.0
    lam: float = 1e-4
    gamma: float = 0.2            # shrinkage (paper: 0.2 llama/qwen, 0.6 gemma)
    t_pre: int = 40               # TuneFP steps per block
    t_post: int = 60              # STE refinement steps per block
    t_glob: int = 60              # global KD steps
    lr_pre: float = 1e-4
    lr_post: float = 1e-5
    lr_glob: float = 1e-6
    microbatch: int = 4
    weighted_mse: bool = True
    min_dim: int = 48             # leave smaller linears in FP
    kd_temp: float = 1.0
    seed: int = 0
    # ablation switches (paper Tables 5-6)
    init_method: str = "lb_admm"  # lb_admm | dual_svid | dbf_admm
    skip_tune_fp: bool = False
    skip_ste: bool = False
    skip_kd: bool = False
    # init-method fallback ladder: on a diverged block (non-finite
    # latents / losses / reconstruction error) the block is retried
    # with these ``@register_init_method`` names, in order, after
    # ``init_method`` (comma-separated so the config stays hashable
    # and JSON-manifest round-trippable). "" disables fallbacks.
    fallback_inits: str = "dbf_admm,dual_svid"

    def admm(self) -> ADMMConfig:
        return ADMMConfig(rank=0, iters=self.admm_iters,
                          rho_init=self.rho_init, rho_final=self.rho_final,
                          lam=self.lam)


# ---------------------------------------------------------------------------
# block enumeration
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockRef:
    stack: str                      # param-tree key
    idx: Any                        # index into the stack (int / tuple / None)
    tap_idx: Any                    # layer index in tap stats (None=aggregate)
    kind: str                       # attn | mamba | cross

    def get(self, params):
        bp = params[self.stack]
        if self.idx is None:
            return bp
        if isinstance(self.idx, tuple):
            for i in self.idx:
                bp = util.tree_index(bp, i)
            return bp
        return util.tree_index(bp, self.idx)


def blocks_of(cfg) -> List[BlockRef]:
    fam = cfg.family
    if fam in ("dense", "audio", "ssm"):
        kind = "mamba" if fam == "ssm" else "attn"
        return [BlockRef("layers", i, i, kind) for i in range(cfg.n_layers)]
    if fam == "moe":
        out = [BlockRef("dense_layers", i, i, "attn")
               for i in range(cfg.first_k_dense)]
        out += [BlockRef("layers", i, i, "attn")
                for i in range(cfg.n_layers - cfg.first_k_dense)]
        return out
    if fam == "hybrid":
        # shared attention block first (on teacher inputs), then SSM layers
        return ([BlockRef("shared_attn", None, None, "attn")]
                + [BlockRef("layers", i, i, "mamba")
                   for i in range(cfg.n_layers)])
    if fam == "vlm":
        per = cfg.cross_attn_every
        out: List[BlockRef] = []
        for g in range(cfg.n_layers // per):
            for i in range(per - 1):
                out.append(BlockRef("self_layers", (g, i),
                                    g * (per - 1) + i, "attn"))
            out.append(BlockRef("cross_layers", g, g, "cross"))
        return out
    raise ValueError(fam)


def make_apply(cfg, kind):
    if kind == "attn":
        def f(bp, x, ctx):
            return T._apply_attn_block(bp, cfg, x, jnp.arange(x.shape[1]))[0]
    elif kind == "mamba":
        def f(bp, x, ctx):
            return T._apply_mamba_block(bp, cfg, x)[0]
    elif kind == "cross":
        def f(bp, x, ctx):
            kv = L.image_kv(bp["xattn"], cfg, ctx["image_embeds"])
            return T._apply_cross_block(bp, cfg, x, kv)
    else:
        raise ValueError(kind)
    return f


# ---------------------------------------------------------------------------
# linear enumeration within a block
# ---------------------------------------------------------------------------

# selection rule + FP exclusions single-sourced in core.layout (shared
# with quant.surgery's abstract walk)
_EXCLUDE = EXCLUDE_LINEARS


def linear_paths(bp, min_dim: int) -> List[Tuple[str, ...]]:
    paths = []

    def walk(d, path):
        for k in sorted(d.keys()):
            v = d[k]
            if isinstance(v, dict):
                if "w" in v and not isinstance(v["w"], dict):
                    if quantizable_linear(k, v["w"].shape, min_dim):
                        paths.append(path + (k,))
                else:
                    walk(v, path + (k,))

    walk(bp, ())
    return paths


def _get_path(tree, path):
    for p in path:
        tree = tree[p]
    return tree


def _set_path(tree, path, val):
    out = dict(tree)
    if len(path) == 1:
        out[path[0]] = val
        return out
    out[path[0]] = _set_path(tree[path[0]], path[1:], val)
    return out


# ---------------------------------------------------------------------------
# tuning loops
# ---------------------------------------------------------------------------


def _mse(out, tgt, weight=None):
    d = (out.astype(jnp.float32) - tgt.astype(jnp.float32))
    if weight is not None:
        d = d * weight
    return jnp.mean(jnp.square(d))


def _channel_weight(Y, enabled):
    if not enabled:
        return None
    rms = jnp.sqrt(jnp.mean(jnp.square(Y.astype(jnp.float32)),
                            axis=tuple(range(Y.ndim - 1))) + 1e-8)
    w = 1.0 / rms
    return w / jnp.mean(w)


def _tune(apply_fn, bp, pred, Xq, Y, ctx, steps, lr, mb, weighted,
          key):
    """Generic block tuning: optimize leaves selected by `pred` so that
    apply_fn(bp, Xq) matches Y."""
    if steps <= 0:
        return bp, []
    trainable, frozen = util.partition(bp, pred)
    if not any(l is not None for l in jax.tree.leaves(
            trainable, is_leaf=lambda x: x is None)):
        return bp, []
    weight = _channel_weight(Y, weighted)
    opt = AdamW(cosine_schedule(lr, steps), clip_norm=1.0)
    state = opt.init(trainable)

    def loss(tr, xb, yb, cb):
        out = apply_fn(util.combine(tr, frozen), xb, cb)
        return _mse(out, yb, weight)

    vg = jax.jit(jax.value_and_grad(loss))
    n = Xq.shape[0]
    losses = []
    for s in range(steps):
        i0 = (s * mb) % max(n - mb + 1, 1)
        xb, yb = Xq[i0:i0 + mb], Y[i0:i0 + mb]
        cb = {k: v[i0:i0 + mb] for k, v in ctx.items()}
        lval, grads = vg(trainable, xb, yb, cb)
        trainable, state, _ = opt.update(grads, state, trainable)
        losses.append(float(lval))
    return util.combine(trainable, frozen), losses


_LATENT_KEYS = ("lu", "lv", "s1", "s2")


def _is_latent_path(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1]
    return leaf in _LATENT_KEYS


def _is_scale_path(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1]
    return leaf in ("s1", "s2")


# ---------------------------------------------------------------------------
# init dispatch (Table 5 ablation) — resolved through the repro.api
# init-method registry; new methods plug in via @register_init_method
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jitted_init(fn, rank: int, admm: ADMMConfig):
    return jax.jit(lambda w, d_in, d_out, key: fn(
        w, d_in, d_out, rank=rank, admm=admm, key=key))


def _init_latent_2d(w, d_in, d_out, rank, admm, method, key):
    # resolve on every call (cheap dict lookup) so re-registered /
    # unregistered methods take effect; the jit cache keys on the
    # resolved function object
    from repro.api.init_methods import get_init_method
    return _jitted_init(get_init_method(method), rank, admm)(
        w, d_in, d_out, key)


def _init_latent(p, d_in, d_out, qcfg: QuantConfig, key):
    from repro.core.bpw import rank_for_bpw
    w = p["w"]
    admm = qcfg.admm()
    if w.ndim == 3:
        E, din, dout = w.shape
        r = rank_for_bpw(dout, din, qcfg.target_bpw, qcfg.rank_align)
        keys = jax.random.split(key, E)
        lat = jax.vmap(lambda we, di, do, k: _init_latent_2d(
            we, di, do, r, admm, qcfg.init_method, k))(w, d_in, d_out, keys)
    else:
        din, dout = w.shape
        r = rank_for_bpw(dout, din, qcfg.target_bpw, qcfg.rank_align)
        lat = _init_latent_2d(w, d_in, d_out, r, admm, qcfg.init_method, key)
    lat = dict(lat)
    if "b" in p:
        lat["b"] = p["b"]
    return lat, r


def _pack_latent(lat: dict, k_align: int = 32) -> dict:
    def pack2d(lu, lv, s1, s2):
        return packing.pack_quantized(lu, lv, s1, s2, k_align=k_align)
    if lat["lu"].ndim == 3:
        q = jax.vmap(pack2d)(lat["lu"], lat["lv"],
                             lat["s1"].astype(jnp.float32),
                             lat["s2"].astype(jnp.float32))
    else:
        q = pack2d(lat["lu"], lat["lv"], lat["s1"], lat["s2"])
    if "b" in lat:
        q["b"] = lat["b"]
    return q


# ---------------------------------------------------------------------------
# health guards + per-block quantization with the init-method
# fallback ladder (docs/quantization.md)
# ---------------------------------------------------------------------------


def _ladder(qcfg: QuantConfig) -> List[str]:
    out = [qcfg.init_method]
    for m in qcfg.fallback_inits.split(","):
        m = m.strip()
        if m and m not in out:
            out.append(m)
    return out


def _check_finite(tree, block: str, layer, reason: str, iteration=None):
    """Raise a structured :class:`QuantizationError` if any float leaf
    of `tree` is non-finite — the guard that keeps NaNs out of
    ``quant.surgery`` packing and the saved artifact."""
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.floating):
            continue
        if not bool(jnp.isfinite(arr.astype(jnp.float32)).all()):
            where = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            raise QuantizationError(
                layer=layer if layer is not None else where, block=block,
                iteration=iteration,
                reason=f"{reason}: non-finite values in {where}")


def _attempt_key(kb, ai: int, li: int):
    """Per-(attempt, linear) RNG key. Attempt 0 reproduces the
    historical keying exactly, so journal/resume bit-identity holds
    across code that never falls back."""
    if ai == 0:
        return jax.random.fold_in(kb, li)
    return jax.random.fold_in(jax.random.fold_in(kb, 7919 + ai), li)


def _quantize_block(apply_fn, bp_fp, Xq_b, Y, ctx_b, stats, bref,
                    label: str, qcfg: QuantConfig, kb, faults, bi: int,
                    log):
    """Steps 1-3 + packing for one block, with divergence detection and
    the init-method fallback ladder. Returns (packed bp, out_q, report
    row, {rank-key: rank})."""
    # Step 1: error-propagation mitigation (method-independent — run
    # once, shared across ladder attempts)
    if not qcfg.skip_tune_fp:
        bp_base, pre_losses = _tune(apply_fn, bp_fp, lambda p: True, Xq_b,
                                    Y, ctx_b, qcfg.t_pre, qcfg.lr_pre,
                                    qcfg.microbatch, qcfg.weighted_mse, kb)
    else:
        bp_base, pre_losses = bp_fp, []
    if pre_losses and not np.isfinite(pre_losses[-1]):
        raise QuantizationError(
            None, label, None, "TuneFP (error-propagation mitigation) "
            "diverged: non-finite loss — no init method can fix a "
            "poisoned block input; check calibration data")
    _check_finite(bp_base, label, None, "TuneFP output")

    lpaths = linear_paths(bp_base, qcfg.min_dim)
    ladder = _ladder(qcfg)
    fallbacks: List[dict] = []
    for ai, method in enumerate(ladder):
        try:
            # Step 2: low-rank binary initialization
            bp, ranks_b = bp_base, {}
            for li, path in enumerate(lpaths):
                pdict = _get_path(bp, path)
                name = ".".join(path)
                w = pdict["w"]
                expert = w.shape[0] if w.ndim == 3 else None
                d_in, d_out = precond.preconditioners_for(
                    stats, bref.stack, name, bref.tap_idx,
                    w.shape[-2], w.shape[-1], qcfg.gamma,
                    expert_shape=expert)
                lat, r = _init_latent(
                    pdict, d_in, d_out,
                    dataclasses.replace(qcfg, init_method=method),
                    _attempt_key(kb, ai, li))
                fault = (faults.poison_init(bi, li)
                         if faults is not None else None)
                if fault is not None:
                    lat = dict(lat, lu=jnp.full_like(lat["lu"], jnp.nan))
                _check_finite(
                    {k: lat[k] for k in _LATENT_KEYS}, label, name,
                    f"init method {method!r} produced non-finite latents",
                    iteration=fault.iteration if fault is not None else None)
                ranks_b[f"{bref.stack}[{bref.idx}].{name}"] = r
                bp = _set_path(bp, path, lat)

            # Step 3: factorized component refinement (STE)
            if not qcfg.skip_ste:
                bp, ste_losses = _tune(apply_fn, bp, _is_latent_path,
                                       Xq_b, Y, ctx_b, qcfg.t_post,
                                       qcfg.lr_post, qcfg.microbatch,
                                       qcfg.weighted_mse, kb)
                if ste_losses and not np.isfinite(ste_losses[-1]):
                    raise QuantizationError(
                        None, label, None,
                        f"STE refinement diverged under init "
                        f"{method!r}: non-finite loss")
            else:
                ste_losses = []

            # pack + final guard
            for path in lpaths:
                bp = _set_path(bp, path,
                               _pack_latent(_get_path(bp, path),
                                            qcfg.pack_k_align))
            _check_finite(bp, label, None,
                          f"packed block under init {method!r}")
            out_q = apply_fn(bp, Xq_b, ctx_b)
            blk_err = float(_mse(out_q, Y))
            if not np.isfinite(blk_err):
                raise QuantizationError(
                    None, label, None, f"block reconstruction error is "
                    f"non-finite under init {method!r}")
            row = {"block": label,
                   "pre_loss": pre_losses[-1] if pre_losses else None,
                   "ste_loss": ste_losses[-1] if ste_losses else None,
                   "block_err": blk_err,
                   "init_method": method,
                   "fallbacks": list(fallbacks)}
            return bp, out_q, row, ranks_b
        except QuantizationError as e:
            fallbacks.append({"method": method, "layer": e.layer,
                              "iteration": e.iteration, "reason": e.reason})
            if ai == len(ladder) - 1:
                raise QuantizationError(
                    e.layer, label, e.iteration,
                    f"init-method fallback ladder exhausted "
                    f"({' -> '.join(ladder)}); last failure: {e.reason}")
            log(f"[nanoquant] {label}: init {method!r} diverged "
                f"({e.reason}) -> falling back to {ladder[ai + 1]!r}")


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------


def nanoquant_quantize(params, cfg, calib_batches, qcfg: QuantConfig,
                       verbose: bool = True, journal_dir: str = None,
                       resume: bool = False, faults=None,
                       heartbeat=None):
    """Quantize `params` (FP teacher) to packed low-rank binary form.

    calib_batches: list of {'tokens','labels'[,'image_embeds']} dicts.
    Returns (quantized_params, report).

    Crash safety (docs/quantization.md): with `journal_dir`, every
    finished block's packed leaves plus a crc32'd journal entry are
    written through ``checkpoint.journal.QuantJournal`` as the run
    progresses; `resume=True` validates the journal against this run's
    fingerprint (model/quant config, params, calibration) and skips
    finished blocks — the final artifact is bit-identical to an
    uninterrupted run (per-block RNG keying, deterministic streams).
    Diverging blocks retry through the ``QuantConfig.fallback_inits``
    init-method ladder; every decision lands in the journal and the
    report. `faults` (a ``quant.faults.QuantFaultPlan``) injects a
    deterministic fault schedule for chaos testing; `heartbeat` is
    called with a short progress string at block/phase boundaries (what
    ``launch/quantize.py --supervise`` hang detection watches)."""
    t0 = time.time()
    key = jax.random.PRNGKey(qcfg.seed)
    report: Dict[str, Any] = {"blocks": [], "ranks": {}}

    def beat(msg: str) -> None:
        if heartbeat is not None:
            heartbeat(msg)

    def log(msg: str) -> None:
        if verbose:
            print(msg, flush=True)

    # ---- Phase 1: global calibration -------------------------------------
    stats = precond.collect_stats(T.loss_fn, params, cfg, calib_batches)

    # ---- activation streams ----------------------------------------------
    toks = jnp.concatenate([b["tokens"] for b in calib_batches], 0)
    ctx = {}
    if cfg.family == "vlm":
        ctx["image_embeds"] = jnp.concatenate(
            [b["image_embeds"] for b in calib_batches], 0)
    x0 = T.embed_tokens(params, cfg, toks)
    Xq, Xfp = x0, x0

    blocks = blocks_of(cfg)
    applies = {b.kind: make_apply(cfg, b.kind) for b in blocks}
    quantized: Dict[Tuple, Any] = {}
    hybrid_boundary = (lambda i: cfg.family == "hybrid"
                       and (i + 1) % cfg.attn_every == 0)

    # ---- journal / resume --------------------------------------------------
    journal, done = None, {}
    if journal_dir:
        from repro.checkpoint.journal import QuantJournal, run_fingerprint
        journal = QuantJournal(journal_dir)
        fingerprint = run_fingerprint(params, cfg, qcfg, calib_batches,
                                      len(blocks))
        if resume:
            done = journal.entries_for_resume(fingerprint)
            if done is None:                # no journal yet: fresh start
                done = {}
                journal.start(fingerprint)
            elif done:
                log(f"[nanoquant] resuming: {len(done)}/{len(blocks)} "
                    f"blocks journaled in {journal_dir}")
        else:
            journal.start(fingerprint)
    elif resume:
        raise ValueError("resume=True requires journal_dir")

    # For the hybrid shared block: gather its application inputs from the
    # teacher stream up-front (it is quantized first, see DESIGN.md §5).
    shared_inputs = None
    if cfg.family == "hybrid":
        xs, gathered = x0, []
        fp_blocks = [b for b in blocks if b.stack == "layers"]
        for b in fp_blocks:
            xs = applies["mamba"](b.get(params), xs, ctx)
            if hybrid_boundary(b.idx):
                gathered.append(xs)
                xs = applies["attn"](params["shared_attn"], xs, ctx)
        shared_inputs = jnp.concatenate(gathered, 0)

    # ---- Phase 2: block reconstruction ------------------------------------
    for bi, bref in enumerate(blocks):
        label = f"{bref.stack}[{bref.idx}]"
        kb = jax.random.fold_in(key, bi)
        bp_fp = bref.get(params)
        apply_fn = applies[bref.kind]
        if bref.stack == "shared_attn":
            Xq_b = shared_inputs
            Xfp_b = shared_inputs
            ctx_b = {k: jnp.concatenate([v] * (Xq_b.shape[0] // v.shape[0]), 0)
                     for k, v in ctx.items()}
        else:
            Xq_b, Xfp_b, ctx_b = Xq, Xfp, ctx
        Y = apply_fn(bp_fp, Xfp_b, ctx_b)

        if bi in done:
            # resumed: reload the packed block, replay its report row
            # (the recomputation it replaces is deterministic, so the
            # artifact stays bit-identical to an uninterrupted run)
            entry = done[bi]
            bp = journal.load_block(bi)
            report["ranks"].update(entry["ranks"])
            row = dict(entry["row"])
            out_q = apply_fn(bp, Xq_b, ctx_b)
            beat(f"block={bi}/{len(blocks)} {label} resumed")
        else:
            if faults is not None:
                faults.on_block_start(bi)
            beat(f"block={bi}/{len(blocks)} {label} start")
            bp, out_q, row, ranks_b = _quantize_block(
                apply_fn, bp_fp, Xq_b, Y, ctx_b, stats, bref, label,
                qcfg, kb, faults, bi, log)
            report["ranks"].update(ranks_b)
            if journal is not None:
                extra = journal.save_block(bi, label, bp)
                if faults is not None:
                    faults.after_block_save(bi)
                journal.append_block({"bi": bi, "block": label,
                                      "ranks": ranks_b, "row": row,
                                      **extra})
                if faults is not None:
                    faults.on_journal_append(bi, journal)
            beat(f"block={bi}/{len(blocks)} {label} done "
                 f"err={row['block_err']:.5f}")
        quantized[(bref.stack, bref.idx)] = bp

        # advance streams
        if bref.stack != "shared_attn":
            Xq = out_q
            Xfp = Y
            if hybrid_boundary(bref.idx):
                Xq = applies["attn"](quantized[("shared_attn", None)], Xq, ctx)
                Xfp = applies["attn"](params["shared_attn"], Xfp, ctx)
        report["blocks"].append(row)
        log(f"[nanoquant] {label} err={row['block_err']:.5f}"
            + (f" init={row['init_method']}" if row["fallbacks"] else ""))

    qparams = _assemble(params, cfg, quantized)

    # ---- Phase 3: scale-only model reconstruction (KD) --------------------
    if not qcfg.skip_kd and qcfg.t_glob > 0:
        qparams, kd_losses = _tune_scales_kd(params, qparams, cfg,
                                             calib_batches, qcfg,
                                             heartbeat=heartbeat)
        report["kd_losses"] = kd_losses

    report["wall_s"] = time.time() - t0
    return qparams, report


def _assemble(params, cfg, quantized):
    out = dict(params)
    stacks: Dict[str, dict] = {}
    for (stack, idx), bp in quantized.items():
        stacks.setdefault(stack, {})[idx] = bp
    for stack, items in stacks.items():
        if None in items:                       # unstacked (shared_attn)
            out[stack] = items[None]
        elif isinstance(next(iter(items)), tuple):   # (g, i) — vlm self
            gs = sorted({g for g, _ in items})
            per = sorted({i for _, i in items})
            out[stack] = util.tree_stack(
                [util.tree_stack([items[(g, i)] for i in per]) for g in gs])
        else:
            out[stack] = util.tree_stack(
                [items[i] for i in sorted(items)])
    return out


def _kd_loss_chunked(hS, hT, params_s, params_t, cfg, temp):
    wS = T._head_w(params_s, cfg)
    wT = T._head_w(params_t, cfg)
    S = hS.shape[1]
    chunk = min(cfg.loss_chunk or S, S)
    if S % chunk:
        chunk = S
    nc = S // chunk

    def body(carry, inp):
        hs, ht = inp
        zS = (hs @ wS.astype(hs.dtype)).astype(jnp.float32) / temp
        zT = (ht @ wT.astype(ht.dtype)).astype(jnp.float32) / temp
        pT = jax.nn.softmax(zT, -1)
        kl = jnp.sum(pT * (jax.nn.log_softmax(zT, -1)
                           - jax.nn.log_softmax(zS, -1)), -1)
        return carry + kl.sum(), None

    hSc = hS.reshape(hS.shape[0], nc, chunk, -1).swapaxes(0, 1)
    hTc = hT.reshape(hT.shape[0], nc, chunk, -1).swapaxes(0, 1)
    tot, _ = jax.lax.scan(body, jnp.zeros(()), (hSc, hTc))
    return tot / (hS.shape[0] * S)


def _tune_scales_kd(teacher, qparams, cfg, calib_batches, qcfg: QuantConfig,
                    heartbeat=None):
    """Phase 3 (Eq. 11): packed binaries frozen, optimize only {s1,s2}."""
    trainable, frozen = util.partition(qparams, _is_scale_path)
    opt = AdamW(cosine_schedule(qcfg.lr_glob, qcfg.t_glob), clip_norm=1.0)
    state = opt.init(trainable)

    def loss(tr, batch):
        qp = util.combine(tr, frozen)
        hS = T.backbone(qp, cfg, batch["tokens"], batch.get("image_embeds"))
        hT = T.backbone(teacher, cfg, batch["tokens"],
                        batch.get("image_embeds"))
        return _kd_loss_chunked(hS, hT, qp, teacher, cfg, qcfg.kd_temp)

    vg = jax.jit(jax.value_and_grad(loss))
    losses = []
    for s in range(qcfg.t_glob):
        b = calib_batches[s % len(calib_batches)]
        lval, grads = vg(trainable, b)
        trainable, state, _ = opt.update(grads, state, trainable)
        losses.append(float(lval))
        if heartbeat is not None and (s % 10 == 0 or s == qcfg.t_glob - 1):
            heartbeat(f"kd step={s + 1}/{qcfg.t_glob} loss={losses[-1]:.5f}")
    return util.combine(trainable, frozen), losses


# public name (repro.api): run Phase 3 standalone with its own data
# budget (paper Table 9 block-vs-model reconstruction splits)
tune_scales_kd = _tune_scales_kd
