"""Robust diagonal K-FAC preconditioners (paper Alg. 1 Phase 1, Eq. 2–3).

``StatCollector`` accumulates, per named linear layer, the diagonal of the
activation second-moment (A = E[x xᵀ] diag — forward tap) and of the
output-gradient second-moment (G = E[g gᵀ] diag — backward tap). The
diagonal preconditioners are D_in = diag(A)^½, D_out = diag(G)^½, so that
‖D_out (W−Ŵ) D_in‖² is the diagonal K-FAC approximation of the task-loss
Hessian quadratic form. :func:`robust_diag` applies Ledoit–Wolf-style
shrinkage toward the mean plus clipping (Eq. 3).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp


class StatCollector:
    """Host-side accumulator fed by jax.debug.callback taps.

    Keys: (stack, name, field, layer_idx) -> {'sq': np (d,), 'cnt': float}.
    Works under jit/scan: the layer index arrives as a runtime value.
    """

    def __init__(self):
        self.data: Dict[Tuple, dict] = {}
        self._cbs = {}

    def make_cb(self, stack: str, name: str, field: str):
        key = (stack, name, field)
        if key not in self._cbs:
            self._cbs[key] = functools.partial(self._accumulate, key)
        return self._cbs[key]

    def _accumulate(self, key, idx, sq, cnt):
        idx = int(np.asarray(idx))
        full = key + (idx,)
        sq = np.asarray(sq, np.float64)
        cnt = float(np.asarray(cnt))
        slot = self.data.setdefault(full, {"sq": np.zeros_like(sq), "cnt": 0.0})
        slot["sq"] += sq
        slot["cnt"] += cnt

    # ---- lookups -----------------------------------------------------------

    def mean_sq(self, stack: str, name: str, field: str, idx: int):
        slot = self.data.get((stack, name, field, idx))
        if slot is None:
            return None
        return slot["sq"] / max(slot["cnt"], 1.0)

    def mean_sq_agg(self, stack: str, name: str, field: str):
        """Aggregate over all layer indices (e.g. shared attention block
        applied at several depths)."""
        tot, cnt = None, 0.0
        for (s, n, f, i), slot in self.data.items():
            if (s, n, f) == (stack, name, field):
                tot = slot["sq"] if tot is None else tot + slot["sq"]
                cnt += slot["cnt"]
        if tot is None:
            return None
        return tot / max(cnt, 1.0)


def robust_diag(mean_sq: np.ndarray, gamma: float, eps: float = 1e-6,
                tau_max: float = 1e4) -> jnp.ndarray:
    """mean_sq: per-channel second moment -> shrunk, clipped, normalized
    diagonal preconditioner (paper Eq. 3 + Lemma 1 clipping)."""
    d = np.sqrt(np.maximum(mean_sq, 0.0))
    d = (1.0 - gamma) * d + gamma * d.mean()
    d = np.clip(d, eps, tau_max)
    d = d / max(d.mean(), 1e-12)          # scale-free (cancelled by balancing)
    return jnp.asarray(d, jnp.float32)


def collect_stats(loss_fn, params, cfg, batches, jit: bool = True):
    """Run calibration batches through the FP model with taps installed,
    doing a full forward+backward per batch (grad wrt params is discarded —
    we only need the activation/gradient taps)."""
    from repro.models import layers as L

    collector = StatCollector()

    def _loss(p, b):
        return loss_fn(p, cfg, b, training=False)
    fwd = jax.jit(_loss) if jit else _loss
    g = jax.jit(jax.grad(_loss)) if jit else jax.grad(_loss)
    try:
        # "in" taps: forward-only pass. jax drops plain debug callbacks
        # inside scan bodies under grad (the primal is re-staged through
        # partial eval without them), so the activation moments must come
        # from an undifferentiated forward.
        L.set_tap(collector, fields=("in",))
        for b in batches:
            fwd(params, b)
            jax.effects_barrier()          # block until callbacks flush
        # "out" taps: fire from the custom-vjp backward rule, which the
        # grad pass does execute.
        L.set_tap(collector, fields=("out",))
        for b in batches:
            g(params, b)
            jax.effects_barrier()
    finally:
        L.set_tap(None)
    return collector


def preconditioners_for(collector: StatCollector, stack: str, name: str,
                        idx, d_in_dim: int, d_out_dim: int, gamma: float,
                        expert_shape=None):
    """Build (D_in, D_out) for one linear, falling back to identity when
    stats are missing (e.g. a layer the calibration never activated)."""
    if idx is None:
        a = collector.mean_sq_agg(stack, name, "in")
        g = collector.mean_sq_agg(stack, name, "out")
    else:
        a = collector.mean_sq(stack, name, "in", idx)
        g = collector.mean_sq(stack, name, "out", idx)
    if expert_shape is not None:
        E = expert_shape
        d_in = (jnp.ones((E, d_in_dim), jnp.float32) if a is None else
                jnp.stack([robust_diag(a[e], gamma) for e in range(E)]))
        d_out = (jnp.ones((E, d_out_dim), jnp.float32) if g is None else
                 jnp.stack([robust_diag(g[e], gamma) for e in range(E)]))
        return d_in, d_out
    d_in = (jnp.ones((d_in_dim,), jnp.float32) if a is None
            else robust_diag(np.asarray(a), gamma))
    d_out = (jnp.ones((d_out_dim,), jnp.float32) if g is None
             else robust_diag(np.asarray(g), gamma))
    return d_in, d_out
