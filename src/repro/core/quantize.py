"""Per-layer quantization entry: preconditioning -> LB-ADMM -> balancing.

Operates on weights in the model's (d_in, d_out) layout; internally works
in the paper's (d_out, d_in) orientation. Returns *latent* param dicts
({'lu','lv','s1','s2'}) consumed by the STE refinement phase; packing to
uint32 happens after refinement (core.packing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.admm import ADMMConfig, lb_admm
from repro.core.balance import magnitude_balance
from repro.core.bpw import rank_for_bpw


def quantize_weight(w, d_in, d_out, rank: int, admm: ADMMConfig, key):
    """w: (d_in, d_out); d_in: (d_in,), d_out: (d_out,) preconditioners.
    Returns latent dict with lu (d_out, r), lv (d_in, r), s1, s2."""
    W = w.astype(jnp.float32).T                        # paper layout (dout, din)
    Wt = d_out[:, None] * W * d_in[None, :]            # Alg. 1 line 15
    res = lb_admm(Wt, admm._replace(rank=rank), key)
    lat_u, lat_v, s1, s2 = magnitude_balance(res["p_u"], res["p_v"],
                                             d_out, d_in)
    return ({"lu": lat_u, "lv": lat_v, "s1": s1, "s2": s2},
            {"residual_trace": res["residual_trace"],
             "health": res["health"]})


def quantize_leaf(p: dict, d_in, d_out, target_bpw: float, admm: ADMMConfig,
                  key, rank_align: int = 32):
    """Quantize one linear param dict ({'w': (din,dout) or (E,din,dout)}).
    Bias (if any) is carried over in FP. Returns (latent dict, info)."""
    w = p["w"]
    if w.ndim == 3:                                    # stacked experts
        E, din, dout = w.shape
        r = rank_for_bpw(dout, din, target_bpw, rank_align)
        keys = jax.random.split(key, E)
        lat, info = jax.vmap(
            lambda we, di, do, k: quantize_weight(we, di, do, r, admm, k)
        )(w, d_in, d_out, keys)
    else:
        din, dout = w.shape
        r = rank_for_bpw(dout, din, target_bpw, rank_align)
        lat, info = quantize_weight(w, d_in, d_out, r, admm, key)
    if "b" in p:
        lat["b"] = p["b"]
    info["rank"] = r
    return lat, info
