"""Packed binary matmul kernels (docs/kernels.md).

- :mod:`repro.kernels.binary_matmul` — the Pallas TPU kernels: the
  fused single-pass low-rank chain (grouped for merged projections /
  stacked experts) and the legacy two-call baseline.
- :mod:`repro.kernels.paged_attention` — the Pallas gather-attention
  decode kernel that walks a paged KV pool's block tables
  (serve.paging) instead of slicing a rectangular cache.
- :mod:`repro.kernels.ref` — pure-jnp oracles (SPMD-partitionable;
  what CPU runs and the multi-pod dry-run lowers) + sign packing.
- :mod:`repro.kernels.tuning` — block-size heuristics fitted to
  divisors of the operand dims, plus swept-table loading.
- :mod:`repro.kernels.ops` — the policy-dispatched public entry points
  (:class:`~repro.kernels.ops.KernelPolicy`: mode / fusion / merged
  projections / block table / tensor-parallel mesh).

Import :mod:`repro.kernels.ops` (or go through ``repro.api``) rather
than the kernel modules directly. The package itself imports nothing,
so ``from repro.kernels import ref`` never drags Pallas in for callers
that only pack (a star-import *does* pull all five submodules via
``__all__``).
"""
__all__ = ["binary_matmul", "ops", "paged_attention", "ref", "tuning"]
