"""Jit'd public wrappers for the binary kernels.

The model stack calls :func:`lowrank_binary_matmul`; execution is
governed by an explicit, immutable :class:`KernelPolicy`:

- ``mode="ref"``    — pure-jnp oracle. Lowerable on every backend and
  under any pjit sharding, so it is the right choice for CPU runs and
  the multi-pod dry-run (XLA SPMD partitions it like any matmul chain).
- ``mode="pallas"`` — the Pallas TPU kernel (interpret mode off-TPU),
  for real deployments and kernel validation.
- ``mode="auto"``   — pallas on TPU backends, ref elsewhere.

A policy can be threaded explicitly (``lowrank_binary_matmul(...,
policy=p)``), installed for a scope (``with kernel_policy(p): ...``), or
set process-wide (:func:`set_kernel_policy`). The scoped form restores
the previous policy on exit and is contextvar-based, so concurrent
threads / asyncio tasks do not trample each other.

``set_kernel_mode`` / ``kernel_mode`` are deprecated shims over the old
mutable process-global mode list.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import warnings
from typing import Optional, Union

import jax

from repro.kernels import binary_matmul, ref

_MODES = ("auto", "ref", "pallas")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Execution policy for the packed binary matmul.

    interpret: run the Pallas kernel in interpreter mode. ``None``
    resolves at call time to "interpret unless on a real TPU backend".
    """
    mode: str = "auto"
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown kernel mode {self.mode!r}; choose from {_MODES}")

    def use_pallas(self) -> bool:
        if self.mode == "auto":
            return jax.default_backend() == "tpu"
        return self.mode == "pallas"

    def resolve_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret


# Scoped overrides live in a ContextVar (thread/async-local); the
# process-wide default lives in a plain module global so that
# set_kernel_policy is visible from every thread (new threads start with
# a fresh contextvars.Context and would miss a ContextVar-only set).
_DEFAULT_POLICY = [KernelPolicy()]
_POLICY: contextvars.ContextVar[Optional[KernelPolicy]] = \
    contextvars.ContextVar("nanoquant_kernel_policy", default=None)


def current_kernel_policy() -> KernelPolicy:
    scoped = _POLICY.get()
    return scoped if scoped is not None else _DEFAULT_POLICY[0]


def set_kernel_policy(policy: KernelPolicy) -> KernelPolicy:
    """Install `policy` process-wide (all threads); returns the previous
    default. Scoped `kernel_policy(...)` overrides still win."""
    prev = _DEFAULT_POLICY[0]
    _DEFAULT_POLICY[0] = _coerce(policy)
    return prev


def _coerce(policy: Union[KernelPolicy, str]) -> KernelPolicy:
    if isinstance(policy, str):
        return KernelPolicy(mode=policy)
    return policy


@contextlib.contextmanager
def kernel_policy(policy: Union[KernelPolicy, str]):
    """Scoped policy override (this thread/task only); restores the
    prior policy on exit."""
    token = _POLICY.set(_coerce(policy))
    try:
        yield current_kernel_policy()
    finally:
        _POLICY.reset(token)


def lowrank_binary_matmul(x, qv, qu_t, s1, s2,
                          policy: Optional[KernelPolicy] = None):
    """y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ  — packed operands (paper Eq. 1).

    Dispatches per `policy` (explicit argument wins, else the active
    contextvar policy)."""
    p = policy if policy is not None else current_kernel_policy()
    if p.use_pallas():
        return binary_matmul.lowrank_binary_matmul_pallas(
            x, qv, qu_t, s1, s2, interpret=p.resolve_interpret())
    return ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)


# ---------------------------------------------------------------------------
# deprecated process-global mode API (pre-KernelPolicy)
# ---------------------------------------------------------------------------


def set_kernel_mode(mode: str) -> None:
    """Deprecated: use ``set_kernel_policy(KernelPolicy(mode=...))``."""
    warnings.warn("set_kernel_mode is deprecated; use set_kernel_policy",
                  DeprecationWarning, stacklevel=2)
    set_kernel_policy(KernelPolicy(mode=mode))


@contextlib.contextmanager
def kernel_mode(mode: str):
    """Deprecated: use ``kernel_policy(mode)``."""
    warnings.warn("kernel_mode is deprecated; use kernel_policy",
                  DeprecationWarning, stacklevel=2)
    with kernel_policy(mode):
        yield


pack_signs = ref.pack_signs
unpack_signs = ref.unpack_signs
