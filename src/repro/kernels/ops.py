"""Jit'd public wrappers for the binary kernels.

The model stack calls :func:`lowrank_binary_matmul` (plus the merged
multi-projection and stacked-expert entry points below); execution is
governed by an explicit, immutable :class:`KernelPolicy`:

- ``mode="ref"``    — pure-jnp oracle. Lowerable on every backend and
  under any pjit sharding, so it is the right choice for CPU runs and
  the multi-pod dry-run (XLA SPMD partitions it like any matmul chain).
- ``mode="pallas"`` — the Pallas TPU kernels (interpret mode off-TPU),
  for real deployments and kernel validation.
- ``mode="auto"``   — pallas on TPU backends, ref elsewhere.

On the pallas path, ``fused=True`` (default) runs the whole low-rank
chain as ONE kernel with the rank-r intermediate held in VMEM
(:func:`repro.kernels.binary_matmul.fused_lowrank_matmul`);
``fused=False`` keeps the legacy two-``pallas_call`` form.
``merge_projections=True`` additionally lets the model layer batch
projections that share an input (QKV, gate/up) into a single grouped
kernel launch. Block sizes come from a heuristic table keyed on
(M, K, N, r) — see :mod:`repro.kernels.tuning` — overridable per policy
via ``block_table=`` (rows from ``tuning.load_block_table``).

Tensor-parallel launch: when the policy carries a ``mesh`` (an axis
named ``tp_axis``, normally ``"model"``), every entry point wraps its
kernel in ``shard_map`` so each device runs the *local* kernel on its
weight shard, mirroring the Megatron pairing of
``repro.sharding.rules``: column-parallel projections (QKV / gate-up /
mamba z/x) compute their d_out shard with no collective, row-parallel
projections (wo / w_down / out_proj) consume a d_in-sharded input and
finish with ONE psum over the small (..., d_out) partial, and merged /
stacked-expert group launches stay shard-local on the group axis.
Shapes that do not divide the axis fall back to the replicated
single-device launch — exactly the rules' divisibility fallback.

A policy can be threaded explicitly (``lowrank_binary_matmul(...,
policy=p)``), installed for a scope (``with kernel_policy(p): ...``), or
set process-wide (:func:`set_kernel_policy`). The scoped form restores
the previous policy on exit and is contextvar-based, so concurrent
threads / asyncio tasks do not trample each other.

``set_kernel_mode`` / ``kernel_mode`` are deprecated shims over the old
mutable process-global mode list; each warns exactly once per process.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import warnings
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import binary_matmul, ref, tuning

_MODES = ("auto", "ref", "pallas")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Execution policy for the packed binary matmul.

    interpret: run the Pallas kernel in interpreter mode. ``None``
    resolves at call time to "interpret unless on a real TPU backend".
    fused: single-pass kernel (VMEM-resident rank intermediate) vs the
    legacy two-call chain. merge_projections: allow grouped QKV /
    gate-up launches. block_table: optional tuple of
    ``(m_hi, k_hi, n_hi, r_hi, bm, bn, bk)`` rows (first match wins)
    replacing the built-in heuristic table — typically produced by the
    offline sweep (``python -m benchmarks.kernel_bench --sweep``)
    and loaded with :func:`repro.kernels.tuning.load_block_table`.
    """
    mode: str = "auto"
    interpret: Optional[bool] = None
    fused: bool = True
    merge_projections: bool = True
    # decode-step megakernel (QKV → paged attention → wo in one pass);
    # requires the fused merged-projection path and only engages for
    # qualifying launches — see decode_step_megakernel.
    megakernel: bool = True
    block_table: Optional[Tuple[Tuple[int, ...], ...]] = None
    # paged-kernel knob table: (b_hi, hkv_hi, d_hi, pages_hi,
    # pages_per_step, head_block) rows, from tuning.load_paged_table.
    paged_block_table: Optional[Tuple[Tuple[int, ...], ...]] = None
    # tensor-parallel launch: a jax Mesh with a `tp_axis` axis turns
    # every entry point into a shard_map over that axis (col/row per
    # repro.sharding.rules); None = single-device launch (default).
    # NB: sharding.rules places weights on "model" only — a different
    # tp_axis is for custom placements and forgoes the placement/launch
    # agreement (the InferenceEngine always pins "model").
    mesh: Optional[Any] = None
    tp_axis: str = "model"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown kernel mode {self.mode!r}; choose from {_MODES}")
        if self.block_table is not None:
            object.__setattr__(self, "block_table",
                               tuple(tuple(r) for r in self.block_table))
        if self.paged_block_table is not None:
            object.__setattr__(self, "paged_block_table",
                               tuple(tuple(r)
                                     for r in self.paged_block_table))

    def use_pallas(self) -> bool:
        if self.mode == "auto":
            return jax.default_backend() == "tpu"
        return self.mode == "pallas"

    def resolve_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def use_merged_projections(self) -> bool:
        """Whether the model layer should issue grouped QKV / gate-up
        kernel calls (requires the fused pallas path)."""
        return self.use_pallas() and self.fused and self.merge_projections

    def use_megakernel(self) -> bool:
        """Whether the model layer should try the fused decode-step
        megakernel (per-launch shape gating still applies — see
        :func:`decode_step_megakernel`)."""
        return self.use_merged_projections() and self.megakernel

    def tp_size(self) -> int:
        """Devices along the tensor-parallel axis (1 = no TP)."""
        if self.mesh is None or self.tp_axis not in self.mesh.axis_names:
            return 1
        return int(self.mesh.shape[self.tp_axis])

    def block_sizes(self, M: int, K: int, N: int, r: int,
                    dtype=jnp.float32) -> Tuple[int, int, int]:
        """(bm, bn, bk) for one call, from the heuristic table fitted to
        the concrete shape (divisor tiles — no weight padding)."""
        return tuning.fit_block_sizes(M, K, N, r, dtype, self.block_table)

    def paged_block_sizes(self, B: int, Hkv: int, D: int,
                          pages: int) -> Tuple[int, int]:
        """(pages_per_step, head_block) for one paged-attention launch,
        from the paged knob table fitted to the concrete shape."""
        return tuning.fit_paged_block_sizes(B, Hkv, D, pages,
                                            self.paged_block_table)


# Scoped overrides live in a ContextVar (thread/async-local); the
# process-wide default lives in a plain module global so that
# set_kernel_policy is visible from every thread (new threads start with
# a fresh contextvars.Context and would miss a ContextVar-only set).
_DEFAULT_POLICY = [KernelPolicy()]
_POLICY: contextvars.ContextVar[Optional[KernelPolicy]] = \
    contextvars.ContextVar("nanoquant_kernel_policy", default=None)


def current_kernel_policy() -> KernelPolicy:
    scoped = _POLICY.get()
    return scoped if scoped is not None else _DEFAULT_POLICY[0]


def set_kernel_policy(policy: KernelPolicy) -> KernelPolicy:
    """Install `policy` process-wide (all threads); returns the previous
    default. Scoped `kernel_policy(...)` overrides still win."""
    prev = _DEFAULT_POLICY[0]
    _DEFAULT_POLICY[0] = _coerce(policy)
    return prev


def _coerce(policy: Union[KernelPolicy, str]) -> KernelPolicy:
    if isinstance(policy, str):
        return KernelPolicy(mode=policy)
    return policy


@contextlib.contextmanager
def kernel_policy(policy: Union[KernelPolicy, str]):
    """Scoped policy override (this thread/task only); restores the
    prior policy on exit."""
    token = _POLICY.set(_coerce(policy))
    try:
        yield current_kernel_policy()
    finally:
        _POLICY.reset(token)


def _match_packed_k(x, qv):
    """Zero-pad x's feature dim up to the packed operand's K. Stored
    operands may be K-aligned past the activation width (surgery packs
    them tile-aligned); the padded s2 columns are zero so the extra
    columns contribute nothing."""
    Kw = qv.shape[-2] * 32
    d = x.shape[-1]
    if Kw == d:
        return x
    assert Kw > d, (qv.shape, x.shape)
    pad = [(0, 0)] * (x.ndim - 1) + [(0, Kw - d)]
    return jnp.pad(x, pad)


def _slice_rank(qv, qu_t, eff_rank: int):
    """In-trace rank truncation for the non-fused paths: keep the
    leading ``eff_rank`` rank columns of packed V (last axis) and the
    leading ``eff_rank // 32`` packed rows of Uᵀ. Pure slices — XLA
    reads sub-extents of the stored operands, no repack (the fused
    pallas launch does the same thing via BlockSpec sub-extents)."""
    r = qv.shape[-1]
    if not (0 < eff_rank <= r and eff_rank % 32 == 0):
        raise ValueError(f"eff_rank must be a multiple of 32 in (0, {r}], "
                         f"got {eff_rank}")
    return qv[..., :eff_rank], qu_t[..., :eff_rank // 32, :]


def _local_lowrank(x, qv, qu_t, s1, s2, p: KernelPolicy, eff_rank=None):
    """Single-device dispatch (x already matched to the packed K)."""
    if p.use_pallas():
        r = qv.shape[-1]
        M = x.size // x.shape[-1]
        bm, bn, bk = p.block_sizes(M, x.shape[-1], qu_t.shape[-1],
                                   eff_rank or r, x.dtype)
        interp = p.resolve_interpret()
        if p.fused and r <= binary_matmul.MAX_FUSED_RANK:
            return binary_matmul.fused_lowrank_matmul(
                x, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk,
                eff_rank=eff_rank, interpret=interp)
        if eff_rank is not None:
            qv, qu_t = _slice_rank(qv, qu_t, eff_rank)
        return binary_matmul.lowrank_binary_matmul_twocall(
            x, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk, interpret=interp)
    if eff_rank is not None:
        qv, qu_t = _slice_rank(qv, qu_t, eff_rank)
    return ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)


def _shard_launch(p: KernelPolicy, local, in_specs, out_specs, *operands,
                  reduce_axis=None):
    """shard_map-wrap a ``_local_*`` dispatcher over the policy mesh:
    each device runs `local(*operands_shard, local_policy)` on its
    shard (the local policy is the same policy with the mesh stripped),
    optionally finishing with one psum over `reduce_axis`."""
    from repro.sharding.rules import shard_map_compat
    lp = dataclasses.replace(p, mesh=None)

    def body(*ops_):
        y = local(*ops_, lp)
        return jax.lax.psum(y, reduce_axis) if reduce_axis else y

    return shard_map_compat(body, p.mesh, in_specs=in_specs,
                            out_specs=out_specs)(*operands)


def _tp_lowrank(x, qv, qu_t, s1, s2, p: KernelPolicy, role: str,
                eff_rank=None):
    """shard_map launch over the policy mesh (Megatron pairing):

    - col: U/s1 arrive d_out-sharded, each device runs the whole fused
      kernel on its output shard — no collective, output stays sharded.
    - row: V/s2 arrive d_in-sharded with a d_in-sharded input, each
      device computes a full-width partial and ONE psum finishes it.

    Returns None when the shape does not divide the axis (caller falls
    back to the replicated single-device launch, mirroring the
    divisibility fallback of ``sharding.rules``)."""
    ax, n = p.tp_axis, p.tp_size()
    lead = (None,) * (x.ndim - 1)
    # rank axes (qv last, qu_t leading packed) are never the sharded
    # dims, so eff_rank truncation composes with either TP role.
    local = functools.partial(_local_lowrank, eff_rank=eff_rank) \
        if eff_rank is not None else _local_lowrank
    if role == "col" and qu_t.shape[-1] % n == 0:
        return _shard_launch(
            p, local,
            (P(*lead, None), P(None, None), P(None, ax), P(ax), P(None)),
            P(*lead, ax), x, qv, qu_t, s1, s2)
    if role == "row" and qv.shape[-2] % n == 0:
        return _shard_launch(
            p, local,
            (P(*lead, ax), P(ax, None), P(None, None), P(None), P(ax)),
            P(*lead, None), x, qv, qu_t, s1, s2, reduce_axis=ax)
    return None


def lowrank_binary_matmul(x, qv, qu_t, s1, s2,
                          policy: Optional[KernelPolicy] = None,
                          tp: Optional[str] = None,
                          eff_rank: Optional[int] = None):
    """y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ  — packed operands (paper Eq. 1).

    Dispatches per `policy` (explicit argument wins, else the active
    contextvar policy). `tp`: this linear's Megatron role ('col' |
    'row' | None, see ``sharding.rules.tp_role``) — only consulted when
    the policy carries a mesh, in which case the kernel is launched
    through ``shard_map`` on the policy's tensor-parallel axis.
    `eff_rank`: optional effective rank R' <= r (multiple of 32) — the
    launch reads only the leading R' singular components of the packed
    factors (BlockSpec sub-extents on the fused pallas path, in-trace
    slices elsewhere; the stored operands are never repacked). Equals
    zeroing the trailing r - R' components: the rank-truncated draft
    forward of ``serve.speculative``."""
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, qv)
    if p.tp_size() > 1 and tp in ("col", "row") and qv.ndim == 2:
        y = _tp_lowrank(x, qv, qu_t, s1, s2, p, tp, eff_rank=eff_rank)
        if y is not None:
            return y
    return _local_lowrank(x, qv, qu_t, s1, s2, p, eff_rank=eff_rank)


def lowrank_binary_matmul_merged(x, mp, dims: Sequence[int],
                                 policy: Optional[KernelPolicy] = None,
                                 eff_rank: Optional[int] = None):
    """Grouped projections sharing one input (QKV / gate-up): ONE kernel
    launch instead of len(dims).

    mp: merged param dict from ``quant.surgery.merge_projection_groups``
    — ``qv`` (P, K//32, R), ``qu_t`` (P, R//32, Nmax), ``s1`` (P, Nmax),
    ``s2`` (P, K), ``rmask`` (P, R) (every projection padded to the
    widest rank R / output Nmax; padded s1 columns are 0 and rmask zeros
    the padded rank columns). dims: static true d_out per projection.
    Returns a list of per-projection outputs (..., dims[i]).

    There is no two-call form of the merged launch (merging exists to
    eliminate launches): when the policy disables the fused pallas path
    the fallback is the grouped jnp oracle. The model layer only routes
    here when ``policy.use_merged_projections()`` is true, so a
    ``fused=False`` pallas policy runs per-projection two-call kernels
    via :func:`lowrank_binary_matmul` instead.
    """
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, mp["qv"])
    shape = x.shape
    x2 = x.reshape(1, -1, shape[-1])
    R = mp["qv"].shape[-1]
    rmask = mp.get("rmask")
    if rmask is None:
        rmask = jnp.ones((mp["qv"].shape[0], R), jnp.float32)
    yg = None
    local = functools.partial(_local_merged, eff_rank=eff_rank) \
        if eff_rank is not None else _local_merged
    if p.tp_size() > 1 and mp["qv"].ndim == 3 \
            and mp["qu_t"].shape[-1] % p.tp_size() == 0:
        # merged groups are all column-parallel (QKV / gate-up): the
        # group stacking stays shard-local and each device computes its
        # padded-Nmax output shard; the per-projection :n slices below
        # read the global (sharded) result.
        ax = p.tp_axis
        yg = _shard_launch(
            p, local,
            (P(None, None, None), P(None, None, None), P(None, None, ax),
             P(None, ax), P(None, None), P(None, None)),
            P(None, None, ax),
            x2, mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], rmask)
    if yg is None:
        yg = local(x2, mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], rmask, p)
    return [yg[i, :, :n].reshape(*shape[:-1], n)
            for i, n in enumerate(dims)]


def _local_merged(x2, qv, qu_t, s1, s2, rmask, p: KernelPolicy,
                  eff_rank=None):
    """Single-device grouped launch shared by the plain and shard_map
    paths (x2: (1, M, K) shared input; operands carry the group axis).
    eff_rank truncates every group to its leading min(eff_rank, true
    rank) components — the rmask already zeros past each group's true
    rank, so truncation just caps the shared padded rank R."""
    R = qv.shape[-1]
    if p.use_pallas() and p.fused and R <= binary_matmul.MAX_FUSED_RANK:
        M = x2.shape[1]
        bm, bn, bk = p.block_sizes(M, x2.shape[-1], qu_t.shape[-1],
                                   eff_rank or R, x2.dtype)
        return binary_matmul.fused_lowrank_matmul_grouped(
            x2, qv, qu_t, s1, s2, rmask, x_shared=True,
            bm=bm, bn=bn, bk=bk, eff_rank=eff_rank,
            interpret=p.resolve_interpret())
    return jax.vmap(
        lambda v, u, a, b, rm: ref.lowrank_binary_matmul_fused_ref(
            x2[0], v, u, a, b, rm, eff_rank=eff_rank),
    )(qv, qu_t, s1, s2, rmask)


def lowrank_binary_matmul_expert(x, qv, qu_t, s1, s2,
                                 policy: Optional[KernelPolicy] = None,
                                 eff_rank: Optional[int] = None):
    """Stacked-expert NanoQuant linear: x (E, C, d_in) with per-expert
    packed operands (E, ...). On the fused pallas path the expert axis
    becomes a kernel grid dimension (one launch for all experts) instead
    of a host-level vmap of the kernel. eff_rank truncates every
    expert's factors to the leading R' components (all experts share one
    packed rank)."""
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, qv)
    local = functools.partial(_local_expert, eff_rank=eff_rank) \
        if eff_rank is not None else _local_expert
    if p.tp_size() > 1 and qv.ndim == 3 and x.shape[0] % p.tp_size() == 0:
        # expert-parallel: the expert grid dim shards over the TP axis,
        # each device launching the fused grid over its local experts.
        ax = p.tp_axis
        return _shard_launch(
            p, local,
            (P(ax, None, None), P(ax, None, None), P(ax, None, None),
             P(ax, None), P(ax, None)),
            P(ax, None, None), x, qv, qu_t, s1, s2)
    return local(x, qv, qu_t, s1, s2, p)


def _local_expert(x, qv, qu_t, s1, s2, p: KernelPolicy, eff_rank=None):
    r = qv.shape[-1]
    if p.use_pallas():
        interp = p.resolve_interpret()
        bm, bn, bk = p.block_sizes(x.shape[1], x.shape[-1],
                                   qu_t.shape[-1], eff_rank or r, x.dtype)
        if p.fused and r <= binary_matmul.MAX_FUSED_RANK:
            return binary_matmul.fused_lowrank_matmul_grouped(
                x, qv, qu_t, s1, s2, x_shared=False,
                bm=bm, bn=bn, bk=bk, eff_rank=eff_rank, interpret=interp)
        if eff_rank is not None:
            qv, qu_t = _slice_rank(qv, qu_t, eff_rank)
        return jax.vmap(
            lambda xe, v, u, a, b: binary_matmul.lowrank_binary_matmul_twocall(
                xe, v, u, a, b, bm=bm, bn=bn, bk=bk, interpret=interp)
        )(x, qv, qu_t, s1, s2)
    if eff_rank is not None:
        qv, qu_t = _slice_rank(qv, qu_t, eff_rank)
    return jax.vmap(ref.lowrank_binary_matmul_ref)(x, qv, qu_t, s1, s2)


def paged_attention(q, k_pool, v_pool, block_table, q_pos, cache_pos, *,
                    window: int = 0, scale: float = 1.0,
                    policy: Optional[KernelPolicy] = None):
    """Block-table decode attention over a paged KV pool (serve.paging).

    q: (B, S, Hq, D) — S == 1 for normal decode, S > 1 for the
    speculative multi-token verify forward (token j lives at position
    q_pos + j / cache row cache_pos + j, all S rows already written);
    k_pool / v_pool: (n_pages, page_size, Hkv, D);
    block_table: (B, pages) int32; q_pos / cache_pos: (B,) — see
    :func:`repro.kernels.ref.paged_attention_ref` for the full
    contract (linear caches pass cache_pos == q_pos; sliding-window
    ring pools pass q_pos wrapped modulo the virtual ring).

    Dispatch per `policy`: the Pallas gather kernel
    (:mod:`repro.kernels.paged_attention`) on the pallas path, the
    gather + rectangle-mask oracle otherwise. With a tensor-parallel
    mesh the pool arrives kv-head-sharded (``sharding.rules.
    cache_pspecs(paged=True)``) and the launch shard_maps over the
    head dim — each device attends over its local heads with no
    collective (GQA groups stay shard-aligned because Hq and Hkv
    divide the axis together); non-divisible head counts fall back to
    the replicated single-device launch, mirroring the placement
    fallback."""
    p = policy if policy is not None else current_kernel_policy()
    n = p.tp_size()
    if n > 1 and k_pool.shape[-2] % n == 0 and q.shape[-2] % n == 0:
        ax = p.tp_axis
        lp = dataclasses.replace(p, mesh=None)

        def body(q_, kp_, vp_, bt_, qp_, cp_):
            return _local_paged_attention(q_, kp_, vp_, bt_, qp_, cp_,
                                          window, scale, lp)

        from repro.sharding.rules import shard_map_compat
        return shard_map_compat(
            body, p.mesh,
            in_specs=(P(None, None, ax, None), P(None, None, ax, None),
                      P(None, None, ax, None), P(None, None), P(None),
                      P(None)),
            out_specs=P(None, None, ax, None))(
                q, k_pool, v_pool, block_table, q_pos, cache_pos)
    return _local_paged_attention(q, k_pool, v_pool, block_table, q_pos,
                                  cache_pos, window, scale, p)


def _local_paged_attention(q, k_pool, v_pool, bt, q_pos, cache_pos,
                           window, scale, p: KernelPolicy):
    if p.use_pallas():
        from repro.kernels import paged_attention as pa
        S = q.shape[1]
        ppb, hb = p.paged_block_sizes(q.shape[0], k_pool.shape[2],
                                      k_pool.shape[3], bt.shape[1])
        if S == 1:
            return pa.paged_decode_attention(
                q, k_pool, v_pool, bt, q_pos, cache_pos, window=window,
                scale=scale, pages_per_step=ppb, head_block=hb,
                interpret=p.resolve_interpret())
        # multi-token verify: all S rows are in the pool before any
        # query reads, and the per-query position reconstruction masks
        # later-written rows (see ref.paged_attention_ref), so S
        # single-token kernel launches at shifted positions are exact.
        outs = [pa.paged_decode_attention(
            q[:, j:j + 1], k_pool, v_pool, bt, q_pos + j, cache_pos + j,
            window=window, scale=scale, pages_per_step=ppb, head_block=hb,
            interpret=p.resolve_interpret())
            for j in range(S)]
        return jnp.concatenate(outs, axis=1)
    return ref.paged_attention_ref(q, k_pool, v_pool, bt, q_pos, cache_pos,
                                   window=window, scale=scale)


def decode_step_megakernel(x, mqkv, wo, k_pool, v_pool, block_table,
                           q_pos, cache_pos, *, head_dim: int,
                           dims: Sequence[int], theta: float,
                           scale: float, window: int = 0,
                           policy: Optional[KernelPolicy] = None,
                           eff_rank: Optional[int] = None,
                           eff_rank_o: Optional[int] = None):
    """Whole decode step in one pallas_call: merged-QKV packed matmul →
    RoPE → paged attention (fresh-KV entry folded in-kernel) → packed
    output projection (:mod:`repro.kernels.megakernel`).

    Returns ``(y, k_new, v_new)`` — k_new/v_new are the current token's
    post-RoPE KV rows (pool dtype) for the caller's paged cache write —
    or **None** when the launch does not qualify, in which case the
    caller runs the unfused chain (projections → cache write →
    paged_attention → wo); the two paths are online-softmax-equal (see
    tests/test_kernel_diff.py). Non-qualifying launches: ref-mode /
    unfused / unmerged policies, megakernel=False, tensor-parallel
    meshes (the merged padded-Nmax layout is not head-aligned, so a TP
    shard cannot slice its q/k/v heads locally — the unfused chain's
    per-role shard_map launches handle TP), ranks past MAX_FUSED_RANK,
    and non-32-multiple eff_rank truncations.

    x: (B, K) one decode token per slot; mqkv / wo: packed merged-QKV /
    output-projection param dicts; dims: (Hq*D, Hkv*D).
    """
    p = policy if policy is not None else current_kernel_policy()
    if not p.use_megakernel() or p.tp_size() > 1:
        return None
    if mqkv["qv"].ndim != 3 or wo["qv"].ndim != 2:
        return None
    if mqkv["qv"].shape[-1] > binary_matmul.MAX_FUSED_RANK \
            or wo["qv"].shape[-1] > binary_matmul.MAX_FUSED_RANK:
        return None
    for r_eff, qv in ((eff_rank, mqkv["qv"]), (eff_rank_o, wo["qv"])):
        if r_eff is not None and not (
                0 < r_eff <= qv.shape[-1] and r_eff % 32 == 0):
            return None
    from repro.kernels import megakernel as mk
    x = _match_packed_k(x, mqkv["qv"])
    ppb, _ = p.paged_block_sizes(x.shape[0], k_pool.shape[2],
                                 k_pool.shape[3], block_table.shape[1])
    _, _, bk = p.block_sizes(x.shape[0], x.shape[-1],
                             mqkv["qu_t"].shape[-1],
                             eff_rank or mqkv["qv"].shape[-1], x.dtype)
    return mk.decode_step_megakernel_raw(
        x, mqkv, wo, k_pool, v_pool, block_table, q_pos, cache_pos,
        dims=tuple(dims), head_dim=head_dim, theta=theta, scale=scale,
        window=window, eff_rank=eff_rank, eff_rank_o=eff_rank_o,
        pages_per_step=ppb, bk=bk, interpret=p.resolve_interpret())


# ---------------------------------------------------------------------------
# deprecated process-global mode API (pre-KernelPolicy)
# ---------------------------------------------------------------------------

_SHIM_WARNED = set()


def _warn_once(name: str) -> None:
    if name in _SHIM_WARNED:
        return
    _SHIM_WARNED.add(name)
    warnings.warn(f"{name} is deprecated; use "
                  f"{'set_kernel_policy' if 'set' in name else 'kernel_policy'}",
                  DeprecationWarning, stacklevel=3)


def set_kernel_mode(mode: str) -> None:
    """Deprecated: use ``set_kernel_policy(KernelPolicy(mode=...))``."""
    _warn_once("set_kernel_mode")
    set_kernel_policy(KernelPolicy(mode=mode))


@contextlib.contextmanager
def kernel_mode(mode: str):
    """Deprecated: use ``kernel_policy(mode)``."""
    _warn_once("kernel_mode")
    with kernel_policy(mode):
        yield


pack_signs = ref.pack_signs
unpack_signs = ref.unpack_signs
