"""Jit'd public wrappers for the binary kernels.

The model stack calls :func:`lowrank_binary_matmul`; execution mode is a
process-global policy:

- ``"ref"``   — pure-jnp oracle. Lowerable on every backend and under any
  pjit sharding, so it is the default for CPU runs and the multi-pod
  dry-run (XLA SPMD partitions it like any matmul chain).
- ``"pallas"`` — the Pallas TPU kernel (interpret=True off-TPU), for real
  deployments and kernel validation.
- ``"auto"``  — pallas on TPU backends, ref elsewhere.
"""
from __future__ import annotations

import contextlib

import jax

from repro.kernels import binary_matmul, ref

_MODE = ["auto"]


def set_kernel_mode(mode: str) -> None:
    assert mode in ("auto", "ref", "pallas")
    _MODE[0] = mode


@contextlib.contextmanager
def kernel_mode(mode: str):
    prev = _MODE[0]
    set_kernel_mode(mode)
    try:
        yield
    finally:
        _MODE[0] = prev


def _use_pallas() -> bool:
    mode = _MODE[0]
    if mode == "pallas":
        return True
    if mode == "ref":
        return False
    return jax.default_backend() == "tpu"


def lowrank_binary_matmul(x, qv, qu_t, s1, s2):
    """y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ  — packed operands (paper Eq. 1)."""
    if _use_pallas():
        interp = jax.default_backend() != "tpu"
        return binary_matmul.lowrank_binary_matmul_pallas(
            x, qv, qu_t, s1, s2, interpret=interp)
    return ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)


pack_signs = ref.pack_signs
unpack_signs = ref.unpack_signs
