"""Jit'd public wrappers for the binary kernels.

The model stack calls :func:`lowrank_binary_matmul` (plus the merged
multi-projection and stacked-expert entry points below); execution is
governed by an explicit, immutable :class:`KernelPolicy`:

- ``mode="ref"``    — pure-jnp oracle. Lowerable on every backend and
  under any pjit sharding, so it is the right choice for CPU runs and
  the multi-pod dry-run (XLA SPMD partitions it like any matmul chain).
- ``mode="pallas"`` — the Pallas TPU kernels (interpret mode off-TPU),
  for real deployments and kernel validation.
- ``mode="auto"``   — pallas on TPU backends, ref elsewhere.

On the pallas path, ``fused=True`` (default) runs the whole low-rank
chain as ONE kernel with the rank-r intermediate held in VMEM
(:func:`repro.kernels.binary_matmul.fused_lowrank_matmul`);
``fused=False`` keeps the legacy two-``pallas_call`` form.
``merge_projections=True`` additionally lets the model layer batch
projections that share an input (QKV, gate/up) into a single grouped
kernel launch. Block sizes come from a heuristic table keyed on
(M, K, N, r) — see :mod:`repro.kernels.tuning` — overridable per policy
via ``block_table=`` (rows from ``tuning.load_block_table``).

A policy can be threaded explicitly (``lowrank_binary_matmul(...,
policy=p)``), installed for a scope (``with kernel_policy(p): ...``), or
set process-wide (:func:`set_kernel_policy`). The scoped form restores
the previous policy on exit and is contextvar-based, so concurrent
threads / asyncio tasks do not trample each other.

``set_kernel_mode`` / ``kernel_mode`` are deprecated shims over the old
mutable process-global mode list; each warns exactly once per process.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import warnings
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels import binary_matmul, ref, tuning

_MODES = ("auto", "ref", "pallas")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Execution policy for the packed binary matmul.

    interpret: run the Pallas kernel in interpreter mode. ``None``
    resolves at call time to "interpret unless on a real TPU backend".
    fused: single-pass kernel (VMEM-resident rank intermediate) vs the
    legacy two-call chain. merge_projections: allow grouped QKV /
    gate-up launches. block_table: optional tuple of
    ``(m_hi, k_hi, n_hi, r_hi, bm, bn, bk)`` rows (first match wins)
    replacing the built-in heuristic table — typically produced by the
    offline sweep (``python -m benchmarks.kernel_bench --sweep``)
    and loaded with :func:`repro.kernels.tuning.load_block_table`.
    """
    mode: str = "auto"
    interpret: Optional[bool] = None
    fused: bool = True
    merge_projections: bool = True
    block_table: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"unknown kernel mode {self.mode!r}; choose from {_MODES}")
        if self.block_table is not None:
            object.__setattr__(self, "block_table",
                               tuple(tuple(r) for r in self.block_table))

    def use_pallas(self) -> bool:
        if self.mode == "auto":
            return jax.default_backend() == "tpu"
        return self.mode == "pallas"

    def resolve_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret

    def use_merged_projections(self) -> bool:
        """Whether the model layer should issue grouped QKV / gate-up
        kernel calls (requires the fused pallas path)."""
        return self.use_pallas() and self.fused and self.merge_projections

    def block_sizes(self, M: int, K: int, N: int, r: int,
                    dtype=jnp.float32) -> Tuple[int, int, int]:
        """(bm, bn, bk) for one call, from the heuristic table fitted to
        the concrete shape (divisor tiles — no weight padding)."""
        return tuning.fit_block_sizes(M, K, N, r, dtype, self.block_table)


# Scoped overrides live in a ContextVar (thread/async-local); the
# process-wide default lives in a plain module global so that
# set_kernel_policy is visible from every thread (new threads start with
# a fresh contextvars.Context and would miss a ContextVar-only set).
_DEFAULT_POLICY = [KernelPolicy()]
_POLICY: contextvars.ContextVar[Optional[KernelPolicy]] = \
    contextvars.ContextVar("nanoquant_kernel_policy", default=None)


def current_kernel_policy() -> KernelPolicy:
    scoped = _POLICY.get()
    return scoped if scoped is not None else _DEFAULT_POLICY[0]


def set_kernel_policy(policy: KernelPolicy) -> KernelPolicy:
    """Install `policy` process-wide (all threads); returns the previous
    default. Scoped `kernel_policy(...)` overrides still win."""
    prev = _DEFAULT_POLICY[0]
    _DEFAULT_POLICY[0] = _coerce(policy)
    return prev


def _coerce(policy: Union[KernelPolicy, str]) -> KernelPolicy:
    if isinstance(policy, str):
        return KernelPolicy(mode=policy)
    return policy


@contextlib.contextmanager
def kernel_policy(policy: Union[KernelPolicy, str]):
    """Scoped policy override (this thread/task only); restores the
    prior policy on exit."""
    token = _POLICY.set(_coerce(policy))
    try:
        yield current_kernel_policy()
    finally:
        _POLICY.reset(token)


def _match_packed_k(x, qv):
    """Zero-pad x's feature dim up to the packed operand's K. Stored
    operands may be K-aligned past the activation width (surgery packs
    them tile-aligned); the padded s2 columns are zero so the extra
    columns contribute nothing."""
    Kw = qv.shape[-2] * 32
    d = x.shape[-1]
    if Kw == d:
        return x
    assert Kw > d, (qv.shape, x.shape)
    pad = [(0, 0)] * (x.ndim - 1) + [(0, Kw - d)]
    return jnp.pad(x, pad)


def lowrank_binary_matmul(x, qv, qu_t, s1, s2,
                          policy: Optional[KernelPolicy] = None):
    """y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ  — packed operands (paper Eq. 1).

    Dispatches per `policy` (explicit argument wins, else the active
    contextvar policy)."""
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, qv)
    if p.use_pallas():
        r = qv.shape[-1]
        M = x.size // x.shape[-1]
        bm, bn, bk = p.block_sizes(M, x.shape[-1], qu_t.shape[-1], r,
                                   x.dtype)
        interp = p.resolve_interpret()
        if p.fused and r <= binary_matmul.MAX_FUSED_RANK:
            return binary_matmul.fused_lowrank_matmul(
                x, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk, interpret=interp)
        return binary_matmul.lowrank_binary_matmul_twocall(
            x, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk, interpret=interp)
    return ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)


def lowrank_binary_matmul_merged(x, mp, dims: Sequence[int],
                                 policy: Optional[KernelPolicy] = None):
    """Grouped projections sharing one input (QKV / gate-up): ONE kernel
    launch instead of len(dims).

    mp: merged param dict from ``quant.surgery.merge_projection_groups``
    — ``qv`` (P, K//32, R), ``qu_t`` (P, R//32, Nmax), ``s1`` (P, Nmax),
    ``s2`` (P, K), ``rmask`` (P, R) (every projection padded to the
    widest rank R / output Nmax; padded s1 columns are 0 and rmask zeros
    the padded rank columns). dims: static true d_out per projection.
    Returns a list of per-projection outputs (..., dims[i]).

    There is no two-call form of the merged launch (merging exists to
    eliminate launches): when the policy disables the fused pallas path
    the fallback is the grouped jnp oracle. The model layer only routes
    here when ``policy.use_merged_projections()`` is true, so a
    ``fused=False`` pallas policy runs per-projection two-call kernels
    via :func:`lowrank_binary_matmul` instead.
    """
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, mp["qv"])
    shape = x.shape
    x2 = x.reshape(1, -1, shape[-1])
    R = mp["qv"].shape[-1]
    rmask = mp.get("rmask")
    if p.use_pallas() and p.fused and R <= binary_matmul.MAX_FUSED_RANK:
        M = x2.shape[1]
        bm, bn, bk = p.block_sizes(M, shape[-1], mp["qu_t"].shape[-1], R,
                                   x.dtype)
        yg = binary_matmul.fused_lowrank_matmul_grouped(
            x2, mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], rmask,
            x_shared=True, bm=bm, bn=bn, bk=bk,
            interpret=p.resolve_interpret())
    else:
        yg = jax.vmap(
            lambda qv, qu, s1, s2, rm: ref.lowrank_binary_matmul_fused_ref(
                x2[0], qv, qu, s1, s2, rm),
        )(mp["qv"], mp["qu_t"], mp["s1"], mp["s2"],
          rmask if rmask is not None
          else jnp.ones((mp["qv"].shape[0], R), jnp.float32))
    return [yg[i, :, :n].reshape(*shape[:-1], n)
            for i, n in enumerate(dims)]


def lowrank_binary_matmul_expert(x, qv, qu_t, s1, s2,
                                 policy: Optional[KernelPolicy] = None):
    """Stacked-expert NanoQuant linear: x (E, C, d_in) with per-expert
    packed operands (E, ...). On the fused pallas path the expert axis
    becomes a kernel grid dimension (one launch for all experts) instead
    of a host-level vmap of the kernel."""
    p = policy if policy is not None else current_kernel_policy()
    x = _match_packed_k(x, qv)
    r = qv.shape[-1]
    if p.use_pallas():
        interp = p.resolve_interpret()
        bm, bn, bk = p.block_sizes(x.shape[1], x.shape[-1],
                                   qu_t.shape[-1], r, x.dtype)
        if p.fused and r <= binary_matmul.MAX_FUSED_RANK:
            return binary_matmul.fused_lowrank_matmul_grouped(
                x, qv, qu_t, s1, s2, x_shared=False,
                bm=bm, bn=bn, bk=bk, interpret=interp)
        return jax.vmap(
            lambda xe, v, u, a, b: binary_matmul.lowrank_binary_matmul_twocall(
                xe, v, u, a, b, bm=bm, bn=bn, bk=bk, interpret=interp)
        )(x, qv, qu_t, s1, s2)
    return jax.vmap(ref.lowrank_binary_matmul_ref)(x, qv, qu_t, s1, s2)


# ---------------------------------------------------------------------------
# deprecated process-global mode API (pre-KernelPolicy)
# ---------------------------------------------------------------------------

_SHIM_WARNED = set()


def _warn_once(name: str) -> None:
    if name in _SHIM_WARNED:
        return
    _SHIM_WARNED.add(name)
    warnings.warn(f"{name} is deprecated; use "
                  f"{'set_kernel_policy' if 'set' in name else 'kernel_policy'}",
                  DeprecationWarning, stacklevel=3)


def set_kernel_mode(mode: str) -> None:
    """Deprecated: use ``set_kernel_policy(KernelPolicy(mode=...))``."""
    _warn_once("set_kernel_mode")
    set_kernel_policy(KernelPolicy(mode=mode))


@contextlib.contextmanager
def kernel_mode(mode: str):
    """Deprecated: use ``kernel_policy(mode)``."""
    _warn_once("kernel_mode")
    with kernel_policy(mode):
        yield


pack_signs = ref.pack_signs
unpack_signs = ref.unpack_signs
