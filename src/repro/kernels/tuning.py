"""Block-size selection for the packed binary matmul + paged kernels.

Two layers per kernel family:

- :data:`DEFAULT_BLOCK_TABLE` — a shape-class heuristic table keyed on
  (M, K, N, r) upper bounds, seeded from an offline sweep
  (``python -m benchmarks.kernel_bench --sweep``) and overridable
  per :class:`~repro.kernels.ops.KernelPolicy` (``block_table=...``).
- :func:`fit_block_sizes` — fits the table's *preferred* tile sizes to a
  concrete shape so that the K and N tiles **divide** the operand dims
  whenever they are pack-aligned. This is what lets ``packed_matmul`` /
  the fused kernel skip call-time padding of the packed weights: a
  divisor tile means zero pad ops traced into the jitted decode step
  (the old code padded K up to a fixed bk=512 multiple, copying the
  whole packed tensor once per token for shapes like d_ff=2816).

A shape no table row covers is never silently given the generic prefill
tile: :func:`lookup_block_table` falls back to shape-derived preferred
tiles (which :func:`fit_block_sizes` then divisor-fits as usual) and
warns ONCE per shape class so untuned decode shapes surface in logs
instead of shipping a padded GEMV.

The paged gather-attention kernel has its own knob table
(:data:`DEFAULT_PAGED_TABLE` / :func:`fit_paged_block_sizes`): how many
block-table pages one grid step walks (``pages_per_step`` — wider steps
amortize grid overhead and coalesce the block-table DMA) and the
kv-head tile (``head_block`` — 0 keeps all heads in one block; a
divisor of Hkv splits the online-softmax state across a head grid
dimension for large-head models).

Table rows are plain tuples so a :class:`KernelPolicy` carrying one
stays an immutable value type: ``(m_hi, k_hi, n_hi, r_hi, bm, bn, bk)``
(matmul) / ``(b_hi, hkv_hi, d_hi, pages_hi, pages_per_step,
head_block)`` (paged); first row whose bounds cover the shape wins.
"""
from __future__ import annotations

import json
import warnings
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# sign bits are packed 32-per-word along K; K tiles must stay multiples
# of 32 so a tile maps to whole uint32 rows of the packed operand.
PACK_ALIGN = 32

# (m_hi, k_hi, n_hi, r_hi, bm, bn, bk) — seeded by the offline sweep in
# benchmarks/kernel_wallclock.py (--sweep); ordered decode -> prefill.
# Decode rows keep bm at the dtype sublane so a (B,) slot batch becomes
# a single MXU row tile instead of being padded to 128; wide-N rows
# stream more output columns per packed-tile unpack.
DEFAULT_BLOCK_TABLE: Tuple[Tuple[int, ...], ...] = (
    # decode / GEMV: tiny M, stream weights in wide tiles
    (16, 4096, 100_000, 1024, 8, 512, 512),
    (16, 100_000, 100_000, 100_000, 8, 256, 512),
    # small-batch decode (continuous-batching slot pools)
    (64, 100_000, 100_000, 100_000, 64, 256, 512),
    # prefill / training: square MXU tiles
    (100_000, 100_000, 100_000, 100_000, 128, 128, 512),
)

# (b_hi, hkv_hi, d_hi, pages_hi, pages_per_step, head_block) — knobs for
# the paged gather-attention decode kernel. pages_per_step > 1 walks
# several block-table pages per grid step (the block table is scalar-
# prefetched once, so the per-page index maps coalesce into one DMA
# burst per step); head_block 0 = all kv heads in one block (small-Hkv
# serving shapes), a divisor of Hkv adds a kv-head grid dimension.
DEFAULT_PAGED_TABLE: Tuple[Tuple[int, ...], ...] = (
    # shallow tables (tiny pools / smoke shapes): pair up pages
    (100_000, 100_000, 100_000, 4, 2, 0),
    # serving-depth tables: walk four pages per step
    (100_000, 8, 100_000, 100_000, 4, 0),
    # many kv heads: tile the online-softmax state across heads too
    (100_000, 100_000, 100_000, 100_000, 4, 8),
)


def _sublane(dtype) -> int:
    """Minimum sublane count for the activation dtype (f32: 8, bf16: 16)."""
    try:
        if jnp.dtype(dtype).itemsize <= 2:
            return 16
    except TypeError:
        pass
    return 8


def _divisor_tile(dim: int, pref: int, align: int) -> int:
    """Largest multiple of `align` that divides `dim` and is <= `pref`;
    0 when `dim` has no aligned divisor (caller falls back to padding)."""
    if dim % align:
        return 0
    best = 0
    d = align
    while d <= min(pref, dim):
        if dim % d == 0:
            best = d
        d += align
    return best


# shape classes that already warned a table miss (once per process per
# class — the decode loop calls block_sizes per trace, not per token,
# but even per-trace repeats would drown logs).
_MISS_WARNED: set = set()


def _miss_tiles(M: int, K: int, N: int, r: int) -> Tuple[int, int, int]:
    """Shape-derived preferred tiles for a shape no table row covers:
    decode-sized M keeps the sublane M tile (GEMV row, never padded to
    128), wide weights stream in wide N tiles. fit_block_sizes then
    divisor-fits K/N exactly like a table hit."""
    if M <= 16:
        return 8, 512 if N >= 512 else 256, 512
    if M <= 64:
        return 64, 256, 512
    return 128, 128, 512


def lookup_block_table(M: int, K: int, N: int, r: int,
                       table: Optional[Sequence[Tuple[int, ...]]] = None
                       ) -> Tuple[int, int, int]:
    """Preferred (bm, bn, bk) for a shape class, before shape fitting.
    A custom (swept) table that covers none of the shape's bounds falls
    through to the built-in heuristic table — a sweep run on small
    shapes must not degrade untuned production shapes. A shape NO table
    covers gets shape-derived tiles plus a one-time warning (it should
    be added to the sweep, see ``kernel_bench --sweep``)."""
    tables = [table, DEFAULT_BLOCK_TABLE] if table else [DEFAULT_BLOCK_TABLE]
    for t in tables:
        for m_hi, k_hi, n_hi, r_hi, bm, bn, bk in t:
            if M <= m_hi and K <= k_hi and N <= n_hi and r <= r_hi:
                return bm, bn, bk
    cls = ("matmul", M <= 16, M <= 64, K, N)
    if cls not in _MISS_WARNED:
        _MISS_WARNED.add(cls)
        warnings.warn(
            f"kernels.tuning: no block-table row covers shape "
            f"(M={M}, K={K}, N={N}, r={r}); using divisor-fitted "
            f"fallback tiles. Re-run `python -m benchmarks.kernel_bench "
            f"--sweep --commit-table` to tune this shape.",
            stacklevel=3)
    return _miss_tiles(M, K, N, r)


def fit_block_sizes(M: int, K: int, N: int, r: int, dtype=jnp.float32,
                    table: Optional[Sequence[Tuple[int, ...]]] = None
                    ) -> Tuple[int, int, int]:
    """Concrete (bm, bn, bk) for one kernel call.

    K/N tiles are fitted to divisors of the operand dims whenever the
    dim is pack-aligned, so the packed weights are never padded at call
    time; the M tile covers the (small) activation batch rounded to the
    dtype sublane. Only a dim with no aligned divisor (e.g. an N not a
    multiple of 8; K is always 32-aligned by packing) falls back to the
    preferred tile with call-time padding.
    """
    bm_p, bn_p, bk_p = lookup_block_table(M, K, N, r, table)
    sub = _sublane(dtype)
    bm = min(max(bm_p, sub), -(-M // sub) * sub)
    bk = _divisor_tile(K, bk_p, PACK_ALIGN) or min(bk_p, K)
    bn = _divisor_tile(N, bn_p, 8) or min(bn_p, N)
    return bm, bn, bk


def fit_paged_block_sizes(B: int, Hkv: int, D: int, pages: int,
                          table: Optional[Sequence[Tuple[int, ...]]] = None
                          ) -> Tuple[int, int]:
    """Concrete (pages_per_step, head_block) for one paged-attention
    launch. pages_per_step is clamped to the table depth (the launch
    pads the block table with null-page entries up to a multiple, so
    any value is *correct* — the clamp just avoids walking pure
    padding); head_block is snapped down to a divisor of Hkv (0 = no
    head tiling)."""
    tables = [table, DEFAULT_PAGED_TABLE] if table else [DEFAULT_PAGED_TABLE]
    ppb, hb = 1, 0
    for t in tables:
        hit = False
        for b_hi, h_hi, d_hi, p_hi, p_ppb, p_hb in t:
            if B <= b_hi and Hkv <= h_hi and D <= d_hi and pages <= p_hi:
                ppb, hb, hit = p_ppb, p_hb, True
                break
        if hit:
            break
    else:
        cls = ("paged", Hkv, D, pages <= 4)
        if cls not in _MISS_WARNED:
            _MISS_WARNED.add(cls)
            warnings.warn(
                f"kernels.tuning: no paged-table row covers shape "
                f"(B={B}, Hkv={Hkv}, D={D}, pages={pages}); using "
                f"defaults (pages_per_step=2). Re-run `python -m "
                f"benchmarks.kernel_bench --sweep --commit-table`.",
                stacklevel=3)
        ppb, hb = 2, 0
    ppb = max(1, min(int(ppb), pages))
    hb = int(hb)
    if hb:
        while hb > 1 and Hkv % hb:
            hb -= 1
        if hb <= 1 or hb >= Hkv:
            hb = 0
    return ppb, hb


def _matmul_rows(rows) -> Tuple[Tuple[int, ...], ...]:
    return tuple((int(r["m_hi"]), int(r["k_hi"]), int(r["n_hi"]),
                  int(r["r_hi"]), int(r["bm"]), int(r["bn"]), int(r["bk"]))
                 for r in rows)


def load_block_table(path: str) -> Tuple[Tuple[int, ...], ...]:
    """Parse a swept block table (``python -m benchmarks.kernel_bench
    --sweep``) into the tuple-of-rows form
    `KernelPolicy(block_table=...)` takes. Accepts both the legacy bare
    row list and the committed ``{"meta":..., "matmul":..., "paged":...}``
    envelope (``--commit-table``); this returns the matmul rows — use
    :func:`load_paged_table` for the paged-kernel rows."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        rows = doc.get("matmul", doc.get("rows", []))
    else:
        rows = doc
    return _matmul_rows(rows)


def load_paged_table(path: str) -> Optional[Tuple[Tuple[int, ...], ...]]:
    """Paged-kernel rows of a committed swept table
    (``kernel_bench --sweep --commit-table``), or None for legacy
    matmul-only artifacts."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "paged" not in doc:
        return None
    return tuple((int(r["b_hi"]), int(r["hkv_hi"]), int(r["d_hi"]),
                  int(r["pages_hi"]), int(r["pages_per_step"]),
                  int(r["head_block"])) for r in doc["paged"])
