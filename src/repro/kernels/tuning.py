"""Block-size selection for the packed binary matmul kernels.

Two layers:

- :data:`DEFAULT_BLOCK_TABLE` — a shape-class heuristic table keyed on
  (M, K, N, r) upper bounds, seeded from an offline sweep
  (``python -m benchmarks.kernel_bench --sweep``) and overridable
  per :class:`~repro.kernels.ops.KernelPolicy` (``block_table=...``).
- :func:`fit_block_sizes` — fits the table's *preferred* tile sizes to a
  concrete shape so that the K and N tiles **divide** the operand dims
  whenever they are pack-aligned. This is what lets ``packed_matmul`` /
  the fused kernel skip call-time padding of the packed weights: a
  divisor tile means zero pad ops traced into the jitted decode step
  (the old code padded K up to a fixed bk=512 multiple, copying the
  whole packed tensor once per token for shapes like d_ff=2816).

Table rows are plain tuples so a :class:`KernelPolicy` carrying one
stays an immutable value type: ``(m_hi, k_hi, n_hi, r_hi, bm, bn, bk)``,
first row whose bounds cover the shape wins.
"""
from __future__ import annotations

import json
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# sign bits are packed 32-per-word along K; K tiles must stay multiples
# of 32 so a tile maps to whole uint32 rows of the packed operand.
PACK_ALIGN = 32

# (m_hi, k_hi, n_hi, r_hi, bm, bn, bk) — seeded by the offline sweep in
# benchmarks/kernel_wallclock.py (--sweep); ordered decode -> prefill.
# Decode rows keep bm at the dtype sublane so a (B,) slot batch becomes
# a single MXU row tile instead of being padded to 128; wide-N rows
# stream more output columns per packed-tile unpack.
DEFAULT_BLOCK_TABLE: Tuple[Tuple[int, ...], ...] = (
    # decode / GEMV: tiny M, stream weights in wide tiles
    (16, 4096, 100_000, 1024, 8, 512, 512),
    (16, 100_000, 100_000, 100_000, 8, 256, 512),
    # small-batch decode (continuous-batching slot pools)
    (64, 100_000, 100_000, 100_000, 64, 256, 512),
    # prefill / training: square MXU tiles
    (100_000, 100_000, 100_000, 100_000, 128, 128, 512),
)


def _sublane(dtype) -> int:
    """Minimum sublane count for the activation dtype (f32: 8, bf16: 16)."""
    try:
        if jnp.dtype(dtype).itemsize <= 2:
            return 16
    except TypeError:
        pass
    return 8


def _divisor_tile(dim: int, pref: int, align: int) -> int:
    """Largest multiple of `align` that divides `dim` and is <= `pref`;
    0 when `dim` has no aligned divisor (caller falls back to padding)."""
    if dim % align:
        return 0
    best = 0
    d = align
    while d <= min(pref, dim):
        if dim % d == 0:
            best = d
        d += align
    return best


def lookup_block_table(M: int, K: int, N: int, r: int,
                       table: Optional[Sequence[Tuple[int, ...]]] = None
                       ) -> Tuple[int, int, int]:
    """Preferred (bm, bn, bk) for a shape class, before shape fitting.
    A custom (swept) table that covers none of the shape's bounds falls
    through to the built-in heuristic table — a sweep run on small
    shapes must not degrade untuned production shapes."""
    tables = [table, DEFAULT_BLOCK_TABLE] if table else [DEFAULT_BLOCK_TABLE]
    for t in tables:
        for m_hi, k_hi, n_hi, r_hi, bm, bn, bk in t:
            if M <= m_hi and K <= k_hi and N <= n_hi and r <= r_hi:
                return bm, bn, bk
    return 128, 128, 512


def fit_block_sizes(M: int, K: int, N: int, r: int, dtype=jnp.float32,
                    table: Optional[Sequence[Tuple[int, ...]]] = None
                    ) -> Tuple[int, int, int]:
    """Concrete (bm, bn, bk) for one kernel call.

    K/N tiles are fitted to divisors of the operand dims whenever the
    dim is pack-aligned, so the packed weights are never padded at call
    time; the M tile covers the (small) activation batch rounded to the
    dtype sublane. Only a dim with no aligned divisor (e.g. an N not a
    multiple of 8; K is always 32-aligned by packing) falls back to the
    preferred tile with call-time padding.
    """
    bm_p, bn_p, bk_p = lookup_block_table(M, K, N, r, table)
    sub = _sublane(dtype)
    bm = min(max(bm_p, sub), -(-M // sub) * sub)
    bk = _divisor_tile(K, bk_p, PACK_ALIGN) or min(bk_p, K)
    bn = _divisor_tile(N, bn_p, 8) or min(bn_p, N)
    return bm, bn, bk


def load_block_table(path: str) -> Tuple[Tuple[int, ...], ...]:
    """Parse a swept block table (``python -m benchmarks.kernel_bench
    --sweep``) into the tuple-of-rows form
    `KernelPolicy(block_table=...)` takes."""
    with open(path) as f:
        rows = json.load(f)
    out = []
    for row in rows:
        out.append((int(row["m_hi"]), int(row["k_hi"]), int(row["n_hi"]),
                    int(row["r_hi"]), int(row["bm"]), int(row["bn"]),
                    int(row["bk"])))
    return tuple(out)
