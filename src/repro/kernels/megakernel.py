"""Fused decode-step megakernel: QKV projection → paged attention → wo.

ONE ``pallas_call`` runs a whole attention decode step for a batch of
slots: the merged-QKV packed low-rank matmul (the PR-3 fused kernel's
math), in-register RoPE, the block-table page walk with an online
softmax, the current token's fresh-KV softmax entry, and the packed
output projection. Neither the rank-r intermediate, nor q/k/v, nor the
attention output ever round-trips HBM — the only HBM traffic is the
packed weights (streamed once), the mapped KV pages, and the three
outputs (y, plus the fresh k/v row for the caller's paged cache write).

Grid layout: ``(B, 1 + n_steps + 1)`` — the inner axis is a *phase*
axis, mirroring the K-then-N phase split of the fused matmul kernel:

- phase 0: merged QKV. For each of the three projection groups the
  packed V/Uᵀ tiles stream through VMEM (unpacked once each, K tiles
  then N tiles), the rank-r intermediate lives in registers, and the
  rmask zeros padded rank columns. RoPE is applied to q and the fresh
  k from the scalar-prefetched position; q/k/v land in VMEM scratch
  and k/v are also written to the fresh-row outputs.
- phases 1..n_steps: the widened page walk of
  :mod:`repro.kernels.paged_attention` (``pages_per_step`` pages per
  phase, coalesced block-table DMA, online-softmax carry). The pool
  row this token will overwrite (virtual row == cache_pos) is
  EXCLUDED — the pool has not been written yet at read time — and the
  fresh k/v scratch supplies that entry instead.
- final phase: fold the fresh-KV entry into the online softmax,
  normalize, and run the packed wo projection on the attention output
  while it is still in VMEM.

Weight/scale operands use constant index maps, so each is DMA'd into
VMEM exactly once per launch regardless of batch; ``eff_rank`` /
``eff_rank_o`` truncate the QKV and wo launches to the leading rank
components via BlockSpec sub-extents (zero-copy, exactly like the
fused matmul kernel — the speculative draft pass composes for free).

Intermediate roundings match the unfused chain: projection outputs
round to the activation dtype, fresh k/v round to the pool dtype
before scoring (what writing them to the pool and reading them back
does), scores and accumulators are f32. The oracle is
:func:`repro.kernels.ref.decode_step_ref`; qualifying-shape gating and
the clean fallback to the unfused chain live in
:func:`repro.kernels.ops.decode_step_megakernel` (see docs/kernels.md
§Decode megakernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.binary_matmul import _unpack_tile

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _rope_rows(h, pos, theta):
    """Rotate-half RoPE on (H, D) rows at a single traced position."""
    d = h.shape[-1]
    half = jax.lax.broadcasted_iota(jnp.float32, (1, d // 2), 1)
    inv = 1.0 / (theta ** (2.0 * half / d))              # (1, D/2)
    ang = pos.astype(jnp.float32) * inv
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    hf = h.astype(jnp.float32)
    x1, x2 = hf[:, : d // 2], hf[:, d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def _stage1(x_row, qv_ref, sel, r_eff, bk):
    """(1, K) ⊙ s2 @ V±1 with K-tiled unpack -> (1, r_eff) f32."""
    n_k = x_row.shape[1] // bk
    acc = jnp.zeros((1, r_eff), jnp.float32)
    for kt in range(n_k):
        v = _unpack_tile(qv_ref[sel + (pl.ds(kt * (bk // 32), bk // 32),
                                       slice(None))], bk)
        acc += jnp.dot(x_row[:, kt * bk:(kt + 1) * bk], v,
                       preferred_element_type=jnp.float32)
    return acc


def _stage2(t_acc, qu_ref, s1_ref, sel, n, r_eff, bn):
    """(1, r_eff) @ Uᵀ±1 ⊙ s1 with N-tiled unpack -> (1, n) f32."""
    ys = []
    for nt in range(n // bn):
        u = _unpack_tile(qu_ref[sel + (slice(None),
                                       pl.ds(nt * bn, bn))], r_eff)
        ys.append(jnp.dot(t_acc, u, preferred_element_type=jnp.float32)
                  * s1_ref[sel + (pl.ds(nt * bn, bn),)
                           ].astype(jnp.float32)[None])
    return jnp.concatenate(ys, axis=1)


def _kernel(bt_ref, qpos_ref, cpos_ref, x_ref, qv3_ref, qu3_ref, s23_ref,
            s13_ref, rm3_ref, qvo_ref, quo_ref, s2o_ref, s1o_ref, *rest,
            dims, head_dim, pages, page_size, window, scale, theta,
            ppb, n_steps, r_eff, ro_eff, bk, bn, bko, bno):
    kv_refs = rest[:2 * ppb]
    (y_ref, kn_ref, vn_ref, q_s, k_s, v_s, m_ref, l_ref,
     acc_ref) = rest[2 * ppb:]
    b = pl.program_id(0)
    t = pl.program_id(1)
    nq, nkv = dims
    hq, hkv = nq // head_dim, nkv // head_dim
    g_rep = hq // hkv
    x_dtype = x_ref.dtype

    @pl.when(t == 0)
    def _qkv():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        pos = qpos_ref[b]
        outs = []
        for g, n in enumerate((nq, nkv, nkv)):
            xg = (x_ref[0].astype(jnp.float32)
                  * s23_ref[g].astype(jnp.float32))[None]      # (1, K)
            t_acc = _stage1(xg, qv3_ref, (g,), r_eff, bk)
            t_acc = t_acc * rm3_ref[g].astype(jnp.float32)[None]
            y_g = _stage2(t_acc, qu3_ref, s13_ref, (g,),
                          s13_ref.shape[-1], r_eff, bn)
            # round to the activation dtype — the unfused chain's
            # projection output dtype — before RoPE/scoring.
            outs.append(y_g[0, :n].astype(x_dtype))
        q = _rope_rows(outs[0].reshape(hq, head_dim), pos, theta)
        q_s[...] = q.astype(x_dtype).astype(jnp.float32)
        k = _rope_rows(outs[1].reshape(hkv, head_dim), pos, theta)
        kn_ref[0] = k.astype(x_dtype).astype(kn_ref.dtype)
        k_s[...] = kn_ref[0].astype(jnp.float32)
        vn_ref[0] = outs[2].reshape(hkv, head_dim).astype(vn_ref.dtype)
        v_s[...] = vn_ref[0].astype(jnp.float32)

    @pl.when(jnp.logical_and(t >= 1, t <= n_steps))
    def _walk():
        qg = q_s[...].reshape(hkv, g_rep, head_dim)
        rows = pages * page_size
        for i in range(ppb):
            k = kv_refs[2 * i][0].astype(jnp.float32)    # (PS, Hkv, D)
            v = kv_refs[2 * i + 1][0].astype(jnp.float32)
            s = jax.lax.dot_general(                     # (Hkv, G, PS)
                qg, k, (((2,), (2,)), ((0,), (1,))),
                preferred_element_type=jnp.float32) * scale
            p_idx = (t - 1) * ppb + i
            r = p_idx * page_size + jax.lax.broadcasted_iota(
                jnp.int32, (1, 1, page_size), 2)
            abs_pos = qpos_ref[b] - (cpos_ref[b] - r) % rows
            # r == cache_pos is the row THIS token overwrites — stale
            # at read time; the fresh-KV scratch supplies that entry in
            # the final phase instead.
            msk = (abs_pos >= 0) & (p_idx < pages) & (r != cpos_ref[b])
            if window:
                msk = jnp.logical_and(msk, abs_pos > qpos_ref[b] - window)
            s = jnp.where(msk, s, -1e30)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            pexp = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
            l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
            acc_ref[...] = (acc_ref[...] * alpha[..., None]
                            + jax.lax.dot_general(
                                pexp, v, (((2,), (0,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32))
            m_ref[...] = m_new

    @pl.when(t == n_steps + 1)
    def _finish():
        # fresh-KV softmax entry at abs_pos == q_pos (always in-window)
        qg = q_s[...].reshape(hkv, g_rep, head_dim)
        s_new = (qg * k_s[...][:, None, :]).sum(-1) * scale  # (Hkv, G)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s_new)
        alpha = jnp.exp(m_prev - m_new)
        p_new = jnp.exp(s_new - m_new)
        l = l_ref[...] * alpha + p_new
        acc = (acc_ref[...] * alpha[..., None]
               + p_new[..., None] * v_s[...][:, None, :])
        o = acc / jnp.maximum(l, 1e-30)[..., None]       # (Hkv, G, D)
        # wo while the attention output is still in VMEM
        ko = s2o_ref.shape[-1]
        xo = o.reshape(1, nq).astype(x_dtype).astype(jnp.float32)
        if ko != nq:
            xo = jnp.pad(xo, ((0, 0), (0, ko - nq)))
        xo = xo * s2o_ref[0].astype(jnp.float32)[None]
        t_o = _stage1(xo, qvo_ref, (0,), ro_eff, bko)
        y = _stage2(t_o, quo_ref, s1o_ref, (0,), s1o_ref.shape[-1],
                    ro_eff, bno)
        y_ref[0] = y[0].astype(y_ref.dtype)


def decode_step_megakernel_raw(x, mqkv, wo, k_pool, v_pool, block_table,
                               q_pos, cache_pos, *, dims, head_dim,
                               theta, scale, window=0, eff_rank=None,
                               eff_rank_o=None, pages_per_step=1,
                               bk=512, bn=512, interpret=False):
    """Launch the decode-step megakernel (no qualification gating — use
    :func:`repro.kernels.ops.decode_step_megakernel` from model code).

    x: (B, K) one decode token per slot, K matched to the packed QKV
    operand; mqkv / wo: packed param dicts (merged layout / single
    projection); dims: (Hq*D, Hkv*D). Returns (y (B, d_model),
    k_new (B, Hkv, D), v_new (B, Hkv, D)) — fresh k/v are post-RoPE in
    the pool dtype for the caller's paged cache write.
    """
    from repro.kernels import tuning
    B, K = x.shape
    nq, nkv = dims
    hq, hkv = nq // head_dim, nkv // head_dim
    NP, PS, Hkv_p, D_p = k_pool.shape
    assert (Hkv_p, D_p) == (hkv, head_dim), (k_pool.shape, dims)
    pages = block_table.shape[1]
    R = mqkv["qv"].shape[-1]
    Nmax = mqkv["qu_t"].shape[-1]
    Ro = wo["qv"].shape[-1]
    No = wo["qu_t"].shape[-1]
    Ko = wo["qv"].shape[0] * 32
    assert mqkv["qv"].shape[1] * 32 == K, (mqkv["qv"].shape, K)

    r_eff = int(eff_rank) if eff_rank else R
    ro_eff = int(eff_rank_o) if eff_rank_o else Ro
    assert 0 < r_eff <= R and r_eff % 32 == 0, (r_eff, R)
    assert 0 < ro_eff <= Ro and ro_eff % 32 == 0, (ro_eff, Ro)
    rmask = mqkv.get("rmask")
    if rmask is None:
        rmask = jnp.ones((3, R), jnp.float32)

    bk = tuning._divisor_tile(K, bk, 32) or K
    bn_q = tuning._divisor_tile(Nmax, bn, 8) or Nmax
    bko = tuning._divisor_tile(Ko, bk, 32) or Ko
    bno = tuning._divisor_tile(No, bn, 8) or No

    ppb = max(1, min(int(pages_per_step), pages))
    npad = -(-pages // ppb) * ppb
    bt = block_table.astype(jnp.int32)
    if npad != pages:
        bt = jnp.pad(bt, ((0, 0), (0, npad - pages)))
    n_steps = npad // ppb
    T = n_steps + 2

    def _kv_map(i):
        def f(b, t, bt_, qp, cp):
            in_walk = jnp.logical_and(t >= 1, t <= n_steps)
            p = jnp.clip((t - 1) * ppb + i, 0, npad - 1)
            return (jnp.where(in_walk, bt_[b, p], 0), 0, 0, 0)
        return f

    kv_specs = []
    for i in range(ppb):
        kv_specs.append(pl.BlockSpec((1, PS, hkv, head_dim), _kv_map(i)))
        kv_specs.append(pl.BlockSpec((1, PS, hkv, head_dim), _kv_map(i)))

    const = lambda *ix: (lambda b, t, bt_, qp, cp: ix)   # noqa: E731

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, K), lambda b, t, bt_, qp, cp: (b, 0)),
            # rank sub-extents: eff_rank truncation without repacking
            pl.BlockSpec((3, K // 32, r_eff), const(0, 0, 0)),
            pl.BlockSpec((3, r_eff // 32, Nmax), const(0, 0, 0)),
            pl.BlockSpec((3, K), const(0, 0)),
            pl.BlockSpec((3, Nmax), const(0, 0)),
            pl.BlockSpec((3, r_eff), const(0, 0)),
            pl.BlockSpec((1, Ko // 32, ro_eff), const(0, 0, 0)),
            pl.BlockSpec((1, ro_eff // 32, No), const(0, 0, 0)),
            pl.BlockSpec((1, Ko), const(0, 0)),
            pl.BlockSpec((1, No), const(0, 0)),
            *kv_specs,
        ],
        out_specs=[
            pl.BlockSpec((1, No), lambda b, t, bt_, qp, cp: (b, 0)),
            pl.BlockSpec((1, hkv, head_dim),
                         lambda b, t, bt_, qp, cp: (b, 0, 0)),
            pl.BlockSpec((1, hkv, head_dim),
                         lambda b, t, bt_, qp, cp: (b, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hq, head_dim), jnp.float32),     # roped q
            pltpu.VMEM((hkv, head_dim), jnp.float32),    # fresh k
            pltpu.VMEM((hkv, head_dim), jnp.float32),    # fresh v
            pltpu.VMEM((hkv, hq // hkv), jnp.float32),   # running max
            pltpu.VMEM((hkv, hq // hkv), jnp.float32),   # running sum
            pltpu.VMEM((hkv, hq // hkv, head_dim), jnp.float32),
        ],
    )
    y, k_new, v_new = pl.pallas_call(
        functools.partial(
            _kernel, dims=(nq, nkv), head_dim=head_dim, pages=pages,
            page_size=PS, window=int(window), scale=float(scale),
            theta=float(theta), ppb=ppb, n_steps=n_steps, r_eff=r_eff,
            ro_eff=ro_eff, bk=bk, bn=bn_q, bko=bko, bno=bno),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, No), x.dtype),
            jax.ShapeDtypeStruct((B, hkv, head_dim), k_pool.dtype),
            jax.ShapeDtypeStruct((B, hkv, head_dim), v_pool.dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(bt, q_pos.astype(jnp.int32), cache_pos.astype(jnp.int32),
      x, mqkv["qv"], mqkv["qu_t"], mqkv["s2"], mqkv["s1"],
      rmask.astype(jnp.float32), wo["qv"][None], wo["qu_t"][None],
      wo["s2"].reshape(1, Ko), wo["s1"].reshape(1, No),
      *([k_pool, v_pool] * ppb))
    return y, k_new, v_new
