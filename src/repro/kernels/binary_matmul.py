"""Pallas TPU kernel: packed-binary matmul with fused channel scales.

TPU-native adaptation of the paper's binary CUDA GEMV/GEMM (App. E): the
±1 factor matrix stays bit-packed (uint32) in HBM; each grid step streams a
(bk//32, bn) packed tile into VMEM, expands it to ±1 with a vectorized
shift/mask (VPU), and feeds the MXU matmul. The f32 accumulator lives in a
VMEM scratch tile across the K grid dimension; input-side (s_k) and
output-side (s_n) channel scales are fused so the low-rank chain
``y = s1 ⊙ ((x ⊙ s2) @ V) @ Uᵀ`` is exactly two pallas_calls with no
intermediate HBM round-trip of unpacked weights.

GEMV (decode) is the same kernel with a single block-row grid: unlike the
paper's CUDA GEMV (which deliberately avoids tensor cores), TPU has no
scalar-core bypass — the MXU is always the right unit, so one kernel serves
both regimes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, w_ref, sk_ref, sn_ref, o_ref, acc_ref, *, n_k: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...]                                  # (bk//32, bn) uint32
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    w = (bits.astype(jnp.float32) * 2.0 - 1.0).reshape(bk, -1)

    x = x_ref[...].astype(jnp.float32) * sk_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * sn_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def packed_matmul(x, packed_w, s_k=None, s_n=None, *,
                  bm: int = 128, bn: int = 128, bk: int = 512,
                  interpret: bool = False):
    """y = (x ⊙ s_k) @ unpack(packed_w) ⊙ s_n.

    x: (M, K) float; packed_w: (K//32, N) uint32; s_k: (K,); s_n: (N,).
    M is padded to bm internally; K and N must be multiples of 32 and are
    padded to bk / bn.
    """
    M, K = x.shape
    N = packed_w.shape[1]
    assert packed_w.shape[0] * 32 == K

    if s_k is None:
        s_k = jnp.ones((K,), jnp.float32)
    if s_n is None:
        s_n = jnp.ones((N,), jnp.float32)

    bm = min(bm, max(8, M))
    bk = min(bk, K)
    bn = min(bn, N)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // bn) * bn
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        packed_w = jnp.pad(packed_w, ((0, (Kp - K) // 32), (0, 0)))
        s_k = jnp.pad(s_k, (0, Kp - K))
    if Np != N:
        packed_w = jnp.pad(packed_w, ((0, 0), (0, Np - N)))
        s_n = jnp.pad(s_n, (0, Np - N))
    # note: padded packed words are 0 => unpack to -1, but padded s_k/x rows
    # are 0 so they contribute 0 to the accumulator. Padded N columns are
    # sliced off below.

    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk
    sk2 = s_k.reshape(1, Kp)
    sn2 = s_n.reshape(1, Np)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bk=bk),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 32, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, packed_w, sk2, sn2)
    return out[:M, :N]


def lowrank_binary_matmul_pallas(x, qv, qu_t, s1, s2, *, interpret=False,
                                 bm=128, bn=128, bk=512):
    """Two-stage NanoQuant linear, both stages as packed-matmul kernels."""
    shape = x.shape
    d_in = shape[-1]
    x2 = x.reshape(-1, d_in)
    t = packed_matmul(x2, qv, s_k=s2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    y = packed_matmul(t, qu_t, s_n=s1, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y.reshape(*shape[:-1], y.shape[-1])
