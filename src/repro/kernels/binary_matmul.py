"""Pallas TPU kernels: packed-binary matmul with fused channel scales.

TPU-native adaptation of the paper's binary CUDA GEMV/GEMM (App. E): the
±1 factor matrices stay bit-packed (uint32) in HBM; each grid step
streams a packed tile into VMEM, expands it to ±1 with a vectorized
shift/mask (VPU), and feeds the MXU matmul.

Two execution strategies for the low-rank chain
``y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ``:

- :func:`fused_lowrank_matmul` (default) — ONE ``pallas_call``. The
  grid is (group, M-tiles, K-tiles ++ N-tiles): the inner grid dim first
  sweeps K accumulating the stage-1 reduction ``(x ⊙ s2) @ V`` into a
  ``(bm, r)`` f32 VMEM scratch (rank r ≪ d_in, so the whole rank
  dimension fits in one block), then sweeps N consuming that scratch
  for stage 2 ``t @ Uᵀ ⊙ s1`` — the rank-r intermediate never touches
  HBM and every packed tile is unpacked exactly once per M-tile.
  The leading *group* grid dimension serves double duty: merged
  multi-projection calls (QKV / gate-up share x, one kernel instead of
  three/two dispatches) and stacked-expert calls (the expert axis is a
  grid dimension instead of a host-level vmap of the kernel).
- :func:`lowrank_binary_matmul_twocall` — the legacy two-``pallas_call``
  form (stage 1 writes t to HBM, stage 2 re-reads it per output tile);
  kept as the baseline `benchmarks/kernel_wallclock.py` races against
  and as a fallback for ranks too large for a single VMEM block.

GEMV (decode) is the same fused kernel with sublane-sized M tiles:
unlike the paper's CUDA GEMV (which deliberately avoids tensor cores),
TPU has no scalar-core bypass — the MXU is always the right unit, so
one kernel serves both regimes; block sizes come from
:mod:`repro.kernels.tuning`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

# ranks above this don't fit a single VMEM block comfortably alongside
# the unpacked stage-1 tile; fall back to the two-call kernel.
MAX_FUSED_RANK = 4096


def _unpack_tile(packed, rows):
    """(rows//32, cols) uint32 -> (rows, cols) ±1 f32 (VPU shift/mask)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    return (bits.astype(jnp.float32) * 2.0 - 1.0).reshape(rows, -1)


# ===========================================================================
# two-call building block (legacy path + wallclock baseline)
# ===========================================================================


def _kernel(x_ref, w_ref, sk_ref, sn_ref, o_ref, acc_ref, *, n_k: int, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_tile(w_ref[...], bk)                     # (bk, bn)
    x = x_ref[...].astype(jnp.float32) * sk_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * sn_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def packed_matmul(x, packed_w, s_k=None, s_n=None, *,
                  bm: int = 128, bn: int = 128, bk: int = 512,
                  interpret: bool = False):
    """y = (x ⊙ s_k) @ unpack(packed_w) ⊙ s_n.

    x: (M, K) float; packed_w: (K//32, N) uint32; s_k: (K,); s_n: (N,).
    bm/bn/bk are preferred tiles: K and N tiles are re-fitted to
    divisors of the operand dims when possible (see kernels.tuning), so
    pack-aligned operands are never padded at call time.
    """
    from repro.kernels import tuning
    M, K = x.shape
    N = packed_w.shape[1]
    assert packed_w.shape[0] * 32 == K

    if s_k is None:
        s_k = jnp.ones((K,), jnp.float32)
    if s_n is None:
        s_n = jnp.ones((N,), jnp.float32)

    bm = min(bm, max(8, M))
    bk = tuning._divisor_tile(K, bk, 32) or min(bk, K)
    bn = tuning._divisor_tile(N, bn, 8) or min(bn, N)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // bn) * bn
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, Kp - K)))
        packed_w = jnp.pad(packed_w, ((0, (Kp - K) // 32), (0, 0)))
        s_k = jnp.pad(s_k, (0, Kp - K))
    if Np != N:
        packed_w = jnp.pad(packed_w, ((0, 0), (0, Np - N)))
        s_n = jnp.pad(s_n, (0, Np - N))
    # note: padded packed words are 0 => unpack to -1, but padded s_k/x rows
    # are 0 so they contribute 0 to the accumulator. Padded N columns are
    # sliced off below.

    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk
    sk2 = s_k.reshape(1, Kp)
    sn2 = s_n.reshape(1, Np)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bk=bk),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 32, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, packed_w, sk2, sn2)
    return out[:M, :N]


def lowrank_binary_matmul_twocall(x, qv, qu_t, s1, s2, *, interpret=False,
                                  bm=128, bn=128, bk=512):
    """Two-stage NanoQuant linear, both stages as packed-matmul kernels
    with the rank-r intermediate round-tripping HBM (pre-fusion path)."""
    shape = x.shape
    d_in = shape[-1]
    x2 = x.reshape(-1, d_in)
    t = packed_matmul(x2, qv, s_k=s2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    y = packed_matmul(t, qu_t, s_n=s1, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y.reshape(*shape[:-1], y.shape[-1])


# deprecated alias (pre-fusion public name)
lowrank_binary_matmul_pallas = lowrank_binary_matmul_twocall


# ===========================================================================
# fused single-pass kernel (grouped: merged projections / stacked experts)
# ===========================================================================


def _fused_kernel(x_ref, qv_ref, qu_ref, s2_ref, s1_ref, rm_ref, o_ref,
                  acc_ref, *, n_k: int, bk: int, r: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < n_k)
    def _stage1():
        v = _unpack_tile(qv_ref[0], bk)                  # (bk, r)
        x = x_ref[0].astype(jnp.float32) * s2_ref[0].astype(jnp.float32)
        acc_ref[...] += jnp.dot(x, v, preferred_element_type=jnp.float32)

    @pl.when(s >= n_k)
    def _stage2():
        u = _unpack_tile(qu_ref[0], r)                   # (r, bn)
        t = acc_ref[...] * rm_ref[0].astype(jnp.float32)
        o_ref[0] = (jnp.dot(t, u, preferred_element_type=jnp.float32)
                    * s1_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


def fused_lowrank_matmul_grouped(xg, qv_g, qu_g, s1_g, s2_g, rmask_g=None, *,
                                 x_shared: bool = False, bm: int = 128,
                                 bn: int = 128, bk: int = 512,
                                 eff_rank: int | None = None,
                                 interpret: bool = False):
    """One fused pass over G grouped low-rank binary linears.

    xg:      (Gx, M, K)  — Gx == 1 with ``x_shared`` (merged projections
             reading the same activations) else Gx == G (stacked experts).
    qv_g:    (G, K//32, R) packed V; qu_g: (G, R//32, N) packed Uᵀ.
    s1_g:    (G, N); s2_g: (G, K); rmask_g: (G, R) f32 zeroing rank
             columns past a group's true rank (merged groups pad every
             projection to the widest rank; None => all ranks real).
    eff_rank: optional effective rank R' <= R (multiple of 32). The
             launch then reads only the leading R' rank columns of the
             FULL packed operands via BlockSpec sub-extents — the HBM
             arrays are untouched (zero-copy rank truncation for the
             speculative draft pass, see serve.speculative). Components
             past R' are simply never streamed into VMEM, so the result
             equals the full launch with a ``arange(R) < R'`` rmask.

    Returns (G, M, N) in xg.dtype. Stage-1 accumulates into a (bm, R)
    VMEM scratch; stage 2 consumes it in place — no HBM traffic for the
    intermediate, one unpack per packed tile per M-tile.
    """
    Gx, M, K = xg.shape
    G, _, R = qv_g.shape
    N = qu_g.shape[2]
    assert qv_g.shape[1] * 32 == K, (qv_g.shape, K)
    assert qu_g.shape[1] * 32 == R, (qu_g.shape, R)
    assert Gx == (1 if x_shared else G)
    if eff_rank is not None:
        if not (0 < eff_rank <= R and eff_rank % 32 == 0):
            raise ValueError(
                f"eff_rank must be a multiple of 32 in (0, {R}], "
                f"got {eff_rank}")
        R_eff = int(eff_rank)
    else:
        R_eff = R
    if rmask_g is None:
        rmask_g = jnp.ones((G, R), jnp.float32)

    from repro.kernels import tuning
    bm = min(bm, max(8, M))
    bk = tuning._divisor_tile(K, bk, 32) or min(bk, K)
    bn = tuning._divisor_tile(N, bn, 8) or min(bn, N)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // bn) * bn
    if Mp != M:
        xg = jnp.pad(xg, ((0, 0), (0, Mp - M), (0, 0)))
    if Kp != K:
        # padded packed words unpack to -1 but the padded s2 columns are
        # 0, so stage 1 accumulates exactly 0 from the padding.
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, Kp - K)))
        qv_g = jnp.pad(qv_g, ((0, 0), (0, (Kp - K) // 32), (0, 0)))
        s2_g = jnp.pad(s2_g, ((0, 0), (0, Kp - K)))
    if Np != N:
        qu_g = jnp.pad(qu_g, ((0, 0), (0, 0), (0, Np - N)))
        s1_g = jnp.pad(s1_g, ((0, 0), (0, Np - N)))

    n_m, n_n, n_k = Mp // bm, Np // bn, Kp // bk
    s2_3 = s2_g.reshape(G, 1, Kp)
    s1_3 = s1_g.reshape(G, 1, Np)
    rm_3 = rmask_g.reshape(G, 1, R)

    def _k(g, i, s):
        return jnp.minimum(s, n_k - 1)

    def _j(g, i, s):
        return jnp.maximum(s - n_k, 0)

    # With eff_rank, the qv / qu_t / rmask blocks are SUB-EXTENTS of the
    # full HBM operands: block index 0 on the rank axis selects the
    # leading R_eff (or R_eff // 32 packed) entries; the trailing
    # R - R_eff components never leave HBM.
    out = pl.pallas_call(
        functools.partial(_fused_kernel, n_k=n_k, bk=bk, r=R_eff),
        grid=(G, n_m, n_k + n_n),
        in_specs=[
            pl.BlockSpec((1, bm, bk),
                         (lambda g, i, s: (0, i, _k(g, i, s))) if x_shared
                         else (lambda g, i, s: (g, i, _k(g, i, s)))),
            pl.BlockSpec((1, Kp // 32 // n_k, R_eff),
                         lambda g, i, s: (g, _k(g, i, s), 0)),
            pl.BlockSpec((1, R_eff // 32, bn),
                         lambda g, i, s: (g, 0, _j(g, i, s))),
            pl.BlockSpec((1, 1, bk), lambda g, i, s: (g, 0, _k(g, i, s))),
            pl.BlockSpec((1, 1, bn), lambda g, i, s: (g, 0, _j(g, i, s))),
            pl.BlockSpec((1, 1, R_eff), lambda g, i, s: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, s: (g, i, _j(g, i, s))),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bm, R_eff), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xg, qv_g, qu_g, s2_3, s1_3, rm_3)
    return out[:, :M, :N]


def fused_lowrank_matmul(x, qv, qu_t, s1, s2, *, interpret=False,
                         bm=128, bn=128, bk=512, eff_rank=None):
    """Single-linear fused NanoQuant matmul: one pallas_call, the rank-r
    intermediate lives only in VMEM. x: (..., d_in) -> (..., d_out).
    ``eff_rank`` truncates the launch to the leading R' rank columns
    without touching the packed operands (see the grouped form)."""
    shape = x.shape
    x2 = x.reshape(1, -1, shape[-1])
    y = fused_lowrank_matmul_grouped(
        x2, qv[None], qu_t[None], s1[None], s2[None], x_shared=True,
        bm=bm, bn=bn, bk=bk, eff_rank=eff_rank, interpret=interpret)[0]
    return y.reshape(*shape[:-1], y.shape[-1])
