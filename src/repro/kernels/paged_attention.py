"""Pallas TPU gather-attention decode kernel over a paged KV pool.

One grid step = one (slot, logical page) pair: the block specs walk the
slot's block table — prefetched into SMEM via
``PrefetchScalarGridSpec``, so the index maps can compute each page's
pool address before the body runs — and DMA exactly the pages the slot
has mapped, instead of slicing a ``max_batch x max_len`` rectangle.
Scores accumulate across pages with an online softmax held in VMEM
scratch (flash-attention style), so the slot's virtual rectangle is
never materialized in HBM or VMEM.

Masking is the rectangular decode-mask math on virtual row indices:
row ``r = page*page_size + offset`` last held absolute position
``q_pos - ((cache_pos - r) mod rows)`` (negative = never written;
``window`` masks past the sliding window) — which makes the same
kernel serve linear caches (``cache_pos == q_pos``) and the hybrid
family's sliding-window ring (``cache_pos == q_pos mod rows``).
Unmapped block-table entries point at the null page 0 and mask out
because their virtual rows sit past every valid position.

Numerics are validated against :func:`repro.kernels.ref.
paged_attention_ref` on the CPU interpreter (tests/test_paging.py);
block/scratch shapes have not been swept on real TPU hardware yet —
that rides the existing ROADMAP block-table-sweep item. The MLA decode
path gathers pages in plain XLA instead (its absorbed-latent scoring
is a dense matmul chain, not a GQA read — see docs/kernels.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(bt_ref, qpos_ref, cpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, pages: int, page_size: int,
            window: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (Hq, D)
    k = k_ref[0].astype(jnp.float32)                     # (PS, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    hq, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(hkv, hq // hkv, d)                    # (Hkv, G, D)
    s = jax.lax.dot_general(                             # (Hkv, G, PS)
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale

    # virtual-row validity (see module docstring)
    rows = pages * page_size
    r = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, page_size), 2)
    abs_pos = qpos_ref[b] - (cpos_ref[b] - r) % rows
    msk = abs_pos >= 0
    if window:
        msk = jnp.logical_and(msk, abs_pos > qpos_ref[b] - window)
    s = jnp.where(msk, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        pexp, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pages - 1)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = o.reshape(hq, d).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_table, q_pos,
                           cache_pos, *, window: int = 0,
                           scale: float = 1.0, interpret: bool = False):
    """Block-table decode attention (one pallas_call).

    q: (B, 1, Hq, D); k_pool / v_pool: (n_pages, page_size, Hkv, D);
    block_table: (B, pages) int32; q_pos / cache_pos: (B,) int32 (see
    :func:`repro.kernels.ref.paged_attention_ref` for the contract).
    Returns (B, 1, Hq, D) in q.dtype.
    """
    B, S, Hq, D = q.shape
    assert S == 1, "paged attention is a single-token decode read"
    NP, PS, Hkv, Dk = k_pool.shape
    assert Dk == D and Hq % Hkv == 0, (q.shape, k_pool.shape)
    pages = block_table.shape[1]
    G = Hq // Hkv

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, pages),
        in_specs=[
            pl.BlockSpec((1, 1, Hq, D),
                         lambda b, j, bt, qp, cp: (b, 0, 0, 0)),
            pl.BlockSpec((1, PS, Hkv, D),
                         lambda b, j, bt, qp, cp: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, PS, Hkv, D),
                         lambda b, j, bt, qp, cp: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hq, D),
                               lambda b, j, bt, qp, cp: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G), jnp.float32),           # running max
            pltpu.VMEM((Hkv, G), jnp.float32),           # running sum
            pltpu.VMEM((Hkv, G, D), jnp.float32),        # output acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, pages=pages, page_size=PS,
                          window=int(window), scale=float(scale)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), q_pos.astype(jnp.int32),
      cache_pos.astype(jnp.int32), q, k_pool, v_pool)
