"""Pallas TPU gather-attention decode kernel over a paged KV pool.

One grid step = one (slot, kv-head tile, page *group*): the block specs
walk the slot's block table — prefetched into SMEM via
``PrefetchScalarGridSpec``, so the index maps can compute each page's
pool address before the body runs — and DMA exactly the pages the slot
has mapped, instead of slicing a ``max_batch x max_len`` rectangle.
Scores accumulate across page groups with an online softmax held in
VMEM scratch (flash-attention style), so the slot's virtual rectangle
is never materialized in HBM or VMEM.

Two tuning knobs (``kernels.tuning.fit_paged_block_sizes``):

- ``pages_per_step`` — pages walked per grid step. Each page of a group
  is a separate BlockSpec over the same pool operand, so the group's
  page DMAs are issued together off one scalar-prefetched block-table
  read (coalesced) and the per-step grid overhead amortizes across the
  group. The block table is padded with null-page entries up to a
  multiple; padded entries mask out.
- ``head_block`` — kv-head tile (0 = all heads in one block). A divisor
  of Hkv adds a head grid dimension with per-tile online-softmax
  scratch, for models whose (Hkv, G, D) state would crowd VMEM.

Masking is the rectangular decode-mask math on virtual row indices:
row ``r = page*page_size + offset`` last held absolute position
``q_pos - ((cache_pos - r) mod rows)`` (negative = never written;
``window`` masks past the sliding window) — which makes the same
kernel serve linear caches (``cache_pos == q_pos``) and the hybrid
family's sliding-window ring (``cache_pos == q_pos mod rows``).
Unmapped block-table entries point at the null page 0 and mask out
because their virtual rows sit past every valid position; padded
table entries sit past the virtual rectangle entirely and are masked
explicitly.

Numerics are validated against :func:`repro.kernels.ref.
paged_attention_ref` on the CPU interpreter (tests/test_paging.py and
the differential fuzz suite, tests/test_kernel_diff.py). The MLA decode
path gathers pages in plain XLA instead (its absorbed-latent scoring
is a dense matmul chain, not a GQA read — see docs/kernels.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _online_update(s, msk, v, m_ref, l_ref, acc_ref):
    """One online-softmax step: fold scores ``s`` (Hb, G, R) with mask
    ``msk`` and values ``v`` (R, Hb, D) into the running (m, l, acc)
    scratch."""
    s = jnp.where(msk, s, -1e30)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        pexp, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel(bt_ref, qpos_ref, cpos_ref, q_ref, *rest, pages: int,
            page_size: int, window: int, scale: float, ppb: int,
            n_steps: int):
    kv_refs = rest[:2 * ppb]
    o_ref, m_ref, l_ref, acc_ref = rest[2 * ppb:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (Hb*G, D)
    hqb, d = q.shape
    hb = kv_refs[0].shape[2]
    qg = q.reshape(hb, hqb // hb, d)                     # (Hb, G, D)
    rows = pages * page_size

    # the group's pages arrive as ppb separate VMEM blocks whose DMAs
    # were all issued from this step's block-table prefetch; the online
    # softmax carries across the widened page axis within the step.
    for i in range(ppb):
        k = kv_refs[2 * i][0].astype(jnp.float32)        # (PS, Hb, D)
        v = kv_refs[2 * i + 1][0].astype(jnp.float32)
        s = jax.lax.dot_general(                         # (Hb, G, PS)
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale

        # virtual-row validity (see module docstring)
        p_idx = j * ppb + i
        r = p_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        abs_pos = qpos_ref[b] - (cpos_ref[b] - r) % rows
        msk = jnp.logical_and(abs_pos >= 0, p_idx < pages)
        if window:
            msk = jnp.logical_and(msk, abs_pos > qpos_ref[b] - window)
        _online_update(s, msk, v, m_ref, l_ref, acc_ref)

    @pl.when(j == n_steps - 1)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = o.reshape(hqb, d).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_table, q_pos,
                           cache_pos, *, window: int = 0,
                           scale: float = 1.0, pages_per_step: int = 1,
                           head_block: int = 0, interpret: bool = False):
    """Block-table decode attention (one pallas_call).

    q: (B, 1, Hq, D); k_pool / v_pool: (n_pages, page_size, Hkv, D);
    block_table: (B, pages) int32; q_pos / cache_pos: (B,) int32 (see
    :func:`repro.kernels.ref.paged_attention_ref` for the contract).
    pages_per_step / head_block: tuning knobs (see module docstring;
    ``kernels.tuning.fit_paged_block_sizes`` picks them from the paged
    heuristic table). Returns (B, 1, Hq, D) in q.dtype.
    """
    B, S, Hq, D = q.shape
    assert S == 1, "paged attention is a single-token decode read"
    NP, PS, Hkv, Dk = k_pool.shape
    assert Dk == D and Hq % Hkv == 0, (q.shape, k_pool.shape)
    pages = block_table.shape[1]
    G = Hq // Hkv

    ppb = max(1, min(int(pages_per_step), pages))
    hb = int(head_block) or Hkv
    if Hkv % hb:
        hb = Hkv
    n_h = Hkv // hb

    # pad the block table with null-page entries up to a step multiple;
    # padded entries sit past the virtual rectangle and mask out.
    npad = -(-pages // ppb) * ppb
    bt = block_table.astype(jnp.int32)
    if npad != pages:
        bt = jnp.pad(bt, ((0, 0), (0, npad - pages)))
    n_steps = npad // ppb

    def _kv_map(i):
        def f(b, h, j, bt_, qp, cp):
            return (bt_[b, j * ppb + i], 0, h, 0)
        return f

    kv_specs = []
    for i in range(ppb):
        kv_specs.append(pl.BlockSpec((1, PS, hb, D), _kv_map(i)))
        kv_specs.append(pl.BlockSpec((1, PS, hb, D), _kv_map(i)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_h, n_steps),
        in_specs=[
            # q heads are kv-head-major (GQA group g of kv head h is
            # head h*G+g), so a kv-head tile's queries are contiguous.
            pl.BlockSpec((1, 1, hb * G, D),
                         lambda b, h, j, bt_, qp, cp: (b, 0, h, 0)),
            *kv_specs,
        ],
        out_specs=pl.BlockSpec((1, 1, hb * G, D),
                               lambda b, h, j, bt_, qp, cp: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((hb, G), jnp.float32),            # running max
            pltpu.VMEM((hb, G), jnp.float32),            # running sum
            pltpu.VMEM((hb, G, D), jnp.float32),         # output acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, pages=pages, page_size=PS,
                          window=int(window), scale=float(scale),
                          ppb=ppb, n_steps=n_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(bt, q_pos.astype(jnp.int32), cache_pos.astype(jnp.int32),
      q, *([k_pool, v_pool] * ppb))
