"""Pure-jnp oracles for the NanoQuant binary kernels.

Packing convention (matches Fig. 2c of the paper): a ±1 matrix ``A`` of
shape (K, N) is packed along axis 0 in groups of 32 rows into a
``uint32`` array of shape (K//32, N); bit ``b`` of word ``i`` stores
``A[i*32+b] > 0`` (so -1 -> 0, +1 -> 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_signs(a: jnp.ndarray) -> jnp.ndarray:
    """(K, N) ±1/float -> (K//32, N) uint32. K must be a multiple of 32."""
    K, N = a.shape
    assert K % 32 == 0, f"pack dim {K} not a multiple of 32"
    bits = (a > 0).astype(jnp.uint32).reshape(K // 32, 32, N)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    return jax.lax.bitwise_or(
        jnp.zeros((K // 32, N), jnp.uint32), (bits << shifts).sum(axis=1).astype(jnp.uint32)
    )


def unpack_signs(packed: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """(K//32, N) uint32 -> (K, N) in {-1, +1}."""
    n32, N = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    return (bits.astype(dtype) * 2 - 1).reshape(n32 * 32, N)


def packed_matmul_ref(x, packed_w, s_k=None, s_n=None):
    """y = (x ⊙ s_k) @ unpack(packed_w) ⊙ s_n.  x: (..., K).

    The ±1 matrix is unpacked in the *compute* dtype (bf16 for bf16
    activations — ±1 is exact in any float format) with an f32
    accumulator, halving the HBM footprint of the unpacked weights on
    the SPMD dry-run path. (On TPU the Pallas kernel unpacks in VMEM and
    this matters only for the lowered reference path.)"""
    wdt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) \
        and jnp.dtype(x.dtype).itemsize <= 2 else jnp.float32
    w = unpack_signs(packed_w, wdt)
    xf = x
    if s_k is not None:
        xf = xf * s_k.astype(x.dtype)
    y = jax.lax.dot_general(
        xf, w, (((xf.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if s_n is not None:
        y = y * s_n.astype(jnp.float32)
    return y.astype(x.dtype)


def lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2):
    """NanoQuant linear (paper Eq. 1):  y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ.

    x: (..., d_in); qv: packed V (d_in//32, r); qu_t: packed Uᵀ (r//32, d_out);
    s1: (d_out,); s2: (d_in,).

    Two-stage form: the rank-r intermediate is rounded to the activation
    dtype between stages, mirroring the pre-fusion two-kernel execution.
    """
    t = packed_matmul_ref(x, qv, s_k=s2)          # (..., r)
    return packed_matmul_ref(t, qu_t, s_n=s1)     # (..., d_out)


def paged_attention_ref(q, k_pool, v_pool, block_table, q_pos, cache_pos,
                        window=0, scale=1.0):
    """Gather-attention decode oracle over a paged KV pool (the
    pure-jax twin of :mod:`repro.kernels.paged_attention`).

    q: (B, S, Hq, D) queries (GQA: Hq = Hkv * G) — S == 1 for normal
    decode, S > 1 for the speculative verify forward (token j at
    absolute position q_pos + j, written at cache row cache_pos + j);
    k_pool / v_pool: (n_pages, page_size, Hkv, D) page pools;
    block_table: (B, pages) int32 per-slot page ids, ordered by logical
    page (unmapped tail entries point at the null page 0);
    q_pos: (B,) absolute positions of the FIRST query; cache_pos: (B,)
    cache write offsets of the first query — equal to q_pos for a
    linear cache, or q_pos wrapped modulo the virtual ring
    (pages * page_size) for a sliding-window ring pool (S == 1 only;
    multi-token callers are linear-cache, see serve.speculative
    gating). Returns (B, S, Hq, D).

    Each slot's gathered pages form a virtual rectangle whose row index
    is the row's cache position, so validity is the standard ring
    reconstruction *per query*: for query j, row r last held absolute
    position ``(q_pos + j) - ((cache_pos + j - r) mod rows)``; negative
    means never written, and `window` (when nonzero) masks positions
    past the sliding window. Rows written by LATER queries of the same
    call (all S rows land in the pool before any query reads) come out
    as ``<= q_pos + j - rows + (S - 1 - j) < 0`` whenever written
    positions stay below ``rows`` — the linear-table invariant — so
    causality between the S queries falls out of the same mask. Masked
    scores hit exact softmax underflow, so the result is bit-identical
    to attention over the rectangular cache."""
    B, S, Hq, D = q.shape
    k = jnp.take(k_pool, block_table, axis=0).reshape(
        B, -1, *k_pool.shape[2:])                       # (B, V, Hkv, D)
    v = jnp.take(v_pool, block_table, axis=0).reshape(
        B, -1, *v_pool.shape[2:])
    rows = k.shape[1]
    r = jnp.arange(rows)
    qp = q_pos[:, None] + jnp.arange(S)[None, :]        # (B, S)
    cp = cache_pos[:, None] + jnp.arange(S)[None, :]
    abs_pos = qp[:, :, None] - (cp[:, :, None] - r[None, None, :]) % rows
    m = abs_pos >= 0                                    # (B, S, V)
    if window:
        m = m & (abs_pos > qp[:, :, None] - window)
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    s = jnp.where(m[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, S, Hq, D)


def _rope_ref(x, pos, theta):
    """Rotate-half RoPE on (B, H, D) at per-slot positions (B,) — the
    pure-jnp twin of models.layers.apply_rope (f32 trig, cast back)."""
    d = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos.astype(jnp.float32)[:, None, None] * inv[None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def decode_step_ref(x, mqkv, wo, k_pool, v_pool, block_table, q_pos,
                    cache_pos, *, head_dim, dims, theta, scale,
                    window=0, eff_rank=None, eff_rank_o=None):
    """Oracle for the decode-step megakernel
    (:mod:`repro.kernels.megakernel`): merged-QKV packed matmul → RoPE
    → fresh-KV paged attention → packed output projection, composed
    from the per-kernel oracles with the same intermediate roundings as
    the unfused chain (projection outputs round to x.dtype, fresh k/v
    round to the pool dtype before scoring — exactly what writing them
    to the pool and reading them back does).

    x: (B, K) single decode token per slot; mqkv: merged param dict
    (``quant.surgery.merge_projection_groups`` layout — qv (3, K//32,
    R), qu_t (3, R//32, Nmax), s1/s2/rmask); wo: packed output
    projection dict; dims: (Hq*D, Hkv*D) true projection widths.
    Slots must not share WRITABLE pages (the batched fresh-row write
    lands in every slot whose table maps the page) — the pager
    guarantees this for live tables; prefix-cache shared pages are
    read-only and copy-on-write before any decode write.
    Returns (y (B, d_model), k_new (B, Hkv, D), v_new (B, Hkv, D)) —
    k_new/v_new are post-RoPE, in the pool dtype, for the caller's
    paged cache write.
    """
    B = x.shape[0]
    nq, nkv = dims
    hq, hkv = nq // head_dim, nkv // head_dim
    rmask = mqkv.get("rmask")
    outs = []
    for g, n in enumerate((nq, nkv, nkv)):
        y = lowrank_binary_matmul_fused_ref(
            x, mqkv["qv"][g], mqkv["qu_t"][g], mqkv["s1"][g],
            mqkv["s2"][g], None if rmask is None else rmask[g],
            eff_rank=eff_rank)
        outs.append(y[:, :n])
    q = _rope_ref(outs[0].reshape(B, hq, head_dim), q_pos, theta)
    k_new = _rope_ref(outs[1].reshape(B, hkv, head_dim), q_pos, theta)
    k_new = k_new.astype(k_pool.dtype)
    v_new = outs[2].reshape(B, hkv, head_dim).astype(v_pool.dtype)

    # write the fresh row, then attend — the unfused-chain order.
    ps = k_pool.shape[1]
    rows = block_table.shape[1] * ps
    rowv = cache_pos % rows
    page = jnp.take_along_axis(block_table, (rowv // ps)[:, None],
                               axis=1)[:, 0]
    kp = k_pool.at[page, rowv % ps].set(k_new)
    vp = v_pool.at[page, rowv % ps].set(v_new)
    o = paged_attention_ref(q[:, None], kp, vp, block_table, q_pos,
                            cache_pos, window=window, scale=scale)
    xo = o.reshape(B, nq).astype(x.dtype)
    ko = wo["qv"].shape[0] * 32          # stored K may be pack-aligned
    if ko != nq:                         # past Hq*D; padded s2 cols are 0
        xo = jnp.pad(xo, ((0, 0), (0, ko - nq)))
    y = lowrank_binary_matmul_fused_ref(
        xo, wo["qv"], wo["qu_t"], wo["s1"], wo["s2"],
        eff_rank=eff_rank_o)
    return y, k_new, v_new


def lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2, rmask=None,
                                    eff_rank=None):
    """Oracle for the *fused* kernel: the whole chain runs with an f32
    intermediate (the fused kernel keeps t in a VMEM f32 scratch, so it
    never rounds to the activation dtype between stages).

    rmask: optional (r,) f32 zeroing rank columns past the true rank —
    merged-projection calls pad every projection to the widest rank and
    mask the padding here.
    eff_rank: optional R' <= r (multiple of 32) — only the leading R'
    rank columns participate (in-trace slices; XLA reads sub-extents of
    the packed operands, no repack), mirroring the Pallas launch's
    BlockSpec sub-extents.
    """
    if eff_rank is not None:
        r_full = qv.shape[-1]
        if not (0 < eff_rank <= r_full and eff_rank % 32 == 0):
            raise ValueError(
                f"eff_rank must be a multiple of 32 in (0, {r_full}], "
                f"got {eff_rank}")
        qv = qv[..., :eff_rank]
        qu_t = qu_t[..., :eff_rank // 32, :]
        if rmask is not None:
            rmask = rmask[..., :eff_rank]
    v = unpack_signs(qv, jnp.float32)             # (d_in, r)
    u = unpack_signs(qu_t, jnp.float32)           # (r, d_out)
    xf = x.astype(jnp.float32) * s2.astype(jnp.float32)
    t = jax.lax.dot_general(
        xf, v, (((xf.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if rmask is not None:
        t = t * rmask.astype(jnp.float32)
    y = jax.lax.dot_general(
        t, u, (((t.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (y * s1.astype(jnp.float32)).astype(x.dtype)
