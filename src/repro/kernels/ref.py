"""Pure-jnp oracles for the NanoQuant binary kernels.

Packing convention (matches Fig. 2c of the paper): a ±1 matrix ``A`` of
shape (K, N) is packed along axis 0 in groups of 32 rows into a
``uint32`` array of shape (K//32, N); bit ``b`` of word ``i`` stores
``A[i*32+b] > 0`` (so -1 -> 0, +1 -> 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_signs(a: jnp.ndarray) -> jnp.ndarray:
    """(K, N) ±1/float -> (K//32, N) uint32. K must be a multiple of 32."""
    K, N = a.shape
    assert K % 32 == 0, f"pack dim {K} not a multiple of 32"
    bits = (a > 0).astype(jnp.uint32).reshape(K // 32, 32, N)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    return jax.lax.bitwise_or(
        jnp.zeros((K // 32, N), jnp.uint32), (bits << shifts).sum(axis=1).astype(jnp.uint32)
    )


def unpack_signs(packed: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """(K//32, N) uint32 -> (K, N) in {-1, +1}."""
    n32, N = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    return (bits.astype(dtype) * 2 - 1).reshape(n32 * 32, N)


def packed_matmul_ref(x, packed_w, s_k=None, s_n=None):
    """y = (x ⊙ s_k) @ unpack(packed_w) ⊙ s_n.  x: (..., K).

    The ±1 matrix is unpacked in the *compute* dtype (bf16 for bf16
    activations — ±1 is exact in any float format) with an f32
    accumulator, halving the HBM footprint of the unpacked weights on
    the SPMD dry-run path. (On TPU the Pallas kernel unpacks in VMEM and
    this matters only for the lowered reference path.)"""
    wdt = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) \
        and jnp.dtype(x.dtype).itemsize <= 2 else jnp.float32
    w = unpack_signs(packed_w, wdt)
    xf = x
    if s_k is not None:
        xf = xf * s_k.astype(x.dtype)
    y = jax.lax.dot_general(
        xf, w, (((xf.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if s_n is not None:
        y = y * s_n.astype(jnp.float32)
    return y.astype(x.dtype)


def lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2):
    """NanoQuant linear (paper Eq. 1):  y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ.

    x: (..., d_in); qv: packed V (d_in//32, r); qu_t: packed Uᵀ (r//32, d_out);
    s1: (d_out,); s2: (d_in,).

    Two-stage form: the rank-r intermediate is rounded to the activation
    dtype between stages, mirroring the pre-fusion two-kernel execution.
    """
    t = packed_matmul_ref(x, qv, s_k=s2)          # (..., r)
    return packed_matmul_ref(t, qu_t, s_n=s1)     # (..., d_out)


def lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2, rmask=None):
    """Oracle for the *fused* kernel: the whole chain runs with an f32
    intermediate (the fused kernel keeps t in a VMEM f32 scratch, so it
    never rounds to the activation dtype between stages).

    rmask: optional (r,) f32 zeroing rank columns past the true rank —
    merged-projection calls pad every projection to the widest rank and
    mask the padding here.
    """
    v = unpack_signs(qv, jnp.float32)             # (d_in, r)
    u = unpack_signs(qu_t, jnp.float32)           # (r, d_out)
    xf = x.astype(jnp.float32) * s2.astype(jnp.float32)
    t = jax.lax.dot_general(
        xf, v, (((xf.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if rmask is not None:
        t = t * rmask.astype(jnp.float32)
    y = jax.lax.dot_general(
        t, u, (((t.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (y * s1.astype(jnp.float32)).astype(x.dtype)
