from repro.data.synthetic import (  # noqa: F401
    SyntheticCorpus, calib_batches, make_batch, train_iterator)
