"""Synthetic LM corpus + loaders (offline substitute for WikiText-2/C4).

A Zipf-weighted Markov-chain token source gives the model real structure
to learn (bigram statistics + long-range "topic" state), so a tiny
FP teacher trained on it reaches a clearly-sub-uniform perplexity and
quantization quality differences are measurable — which is what the
paper-validation benchmarks need (Tables 2/5/6/9 orderings).

Determinism contract (fault-tolerance): batches are a pure function of
``(seed, step)`` — after a restart the trainer resumes at step k and the
iterator regenerates exactly the batches it would have seen, with no
state to checkpoint beyond the step counter ("deterministic data skip").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticCorpus:
    """Zipf-Markov chain over a vocab with `n_topics` latent regimes.

    Defaults give ~2.2 bits/token conditional entropy (ppl ~5), so a
    ~1M-param teacher reaches far-below-uniform perplexity on ~100k
    tokens and quantization-quality differences are well-resolved."""
    vocab_size: int
    n_topics: int = 2
    branch: int = 8             # out-degree of each state
    zipf_a: float = 1.5
    seed: int = 1234

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, B = self.vocab_size, min(self.branch, self.vocab_size)
        # per-topic sparse transition tables: V x B successor ids + probs
        self.succ = rng.integers(0, V, size=(self.n_topics, V, B),
                                 dtype=np.int32)
        w = (1.0 / np.arange(1, B + 1) ** self.zipf_a)
        self.probs = (w / w.sum()).astype(np.float32)
        self.topic_stay = 0.995

    def sample(self, rng: np.random.Generator, batch: int,
               seq: int) -> np.ndarray:
        """(batch, seq+1) token stream (callers split into input/label)."""
        out = np.empty((batch, seq + 1), np.int32)
        tok = rng.integers(0, self.vocab_size, size=batch).astype(np.int32)
        topic = rng.integers(0, self.n_topics, size=batch)
        for t in range(seq + 1):
            out[:, t] = tok
            switch = rng.random(batch) > self.topic_stay
            topic = np.where(
                switch, rng.integers(0, self.n_topics, size=batch), topic)
            choice = rng.choice(self.probs.shape[0], size=batch, p=self.probs)
            tok = self.succ[topic, tok, choice]
        return out


def make_batch(cfg: ModelConfig, corpus: SyntheticCorpus, seed: int,
               step: int, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    """Pure function of (seed, step) -> batch dict for any family."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    if cfg.family == "audio":
        streams = [corpus.sample(rng, batch, seq)
                   for _ in range(cfg.n_codebooks)]
        full = np.stack(streams, axis=-1)                 # (B, S+1, K)
        b = {"tokens": jnp.asarray(full[:, :-1]),
             "labels": jnp.asarray(full[:, 1:])}
    else:
        full = corpus.sample(rng, batch, seq)
        b = {"tokens": jnp.asarray(full[:, :-1]),
             "labels": jnp.asarray(full[:, 1:])}
    if cfg.family == "vlm":
        # stubbed modality frontend: precomputed patch embeddings
        img = rng.standard_normal(
            (batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
        b["image_embeds"] = jnp.asarray(img, jnp.dtype(cfg.dtype))
    return b


def train_iterator(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                   start_step: int = 0,
                   corpus: Optional[SyntheticCorpus] = None
                   ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite deterministic stream; resume by passing start_step."""
    corpus = corpus or SyntheticCorpus(cfg.vocab_size)
    step = start_step
    while True:
        yield make_batch(cfg, corpus, seed, step, batch, seq)
        step += 1


def calib_batches(cfg: ModelConfig, n_samples: int = 16, seq: int = 128,
                  batch: int = 4, seed: int = 7,
                  corpus: Optional[SyntheticCorpus] = None
                  ) -> List[Dict[str, jnp.ndarray]]:
    """Calibration set for the PTQ pipeline (paper: 128 x 2048 samples;
    scaled down for CPU-tier validation)."""
    corpus = corpus or SyntheticCorpus(cfg.vocab_size)
    return [make_batch(cfg, corpus, seed, i, batch, seq)
            for i in range(max(1, n_samples // batch))]


def eval_perplexity(loss_fn, params, cfg, batches) -> float:
    """exp(mean token NLL) over a batch list."""
    tot, n = 0.0, 0
    for b in batches:
        tot += float(loss_fn(params, cfg, b, training=False))
        n += 1
    return float(np.exp(tot / max(n, 1)))
