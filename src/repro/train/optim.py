"""Minimal pure-JAX optimizer substrate (no optax).

AdamW with cosine / linear schedules and global-norm clipping, operating on
arbitrary param pytrees. Optimizer state is a pytree with the same
structure (m, v in f32 regardless of param dtype — mixed-precision master
statistics), so it shards identically to the params under pjit.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.0) -> Callable:
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1)) if warmup else 1.0
        t = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(math.pi * t))
        return base_lr * warm * (final_frac + (1 - final_frac) * cos)
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), n


class AdamW:
    def __init__(self, lr: Callable | float, b1=0.9, b2=0.999, eps=1e-8,
                 weight_decay=0.0, clip_norm: Optional[float] = None,
                 mask: Optional[Callable] = None):
        self.lr = lr if callable(lr) else constant_schedule(lr)
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.mask = mask  # fn(path_str, leaf) -> bool: apply weight decay?

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         m=jax.tree.map(zeros, params),
                         v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamState, params):
        if self.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        lr = self.lr(state.step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
