"""Binary low-rank gradient compression with error feedback.

Reuses the paper's own representation — rank-k sign–value factorization
(residual SVID, the BiLLM-family building block that also powers
LB-ADMM's proxy step) — as a data-parallel gradient compressor:

    G ≈ Σ_i sign(R_i) ⊙ (a_i b_iᵀ),  R_0 = G,  R_{i+1} = R_i − Ĝ_i

On a real deployment the ±1 sign planes are bit-packed and the factors
are what cross the slow DCN (pod) axis: each pod all-gathers the others'
packed factors and decompresses locally — `compressed_psum` below is that
collective, written with shard_map. Compression is lossy, so an error-
feedback accumulator keeps the optimizer unbiased over time
(e ← g + e − decompress(compress(g + e))).

Bytes per leaf: k·(n+m)/8 (packed signs) + 4k·(n+m) bytes of f32 factor
values vs 4·n·m uncompressed — `compression_ratio` reports it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.svid import svid_factors


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    rank: int = 4                 # residual SVID planes per tensor
    min_size: int = 65536         # leave small leaves uncompressed
    power_iters: int = 4


def _as2d(g: jnp.ndarray) -> Tuple[jnp.ndarray, tuple]:
    shape = g.shape
    if g.ndim == 1:
        return g.reshape(1, -1), shape
    return g.reshape(-1, shape[-1]), shape


def compress_leaf(g: jnp.ndarray, cfg: CompressConfig):
    """-> (signs (k, m, n) ±1, a (k, m), b (k, n)). Residual rank-k SVID."""
    g2, shape = _as2d(g.astype(jnp.float32))

    def plane(res, _):
        a, b = svid_factors(res, cfg.power_iters)
        s = jnp.sign(jnp.where(res == 0, 1.0, res))
        approx = s * jnp.outer(a, b)
        return res - approx, (s, a, b)

    _, (signs, aa, bb) = jax.lax.scan(plane, g2, None, length=cfg.rank)
    return {"signs": signs, "a": aa, "b": bb, "shape": shape}


def decompress_leaf(c) -> jnp.ndarray:
    recon = jnp.einsum("kmn,km,kn->mn", c["signs"], c["a"], c["b"])
    return recon.reshape(c["shape"])


def compress_with_error_feedback(grads, err: Optional[Any],
                                 cfg: CompressConfig):
    """Returns (decompressed grads, new error state). err=None initializes."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        if g.size < cfg.min_size or g.ndim < 2:
            return g, jnp.zeros(g.shape, jnp.float32)
        corrected = g.astype(jnp.float32) + e
        c = compress_leaf(corrected, cfg)
        d = decompress_leaf(c)
        return d.astype(g.dtype), corrected - d

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def compression_ratio(g_shape: tuple, cfg: CompressConfig) -> float:
    """Wire-bytes ratio for one tensor (packed signs + f32 factors vs f32)."""
    if len(g_shape) < 2:
        return 1.0
    m = 1
    for s in g_shape[:-1]:
        m *= s
    n = g_shape[-1]
    raw = 4.0 * m * n
    comp = cfg.rank * (m * n / 8.0 + 4.0 * (m + n))
    return comp / raw


# ---------------------------------------------------------------------------
# the actual collective (pod-axis DP exchange), for deployments where the
# gradient all-reduce crosses the DCN: all-gather packed factors, then
# decompress + mean locally. shard_map'd over the named DP axis.
# ---------------------------------------------------------------------------


def compressed_psum(g_local: jnp.ndarray, axis: str, cfg: CompressConfig):
    """Mean of per-shard gradients exchanged in compressed form.

    Must be called inside a shard_map whose mesh has `axis`. The wire
    format is the rank-k factorization; signs travel packed in uint32 in
    a real deployment (we keep them as ±1 here — the *byte accounting*
    uses the packed size; see compression_ratio)."""
    c = compress_leaf(g_local, cfg)
    signs = jax.lax.all_gather(c["signs"], axis)      # (P, k, m, n)
    aa = jax.lax.all_gather(c["a"], axis)
    bb = jax.lax.all_gather(c["b"], axis)
    recon = jnp.einsum("pkmn,pkm,pkn->mn", signs, aa, bb)
    return (recon / signs.shape[0]).reshape(c["shape"])
