"""Distributed training loop: jit'd train_step with explicit shardings,
microbatch gradient accumulation, optional binary low-rank gradient
compression with error feedback, and checkpoint/restart hooks.

``make_train_step`` builds the pjit-able step; the ``Trainer`` host loop
adds fault tolerance (atomic checkpoints, deterministic data skip) and is
what ``launch/train.py`` / the supervisor drive.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train.grad_compress import (
    CompressConfig, compress_with_error_feedback)
from repro.train.optim import AdamW, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    grad_accum: int = 1
    compress_grads: bool = False
    compress_rank: int = 4
    seed: int = 0


def make_optimizer(tcfg: TrainConfig) -> AdamW:
    return AdamW(cosine_schedule(tcfg.lr, tcfg.total_steps, tcfg.warmup),
                 weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    opt: Optional[AdamW] = None) -> Callable:
    """(params, opt_state, eff, batch) -> (params, opt_state, eff, metrics).

    Gradient accumulation scans over `grad_accum` microbatches (splitting
    the global batch's leading dim) with an f32 accumulator sharded like
    the params; compression (if on) applies to the *accumulated* gradient
    with persistent error feedback `eff`.
    """
    opt = opt or make_optimizer(tcfg)
    ccfg = CompressConfig(rank=tcfg.compress_rank)
    accum = max(1, tcfg.grad_accum)

    def gloss(p, mb):
        return T.loss_fn(p, cfg, mb, training=True)

    def train_step(params, opt_state, eff, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(gloss)(params, batch)
        else:
            # batch arrives pre-split (accum, micro, ...) — see
            # configs.shapes.batch_specs; scanning a leading axis keeps
            # the DP sharding of the micro dim intact (no all-to-all).
            mb = batch
            lead = jax.tree.leaves(batch)[0].shape[0]
            assert lead == accum, (lead, accum)

            def body(carry, b):
                tot, acc = carry
                l, g = jax.value_and_grad(gloss)(params, b)
                gf = jax.tree.map(lambda a: a.astype(jnp.float32), g)
                return (tot + l, _tree_add(acc, gf)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), mb)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        if tcfg.compress_grads:
            grads, eff = compress_with_error_feedback(grads, eff, ccfg)

        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return params, opt_state, eff, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig,
                     key=None) -> Tuple[Any, Any, Any]:
    """(params, opt_state, eff) — eff is the error-feedback tree (zeros)
    when compression is on, else an empty placeholder."""
    key = key if key is not None else jax.random.PRNGKey(tcfg.seed)
    opt = make_optimizer(tcfg)
    params = T.init_params(key, cfg)
    opt_state = opt.init(params)
    if tcfg.compress_grads:
        eff = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    else:
        eff = jnp.zeros(())
    return params, opt_state, eff


class Trainer:
    """Host loop: step the jit'd train_step, checkpoint periodically,
    resume deterministically (see launch/supervisor.py for restarts)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, data_iter,
                 checkpoint_mgr=None, ckpt_every: int = 100,
                 jit_step: Optional[Callable] = None,
                 log_every: int = 10, log_fn=print):
        self.cfg, self.tcfg = cfg, tcfg
        self.data_iter = data_iter
        self.ckpt_mgr = checkpoint_mgr
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.step_fn = jit_step or jax.jit(make_train_step(cfg, tcfg))
        self.state: Optional[tuple] = None
        self.step = 0

    def restore_or_init(self):
        state0 = init_train_state(self.cfg, self.tcfg)
        if self.ckpt_mgr is not None:
            restored = self.ckpt_mgr.restore_latest(template=state0)
            if restored is not None:
                self.step, self.state = restored
                self.log(f"[trainer] resumed at step {self.step}")
                return
        self.state = state0
        self.step = 0

    def run(self, n_steps: int) -> Dict[str, float]:
        if self.state is None:
            self.restore_or_init()
        params, opt_state, eff = self.state
        last = {}
        t0 = time.time()
        for _ in range(n_steps):
            batch = next(self.data_iter)
            params, opt_state, eff, m = self.step_fn(
                params, opt_state, eff, batch)
            self.step += 1
            if self.step % self.log_every == 0:
                last = {k: float(v) for k, v in m.items()}
                self.log(f"[trainer] step={self.step} "
                         f"loss={last.get('loss', float('nan')):.4f} "
                         f"({(time.time()-t0)/self.log_every:.2f}s/step)")
                t0 = time.time()
            if (self.ckpt_mgr is not None
                    and self.step % self.ckpt_every == 0):
                self.state = (params, opt_state, eff)
                self.ckpt_mgr.save(self.step, self.state)
        self.state = (params, opt_state, eff)
        return {k: float(v) for k, v in m.items()}
