from repro.train.loop import (  # noqa: F401
    TrainConfig, Trainer, init_train_state, make_optimizer, make_train_step)
from repro.train.optim import AdamW, cosine_schedule  # noqa: F401
