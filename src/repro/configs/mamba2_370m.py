"""mamba2-370m [ssm] — 48L d_model=1024, attn-free, vocab=50280,
ssm_state=128, SSD state-space duality [arXiv:2405.21060; unverified]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = scaled_down(
    CONFIG, name="mamba2-370m-smoke", n_layers=3, d_model=64,
    vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


@register_arch("mamba2-370m")
def _arch() -> ArchSpec:
    return ArchSpec("mamba2-370m", CONFIG, SMOKE, tuple(SHAPES))
