"""Assigned-architecture registry (``--arch <id>``).

Each ``<arch>.py`` module defines:

- ``CONFIG``  — the exact published configuration (full scale),
- ``SMOKE``   — a reduced same-family config for CPU smoke tests,
- ``SHAPES``  — the input-shape cells this arch runs (subset of
  ``repro.configs.shapes.SHAPES``; ``long_500k`` only for sub-quadratic
  families per the assignment note — see DESIGN.md §5).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

# arch-id (CLI spelling) -> module name
_REGISTRY: Dict[str, str] = {
    "musicgen-medium": "musicgen_medium",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen3-4b": "qwen3_4b",
    "qwen1.5-110b": "qwen1p5_110b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "llama3.2-1b": "llama3p2_1b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "llama-3.2-vision-90b": "llama3p2_vision_90b",
    "mamba2-370m": "mamba2_370m",
}


def _module(arch: str):
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")


def list_archs() -> List[str]:
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def shapes_for(arch: str) -> List[str]:
    return list(_module(arch).SHAPES)
