"""Assigned-architecture configs (``--arch <id>``).

Each ``<arch>.py`` module defines:

- ``CONFIG``  — the exact published configuration (full scale),
- ``SMOKE``   — a reduced same-family config for CPU smoke tests,
- ``SHAPES``  — the input-shape cells this arch runs (subset of
  ``repro.configs.shapes.SHAPES``; ``long_500k`` only for sub-quadratic
  families per the assignment note — see DESIGN.md §5),

and self-registers into the ``repro.api`` arch registry via
``@register_arch`` — the static module-name table that used to live
here is gone. This module keeps the historical accessors
(``list_archs`` / ``get_config`` / ``get_smoke`` / ``shapes_for``) as
thin delegations; new code should use ``repro.api`` directly.
"""
from __future__ import annotations

from typing import List

from repro.api import archs as _archs
from repro.models.config import ModelConfig

# importing the arch modules registers them (decorator side effect)
from repro.configs import (  # noqa: F401,E402
    deepseek_v2_lite, llama3p2_1b, llama3p2_vision_90b, mamba2_370m,
    musicgen_medium, qwen1p5_0p5b, qwen1p5_110b, qwen3_4b, qwen3_moe_235b,
    zamba2_1p2b)


def list_archs() -> List[str]:
    return _archs.list_archs()


def get_config(arch: str) -> ModelConfig:
    return _archs.get_config(arch)


def get_smoke(arch: str) -> ModelConfig:
    return _archs.get_smoke(arch)


def shapes_for(arch: str) -> List[str]:
    return _archs.shapes_for(arch)
