"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048 per codebook
[arXiv:2306.05284; hf]. The EnCodec frontend is a STUB per the
assignment: the backbone consumes precomputed 4-codebook token streams
(tokens shape (B, S, 4)); embeddings are summed per-codebook tables and
the head predicts all 4 codebooks in parallel.
"""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    head_dim=64,
    rope_theta=10000.0,
)

SMOKE = scaled_down(
    CONFIG, name="musicgen-medium-smoke", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128, head_dim=16,
    loss_chunk=0, remat=False)

# full attention -> long_500k skipped (see DESIGN.md §5)
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("musicgen-medium")
def _arch() -> ArchSpec:
    return ArchSpec("musicgen-medium", CONFIG, SMOKE, tuple(SHAPES))
