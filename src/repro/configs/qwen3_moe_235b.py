"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm
[hf:Qwen/Qwen3-30B-A3B family scaling; hf]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    moe_d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    n_experts=128,
    n_experts_per_tok=8,
    rope_theta=1000000.0,
)

SMOKE = scaled_down(
    CONFIG, name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=96, moe_d_ff=96, vocab_size=256, head_dim=16,
    n_experts=8, n_experts_per_tok=2, loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("qwen3-moe-235b-a22b")
def _arch() -> ArchSpec:
    return ArchSpec("qwen3-moe-235b-a22b", CONFIG, SMOKE, tuple(SHAPES))
