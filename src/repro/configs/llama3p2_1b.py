"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=64,
    rope_theta=500000.0,
    tie_embeddings=True,
)

SMOKE = scaled_down(
    CONFIG, name="llama3.2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("llama3.2-1b")
def _arch() -> ArchSpec:
    return ArchSpec("llama3.2-1b", CONFIG, SMOKE, tuple(SHAPES))
