"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
)

SMOKE = scaled_down(
    CONFIG, name="qwen1.5-0.5b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("qwen1.5-0.5b")
def _arch() -> ArchSpec:
    return ArchSpec("qwen1.5-0.5b", CONFIG, SMOKE, tuple(SHAPES))
