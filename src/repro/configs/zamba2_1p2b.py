"""zamba2-1.2b [hybrid] — Mamba2 backbone + one *shared* attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]. The shared attention+FFN block is applied every
``attn_every`` SSM layers (weights shared across applications, zamba2
style). We give the shared block a 4096 sliding window so the arch stays
sub-quadratic at the ``long_500k`` decode cell (adaptation recorded in
DESIGN.md §5).
"""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    sliding_window=4096,
)

SMOKE = scaled_down(
    CONFIG, name="zamba2-1.2b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16, ssm_state=16,
    ssm_head_dim=16, ssm_chunk=16, attn_every=2, sliding_window=64,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


@register_arch("zamba2-1.2b")
def _arch() -> ArchSpec:
    return ArchSpec("zamba2-1.2b", CONFIG, SMOKE, tuple(SHAPES))
