"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H, MLA kv_lora=512,
expert d_ff=1408, vocab=102400, MoE 64 routed top-6 + 2 shared experts,
first layer dense (d_ff=10944) [arXiv:2405.04434; hf]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    dense_d_ff=10944,
    first_k_dense=1,
    vocab_size=102400,
    n_experts=64,
    n_experts_per_tok=6,
    n_shared_experts=2,
    # MLA
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
)

SMOKE = scaled_down(
    CONFIG, name="deepseek-v2-lite-smoke", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=96, moe_d_ff=96, dense_d_ff=160,
    first_k_dense=1, vocab_size=256, n_experts=8, n_experts_per_tok=2,
    n_shared_experts=1, kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16,
    v_head_dim=16, loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("deepseek-v2-lite-16b")
def _arch() -> ArchSpec:
    return ArchSpec("deepseek-v2-lite-16b", CONFIG, SMOKE, tuple(SHAPES))
