"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm [hf:Qwen/Qwen3-8B; hf]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
)

SMOKE = scaled_down(
    CONFIG, name="qwen3-4b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("qwen3-4b")
def _arch() -> ArchSpec:
    return ArchSpec("qwen3-4b", CONFIG, SMOKE, tuple(SHAPES))
