"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias [hf:Qwen/Qwen1.5-0.5B family scaling; hf]."""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = scaled_down(
    CONFIG, name="qwen1.5-110b-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=1, d_ff=192, vocab_size=256, head_dim=8,
    loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("qwen1.5-110b")
def _arch() -> ArchSpec:
    return ArchSpec("qwen1.5-110b", CONFIG, SMOKE, tuple(SHAPES))
