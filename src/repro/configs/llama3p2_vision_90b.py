"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256, gated cross-attention image layers every 5th
layer [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (B, n_image_tokens, d_model); the
backbone projects them to per-cross-layer K/V.
"""
from repro.api.archs import ArchSpec, register_arch
from repro.models.config import ModelConfig, scaled_down

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1024,
)

SMOKE = scaled_down(
    CONFIG, name="llama-vision-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    cross_attn_every=2, n_image_tokens=16, loss_chunk=0, remat=False)

SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@register_arch("llama-3.2-vision-90b")
def _arch() -> ArchSpec:
    return ArchSpec("llama-3.2-vision-90b", CONFIG, SMOKE, tuple(SHAPES))
