"""Input-shape cells and ShapeDtypeStruct stand-ins for the dry-run.

The assigned shape set (LM family — seq_len x global_batch):

- ``train_4k``     4,096 x 256   -> lowers ``train_step``
- ``prefill_32k``  32,768 x 32   -> lowers ``prefill_step``
- ``decode_32k``   32,768 x 128  -> lowers ``serve_step`` (1 new token,
                                    KV cache of 32k already filled)
- ``long_500k``    524,288 x 1   -> lowers ``serve_step`` (SSM / hybrid
                                    only — O(1)-state decode)

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no device
allocation), matching what ``train_step`` / ``serve_step`` take.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _token_shape(cfg: ModelConfig, b: int, s: int):
    if cfg.family == "audio":
        return (b, s, cfg.n_codebooks)
    return (b, s)


def batch_specs(cfg: ModelConfig, b: int, s: int,
                grad_accum: int = 1) -> Dict[str, Any]:
    """Training / prefill batch: tokens + labels (+ VLM image embeds).

    With grad_accum > 1 the global batch arrives pre-split as
    (accum, b/accum, ...) — microbatches are a leading scan axis, so the
    data-parallel sharding of the per-microbatch dim never needs an
    all-to-all (see train.loop)."""
    lead = (grad_accum, b // grad_accum) if grad_accum > 1 else (b,)
    assert b % grad_accum == 0
    specs = {
        "tokens": _sds(lead + _token_shape(cfg, 1, s)[1:], jnp.int32),
        "labels": _sds(lead + _token_shape(cfg, 1, s)[1:], jnp.int32),
    }
    if cfg.family == "vlm":
        specs["image_embeds"] = _sds(
            lead + (cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    return specs


def cache_specs(cfg: ModelConfig, b: int, max_len: int):
    """Abstract KV / SSM cache structs (what serve_step carries)."""
    return jax.eval_shape(lambda: T.init_cache(cfg, b, max_len))


def input_specs(cfg: ModelConfig, shape: str,
                grad_accum: int = 1) -> Dict[str, Any]:
    """All non-param inputs for the step lowered at this shape cell."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    if cell.mode == "train":
        return {"batch": batch_specs(cfg, b, s, grad_accum)}
    if cell.mode == "prefill":
        specs = {"tokens": _sds(_token_shape(cfg, b, s), jnp.int32)}
        if cfg.family == "vlm":
            specs["image_embeds"] = _sds(
                (b, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
        return specs
    if cell.mode == "decode":
        return {
            "token": _sds(_token_shape(cfg, b, 1), jnp.int32),
            "cache": cache_specs(cfg, b, s),
            "pos": _sds((), jnp.int32),
        }
    raise ValueError(cell.mode)


def param_specs(cfg: ModelConfig):
    """Abstract FP parameter tree (ShapeDtypeStructs, no allocation)."""
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg), _sds((2,), jnp.uint32))
