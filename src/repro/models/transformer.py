"""Model assembly: init / forward / loss / prefill / decode for every
assigned family (dense, moe, mla, ssm, hybrid, vlm, audio).

Layer stacks are *stacked pytrees* (leading axis = layer) consumed by
``jax.lax.scan`` so HLO size — and therefore AOT compile time for the
512-device dry-run — is O(1) in depth. Heterogeneous stacks are expressed
as structured scans:

- deepseek  : ``first_k_dense`` unscanned dense layers + scanned MoE layers
- vlm       : scan over groups of (k-1 self layers -> 1 cross-attn layer)
- hybrid    : scan over SSM layers with a *shared* attention block applied
              every ``attn_every`` layers via lax.cond (params closed over,
              per-application KV caches carried)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# init
# ===========================================================================


def _init_block(key, cfg: ModelConfig, kind: str, d_ff: Optional[int] = None):
    """One residual block. kind: attn_ffn | attn_moe | mamba | cross."""
    dt = _dt(cfg)
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    if kind == "mamba":
        return {"ln": jnp.ones((d,), dt), "mixer": L.init_mamba2(k1, cfg, dt)}
    if kind == "cross":
        return {
            "ln1": jnp.ones((d,), dt),
            "xattn": L.init_cross_attention(k1, cfg, dt),
            "ln2": jnp.ones((d,), dt),
            "ffn": L.init_ffn(k2, d, cfg.d_ff, dt),
            "ffn_gate": jnp.zeros((), dt),
        }
    attn = (L.init_mla(k1, cfg, dt) if cfg.is_mla
            else L.init_attention(k1, cfg, dt))
    p = {"ln1": jnp.ones((d,), dt), "attn": attn, "ln2": jnp.ones((d,), dt)}
    if kind == "attn_moe":
        p["moe"] = L.init_moe(k2, cfg, dt)
    else:
        p["ffn"] = L.init_ffn(k2, d, d_ff or cfg.d_ff, dt)
    return p


def _stack_init(key, cfg, n, kind, d_ff=None):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, kind, d_ff))(keys)


def init_params(key, cfg: ModelConfig) -> Params:
    dt = _dt(cfg)
    ke, kl, kh, ks = jax.random.split(key, 4)
    p: Params = {"ln_f": jnp.ones((cfg.d_model,), dt)}

    # embeddings / head
    if cfg.family == "audio":
        p["embed"] = (jax.random.normal(
            ke, (cfg.n_codebooks, cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dt)
        p["lm_head"] = {"w": (jax.random.normal(
            kh, (cfg.d_model, cfg.n_codebooks * cfg.vocab_size), jnp.float32)
            / math.sqrt(cfg.d_model)).astype(dt)}
    else:
        p["embed"] = (jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dt)
        if not cfg.tie_embeddings:
            p["lm_head"] = {"w": (jax.random.normal(
                kh, (cfg.d_model, cfg.vocab_size), jnp.float32)
                / math.sqrt(cfg.d_model)).astype(dt)}

    fam = cfg.family
    if fam in ("dense", "audio"):
        p["layers"] = _stack_init(kl, cfg, cfg.n_layers, "attn_ffn")
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            p["dense_layers"] = _stack_init(
                ks, cfg, cfg.first_k_dense, "attn_ffn", cfg.dense_d_ff or cfg.d_ff)
        p["layers"] = _stack_init(kl, cfg, n_moe, "attn_moe")
    elif fam == "ssm":
        p["layers"] = _stack_init(kl, cfg, cfg.n_layers, "mamba")
    elif fam == "hybrid":
        p["layers"] = _stack_init(kl, cfg, cfg.n_layers, "mamba")
        p["shared_attn"] = _init_block(ks, cfg, "attn_ffn")
    elif fam == "vlm":
        per = cfg.cross_attn_every
        assert cfg.n_layers % per == 0
        groups = cfg.n_layers // per
        kg, kc = jax.random.split(kl)
        gkeys = jax.random.split(kg, groups)
        p["self_layers"] = jax.vmap(
            lambda k: _stack_init(k, cfg, per - 1, "attn_ffn"))(gkeys)
        p["cross_layers"] = _stack_init(kc, cfg, groups, "cross")
    return p


# ===========================================================================
# block application
# ===========================================================================


def _apply_attn_block(p, cfg, x, positions, cache=None, cache_pos=None,
                      block_table=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.is_mla:
        a, new_cache = L.mla_attention(p["attn"], cfg, h, positions, cache,
                                       cache_pos, block_table)
    else:
        a, new_cache = L.attention(p["attn"], cfg, h, positions, cache,
                                   cache_pos, block_table)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe(p["moe"], cfg, h)
    else:
        x = x + L.ffn(p["ffn"], h)
    return x, new_cache


def _apply_mamba_block(p, cfg, x, state=None):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    y, new_state = L.mamba2(p["mixer"], cfg, h, state)
    return x + y, new_state


def _apply_cross_block(p, cfg, x, img_kv):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.cross_attention(p["xattn"], cfg, h, img_kv)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(p["ffn_gate"]).astype(x.dtype) * L.ffn(p["ffn"], h)
    return x


def _maybe_remat(f, cfg, training):
    if cfg.remat and training:
        return jax.checkpoint(f)
    return f


# ===========================================================================
# backbone forward (training / teacher-forcing; no cache)
# ===========================================================================


def embed_tokens(params, cfg, tokens):
    if cfg.family == "audio":
        # tokens: (B, S, K) -> sum of per-codebook embeddings
        emb = params["embed"]                            # (K, V, d)
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), emb.dtype)
        for k in range(cfg.n_codebooks):
            x = x + jnp.take(emb[k], tokens[..., k], axis=0)
        return x
    return jnp.take(params["embed"], tokens, axis=0)


def backbone(params, cfg: ModelConfig, tokens, image_embeds=None,
             training=False):
    """Full-sequence forward to final hidden states (B, S, d)."""
    x = embed_tokens(params, cfg, tokens)
    S = x.shape[1]
    positions = jnp.arange(S)
    fam = cfg.family

    if fam in ("dense", "audio", "moe"):
        if fam == "moe" and cfg.first_k_dense:
            def dbody(h, inp):
                lp, idx = inp
                L.set_scope("dense_layers", idx)
                h, _ = _apply_attn_block(lp, cfg, h, positions)
                return h, None
            x, _ = jax.lax.scan(_maybe_remat(dbody, cfg, training), x,
                                (params["dense_layers"],
                                 jnp.arange(cfg.first_k_dense)))

        def body(h, inp):
            lp, idx = inp
            L.set_scope("layers", idx)
            h, _ = _apply_attn_block(lp, cfg, h, positions)
            return h, None
        n_scan = cfg.n_layers - cfg.first_k_dense
        x, _ = jax.lax.scan(_maybe_remat(body, cfg, training), x,
                            (params["layers"], jnp.arange(n_scan)))

    elif fam == "ssm":
        def body(h, inp):
            lp, idx = inp
            L.set_scope("layers", idx)
            h, _ = _apply_mamba_block(lp, cfg, h)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg, training), x,
                            (params["layers"], jnp.arange(cfg.n_layers)))

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def body(h, inp):
            lp, idx = inp
            L.set_scope("layers", idx)
            h, _ = _apply_mamba_block(lp, cfg, h)

            def do_attn(hh):
                L.set_scope("shared_attn", (idx + 1) // cfg.attn_every - 1)
                out = _apply_attn_block(shared, cfg, hh, positions)[0]
                L.set_scope("", None)
                return out
            h = jax.lax.cond(
                (idx + 1) % cfg.attn_every == 0, do_attn,
                lambda hh: hh, h)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg, training), x,
                            (params["layers"], jnp.arange(cfg.n_layers)))

    elif fam == "vlm":
        assert image_embeds is not None
        per = cfg.cross_attn_every

        def group(h, gp):
            selfs, crossp, gidx = gp

            def sbody(hh, sinp):
                lp, sidx = sinp
                L.set_scope("self_layers", gidx * (per - 1) + sidx)
                hh, _ = _apply_attn_block(lp, cfg, hh, positions)
                return hh, None
            h, _ = jax.lax.scan(_maybe_remat(sbody, cfg, training), h,
                                (selfs, jnp.arange(per - 1)))
            L.set_scope("cross_layers", gidx)
            kv = L.image_kv(crossp["xattn"], cfg, image_embeds)
            h = _apply_cross_block(crossp, cfg, h, kv)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(group, cfg, training), x,
                            (params["self_layers"], params["cross_layers"],
                             jnp.arange(cfg.n_layers // per)))

    L.set_scope("", None)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def _head_w(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]["w"]


def logits_fn(params, cfg, hidden):
    w = _head_w(params, cfg)
    out = hidden @ w.astype(hidden.dtype)
    if cfg.family == "audio":
        out = out.reshape(*hidden.shape[:-1], cfg.n_codebooks, cfg.vocab_size)
    return out


def forward(params, cfg, tokens, image_embeds=None):
    h = backbone(params, cfg, tokens, image_embeds)
    return logits_fn(params, cfg, h)


# ===========================================================================
# loss — sequence-chunked cross-entropy (Cut-Your-Losses-style; the full
# (B,S,V) logits tensor is never materialized)
# ===========================================================================


def _xent(logits, labels):
    """logits (..., V) f32; labels (...) int32 with -1 = masked."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - ll) * mask).sum(), mask.sum()


def loss_fn(params, cfg: ModelConfig, batch, training=True):
    """batch: tokens (B,S[,K]), labels (B,S[,K]), optional image_embeds."""
    h = backbone(params, cfg, batch["tokens"], batch.get("image_embeds"),
                 training=training)
    labels = batch["labels"]
    w = _head_w(params, cfg)
    S = h.shape[1]
    chunk = cfg.loss_chunk or S
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S

    def chunk_loss(h_c, y_c):
        logits = L.constrain(h_c @ w.astype(h_c.dtype), "dp", None, "tp")
        if cfg.family == "audio":
            logits = logits.reshape(*h_c.shape[:-1], cfg.n_codebooks,
                                    cfg.vocab_size)
        return _xent(logits, y_c)

    if chunk == S:
        tot, cnt = chunk_loss(h, labels)
    else:
        nc = S // chunk
        hc = h.reshape(h.shape[0], nc, chunk, h.shape[-1]).transpose(1, 0, 2, 3)
        yc = labels.reshape(labels.shape[0], nc, chunk, *labels.shape[2:]
                            ).swapaxes(0, 1)

        def body(carry, inp):
            t, c = carry
            dl, dc = jax.checkpoint(chunk_loss)(*inp)
            return (t + dl, c + dc), None
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (hc, yc))
    return tot / jnp.maximum(cnt, 1.0)


# ===========================================================================
# KV / state caches + prefill / decode
# ===========================================================================


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dt = _dt(cfg)
    fam = cfg.family
    hd = cfg.head_dim

    def attn_cache(n, rows=max_len):
        if cfg.is_mla:
            return {
                "c_kv": jnp.zeros((n, batch, rows, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((n, batch, rows, 1, cfg.qk_rope_dim), dt),
            }
        return {
            "k": jnp.zeros((n, batch, rows, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((n, batch, rows, cfg.n_kv_heads, hd), dt),
        }

    def ssm_state(n):
        gn = cfg.ssm_groups * cfg.ssm_state
        return {
            "ssm": jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv_x": jnp.zeros((n, batch, cfg.ssm_conv - 1, cfg.d_inner), dt),
            "conv_B": jnp.zeros((n, batch, cfg.ssm_conv - 1, gn), dt),
            "conv_C": jnp.zeros((n, batch, cfg.ssm_conv - 1, gn), dt),
        }

    if fam in ("dense", "audio"):
        return {"layers": attn_cache(cfg.n_layers)}
    if fam == "moe":
        c = {"layers": attn_cache(cfg.n_layers - cfg.first_k_dense)}
        if cfg.first_k_dense:
            c["dense_layers"] = attn_cache(cfg.first_k_dense)
        return c
    if fam == "ssm":
        return {"layers": ssm_state(cfg.n_layers)}
    if fam == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        win = min(max_len, cfg.sliding_window or max_len)
        # the shared-attn cache is a ring of exactly `win` rows: writes
        # wrap at win (pos % win in _cached_forward), and the decode
        # mask's ring modulo is the buffer length — sizing it max_len
        # would both waste KV memory and desynchronize the modulo.
        return {"layers": ssm_state(cfg.n_layers),
                "shared_attn": attn_cache(n_apps, win),
                "window": win}
    if fam == "vlm":
        per = cfg.cross_attn_every
        groups = cfg.n_layers // per
        sc = attn_cache(groups * (per - 1))
        sc = jax.tree.map(lambda a: a.reshape(groups, per - 1, *a.shape[1:]), sc)
        return {
            "self_layers": sc,
            "cross_kv": {
                "k": jnp.zeros((groups, batch, cfg.n_image_tokens,
                                cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((groups, batch, cfg.n_image_tokens,
                                cfg.n_kv_heads, hd), dt),
            },
        }
    raise ValueError(fam)


def _cached_forward(params, cfg, tokens, cache, pos, image_embeds=None,
                    block_tables=None):
    """Shared implementation for prefill (S>=1) and decode (S==1).

    pos: absolute position of tokens[:, 0] — a scalar shared by the
    batch, or a (B,) vector of per-slot positions (continuous-batching
    decode, S == 1 only): each batch row then gets its own RoPE phase,
    cache write offset and causal mask.
    block_tables: the cache's attention leaves are paged pools
    (serve.paging) and this is a paged decode — a dict with a
    ``"linear"`` (B, pages) table for ordinary caches and/or a
    ``"ring"`` table for the hybrid shared-attention ring. S == 1 is
    the normal decode; S > 1 (speculative verify, token j at row
    pos + j) is supported for linear-only tables — a ring table wraps
    its write position per token, which the shared first-row wrap
    below does not model, so multi-token calls drop the tables and
    would read a rectangular cache instead (the speculative engine
    gates ring/hybrid out before ever getting here).
    Returns (hidden, new_cache)."""
    x = embed_tokens(params, cfg, tokens)
    S = x.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim:                                   # per-slot (B,) positions
        positions = pos[:, None] + jnp.arange(S)[None, :]     # (B, S)
    else:
        positions = pos + jnp.arange(S)                       # (S,)
    fam = cfg.family
    if not block_tables or (S != 1 and set(block_tables) != {"linear"}):
        block_tables = None                        # paged decode only

    bt_lin = block_tables.get("linear") if block_tables else None

    if fam in ("dense", "audio", "moe"):
        new_cache = dict(cache)
        if fam == "moe" and cfg.first_k_dense:
            def dbody(h, inp):
                lp, lc = inp
                h, nc = _apply_attn_block(lp, cfg, h, positions, lc, pos,
                                          bt_lin)
                return h, nc
            x, ncache = jax.lax.scan(dbody, x, (params["dense_layers"],
                                                cache["dense_layers"]))
            new_cache["dense_layers"] = ncache

        def body(h, inp):
            lp, lc = inp
            h, nc = _apply_attn_block(lp, cfg, h, positions, lc, pos, bt_lin)
            return h, nc
        x, ncache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = ncache

    elif fam == "ssm":
        if S == 1:
            def body(h, inp):
                lp, lc = inp
                h, ns = _apply_mamba_block(lp, cfg, h, lc)
                return h, ns
            x, nstate = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": nstate}
        else:  # prefill: run full-seq SSD, rebuild terminal states
            def body(h, inp):
                lp, lc = inp
                h2 = L.rms_norm(h, lp["ln"], cfg.norm_eps)
                y, ns = _mamba_prefill(lp["mixer"], cfg, h2, lc)
                return h + y, ns
            x, nstate = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": nstate}

    elif fam == "hybrid":
        shared = params["shared_attn"]
        win = cache["window"]
        n_apps = cfg.n_layers // cfg.attn_every
        bt_ring = block_tables.get("ring") if block_tables else None
        if bt_ring is not None:
            # paged ring: writes wrap modulo the *virtual* ring size
            # (mapped pages x page_size >= win) so the decode mask's
            # row->position reconstruction matches the write wrap.
            ps = jax.tree.leaves(cache["shared_attn"])[0].shape[2]
            ring_rows = bt_ring.shape[1] * ps

        def body(carry, inp):
            h, attn_caches = carry
            lp, lc, idx = inp
            if S == 1:
                h, ns = _apply_mamba_block(lp, cfg, h, lc)
            else:
                h2 = L.rms_norm(h, lp["ln"], cfg.norm_eps)
                y, ns = _mamba_prefill(lp["mixer"], cfg, h2, lc)
                h = h + y
            app = (idx + 1) // cfg.attn_every - 1

            def do_attn(op):
                hh, caches = op
                lc_a = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
                    a, app, 0, keepdims=False), caches)
                # window the cache write position
                if bt_ring is not None:
                    wpos = pos % ring_rows
                elif S > 1:
                    wpos = jnp.minimum(pos, win - S)
                else:
                    wpos = pos % jnp.maximum(win, 1)
                hh2, nc = _apply_attn_block(shared, cfg, hh, positions,
                                            lc_a, wpos, bt_ring)
                caches = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(
                        a, n.astype(a.dtype), app, 0), caches, nc)
                return hh2, caches

            h, attn_caches = jax.lax.cond(
                (idx + 1) % cfg.attn_every == 0, do_attn,
                lambda op: op, (h, attn_caches))
            return (h, attn_caches), ns

        (x, nattn), nstate = jax.lax.scan(
            body, (x, cache["shared_attn"]),
            (params["layers"], cache["layers"], jnp.arange(cfg.n_layers)))
        new_cache = {"layers": nstate, "shared_attn": nattn, "window": win}

    elif fam == "vlm":
        if image_embeds is not None:  # prefill: project image K/V once
            def proj(crossp):
                k, v = L.image_kv(crossp["xattn"], cfg, image_embeds)
                return {"k": k, "v": v}
            cross_kv = jax.vmap(proj)(params["cross_layers"])
        else:
            cross_kv = cache["cross_kv"]

        def group(h, inp):
            selfs, crossp, scache, ckv = inp

            def sbody(hh, sinp):
                lp, lc = sinp
                hh, nc = _apply_attn_block(lp, cfg, hh, positions, lc, pos,
                                           bt_lin)
                return hh, nc
            h, nsc = jax.lax.scan(sbody, h, (selfs, scache))
            h = _apply_cross_block(crossp, cfg, h, (ckv["k"], ckv["v"]))
            return h, nsc
        x, nsc = jax.lax.scan(group, x, (params["self_layers"],
                                         params["cross_layers"],
                                         cache["self_layers"], cross_kv))
        new_cache = {"self_layers": nsc, "cross_kv": cross_kv}

    else:
        raise ValueError(fam)

    return L.rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def _mamba_prefill(p, cfg, x, state):
    """Full-seq mamba forward that also returns the terminal SSM/conv state."""
    B, S, _ = x.shape
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    K1 = cfg.ssm_conv - 1
    z, xs, Bm, Cm, dt = L._mamba_streams(p, x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    tails = {"conv_x": xs[:, -K1:, :], "conv_B": Bm[:, -K1:, :],
             "conv_C": Cm[:, -K1:, :]}
    xs = L.silu(L._causal_conv(xs, p["conv_x"], p["conv_bx"]))
    Bm = L.silu(L._causal_conv(Bm, p["conv_B"], p["conv_bB"]))
    Cm = L.silu(L._causal_conv(Cm, p["conv_C"], p["conv_bC"]))
    xs = L.constrain(xs.reshape(B, S, H, P), "dp", None, "tp", None)
    Bm = Bm.reshape(B, S, g, n)
    Cm = Cm.reshape(B, S, g, n)
    y, final = L.ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = L.rms_norm(y * L.silu(z), p["norm_w"], cfg.norm_eps)
    new_state = {"ssm": final}
    for k, tail in tails.items():
        new_state[k] = tail.astype(state[k].dtype)
    return L.dense(p["out_proj"], y), new_state


def prefill(params, cfg, tokens, cache, image_embeds=None, last_idx=None,
            start_pos=None, block_tables=None):
    """Process the prompt; returns (last-token logits, filled cache).

    last_idx: position of the final *real* prompt token. Defaults to the
    last column; pass it when `tokens` is right-padded to a compile
    bucket — causality makes the logits at last_idx (and the cache rows
    up to it) identical to an unpadded prefill.

    start_pos / block_tables: the **suffix prefill** path (prefix-cached
    serving, serve.prefix): `cache` is the paged pool, rows
    ``[0, start_pos)`` of the slot already hold the shared prefix KV,
    and `tokens` is only the uncached suffix. Token j writes cache row
    ``start_pos + j`` through the slot's linear block table and attends
    to every earlier row — the same mechanics as the speculative
    multi-token verify (`decode_step` with S > 1), just admission-sized.
    `start_pos` follows decode_step's pos contract ((B,) vector for
    per-slot offsets); linear-only tables, like any S > 1 paged call."""
    h, cache = _cached_forward(params, cfg, tokens, cache,
                               0 if start_pos is None else start_pos,
                               image_embeds, block_tables=block_tables)
    if last_idx is None:
        h = h[:, -1:]
    else:
        h = jax.lax.dynamic_slice_in_dim(h, last_idx, 1, axis=1)
    return logits_fn(params, cfg, h), cache


def decode_step(params, cfg, token, cache, pos, block_tables=None):
    """One decode step. token: (B, S[, K]) with S == 1 normally, or
    S > 1 for the speculative multi-token verify forward (logits come
    back for every position); pos: absolute position of token[:, 0] —
    scalar (lockstep batch) or (B,) per-slot vector (continuous
    batching). block_tables: per-slot page tables when `cache` is a
    paged pool (serve.paging; requires per-slot (B,) pos; S > 1 needs
    linear tables only, see :func:`_cached_forward`)."""
    h, cache = _cached_forward(params, cfg, token, cache, pos,
                               block_tables=block_tables)
    return logits_fn(params, cfg, h), cache


# ===========================================================================
# parameter accounting
# ===========================================================================


def count_params(cfg: ModelConfig) -> int:
    import numpy as np
    p = jax.eval_shape(lambda k: init_params(k, cfg),
                       jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(p)))
