from repro.models.config import ModelConfig, scaled_down  # noqa: F401
from repro.models import layers, transformer  # noqa: F401
