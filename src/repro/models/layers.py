"""Layer zoo (pure JAX, no flax).

Params are plain dicts of jnp arrays; every sublayer is an
``init_*(key, cfg) -> params`` / ``apply(params, x, ...)`` pair. Linear
layers route through :func:`dense`, which transparently executes either a
full-precision matmul or a NanoQuant packed low-rank binary matmul when the
param dict carries quantized leaves — this is what makes the quantized
model a drop-in for serving.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.sharding.rules import tp_role

# --------------------------------------------------------------------------
# calibration taps (paper Alg. 1 Phase 1): when a StatCollector is
# installed, every named dense() records input second moments on the
# forward pass and output-gradient second moments on the backward pass —
# the diagonal K-FAC statistics behind D̃_in / D̃_out. Taps are trace-time:
# with no collector installed the hooks cost nothing.
# --------------------------------------------------------------------------

_TAP = [None]
_TAP_FIELDS = [("in", "out")]
_SCOPE = [("", None)]  # (stack_name, traced layer index | None)


def set_tap(collector, fields=("in", "out")) -> None:
    """Install `collector`; `fields` selects which taps fire ("in":
    forward activation moments, "out": output-gradient moments). The
    calibration driver runs them in separate passes — jax drops plain
    forward debug callbacks inside scan under grad, so "in" must be
    collected by a forward-only pass."""
    _TAP[0] = collector
    _TAP_FIELDS[0] = tuple(fields)


def set_scope(stack: str, idx) -> None:
    _SCOPE[0] = (stack, idx)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3))
def _grad_tap(cb, y, idx, expert=False):
    return y


def _grad_tap_fwd(cb, y, idx, expert):
    # fwd receives args in the primal's order (nondiff args included).
    return y, idx


def _grad_tap_bwd(cb, expert, idx, g):
    red = (1,) if expert else tuple(range(g.ndim - 1))
    sq = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=red)
    cnt = jnp.asarray(g.shape[1] if expert else g.size // g.shape[-1],
                      jnp.float32)
    jax.debug.callback(cb, idx, sq, cnt)
    return g, jnp.zeros_like(idx)


_grad_tap.defvjp(_grad_tap_fwd, _grad_tap_bwd)


def _tap_pre(name, x, expert=False):
    tap = _TAP[0]
    if tap is None or name is None or "in" not in _TAP_FIELDS[0]:
        return
    stack, idx = _SCOPE[0]
    red = (1,) if expert else tuple(range(x.ndim - 1))
    sq = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=red)
    cnt = jnp.asarray(x.shape[1] if expert else x.size // x.shape[-1],
                      jnp.float32)
    jax.debug.callback(tap.make_cb(stack, name, "in"),
                       _scope_idx(idx), sq, cnt)


def _tap_post(name, y, expert=False):
    tap = _TAP[0]
    if tap is None or name is None or "out" not in _TAP_FIELDS[0]:
        return y
    stack, idx = _SCOPE[0]
    cb = tap.make_cb(stack, name, "out")
    return _grad_tap(cb, y, _scope_idx(idx), expert)


def _scope_idx(idx):
    return jnp.asarray(-1.0 if idx is None else idx, jnp.float32)


def sign_ste(u):
    """sign with straight-through gradient (paper Eq. 10)."""
    s = jnp.sign(u)
    s = jnp.where(s == 0, 1.0, s).astype(u.dtype)
    return u + jax.lax.stop_gradient(s - u)


# --------------------------------------------------------------------------
# activation-sharding constraints. GSPMD propagation alone loses the
# head sharding at the GQA grouping reshape (heads < mesh axis) and then
# replicates whole attention blocks; production frameworks pin activation
# shardings explicitly, and so do we. A process-global policy (installed
# by launch/cells.py before lowering; absent in plain CPU tests, where
# every constraint is a no-op) maps logical roles to mesh axes.
# --------------------------------------------------------------------------

# process-wide default (launch/cells.py installs one before lowering)
# plus a contextvar override for scoped traces (the tensor-parallel
# InferenceEngine) — same dual-layer shape as kernels.ops' policy, so
# concurrent traces from different engines/threads cannot trample each
# other's constraints.
_ACT_SHARD = [None]
_ACT_UNSET = object()
_ACT_SCOPED: contextvars.ContextVar = contextvars.ContextVar(
    "nanoquant_act_shard", default=_ACT_UNSET)


def _make_act_policy(mesh, dp, tp):
    return None if mesh is None else {
        "mesh": mesh, "dp": tuple(dp) if dp else None, "tp": tp}


def _current_act_shard():
    scoped = _ACT_SCOPED.get()
    return _ACT_SHARD[0] if scoped is _ACT_UNSET else scoped


def set_activation_sharding(mesh, dp, tp) -> None:
    """Install process-wide. mesh: jax Mesh (or None to clear); dp:
    tuple of data axes; tp: model axis name."""
    _ACT_SHARD[0] = _make_act_policy(mesh, dp, tp)


@contextlib.contextmanager
def activation_sharding(mesh, dp, tp):
    """Scoped override (this thread/task only; restores on exit) — for
    tracing under a specific mesh, e.g. the sharded InferenceEngine's
    jitted steps. ``mesh=None`` scopes the constraints *off*."""
    token = _ACT_SCOPED.set(_make_act_policy(mesh, dp, tp))
    try:
        yield
    finally:
        _ACT_SCOPED.reset(token)


def _axis_len(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def constrain(x, *roles):
    """with_sharding_constraint by per-dim logical role:
    None (replicated) | 'dp' (batch) | 'tp' (model). Divisibility-checked;
    non-divisible dims fall back to replicated."""
    pol = _current_act_shard()
    if pol is None:
        return x
    mesh = pol["mesh"]
    spec = []
    for dim, role in zip(x.shape, roles):
        axis = pol.get(role) if role else None
        spec.append(axis if axis is not None
                    and dim % _axis_len(mesh, axis) == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def silu(x):
    return x * jax.nn.sigmoid(x)


def init_linear(key, d_in, d_out, bias=False, dtype=jnp.bfloat16, std=None):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _ste_matmul(p, x):
    """Latent STE linear (block-reconstruction Step 3, paper Eq. 10):
    W_eff = diag(s1)·sign(𝒰)·sign(𝒱)ᵀ·diag(s2) with straight-through grads
    to the continuous latents. lv: (d_in, r), lu: (d_out, r)."""
    xs = x * p["s2"].astype(x.dtype)
    t = xs @ sign_ste(p["lv"]).astype(x.dtype)
    y = t @ sign_ste(p["lu"]).astype(x.dtype).T
    return y * p["s1"].astype(x.dtype)


def dense(p: dict, x: jnp.ndarray, name: Optional[str] = None) -> jnp.ndarray:
    """FP / STE-latent / packed-binary linear. x: (..., d_in) -> (..., d_out)."""
    _tap_pre(name, x)
    if "qu_t" in p:      # packed low-rank binary path (paper Eq. 1)
        # "eff_rank" is a static EffRank marker added by
        # quant.surgery.rank_truncated_view (speculative draft views);
        # the kernel then reads only the leading r' rank columns.
        er = p.get("eff_rank")
        y = kops.lowrank_binary_matmul(x, p["qv"], p["qu_t"], p["s1"],
                                       p["s2"], tp=tp_role(name),
                                       eff_rank=int(er) if er else None)
    elif "lu" in p:      # continuous latents with STE (refinement phase)
        y = _ste_matmul(p, x)
    else:
        y = x @ p["w"].astype(x.dtype)
    y = _tap_post(name, y)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def dense_merged(mp: dict, x: jnp.ndarray, names, dims):
    """Grouped packed projections sharing the input `x` (attention QKV /
    MLP gate-up): ONE fused kernel launch instead of len(dims). `mp` is
    the merged operand group built by
    ``quant.surgery.merge_projection_groups``; `dims` are the static
    true output widths. Taps and per-projection biases behave exactly
    like the equivalent per-projection :func:`dense` calls."""
    for nm in names:
        _tap_pre(nm, x)
    er = mp.get("eff_rank")
    ys = kops.lowrank_binary_matmul_merged(x, mp, dims,
                                           eff_rank=int(er) if er else None)
    out = []
    for i, (nm, n) in enumerate(zip(names, dims)):
        y = _tap_post(nm, ys[i])
        if "b" in mp:
            y = y + mp["b"][i, :n].astype(y.dtype)
        out.append(y)
    return out


def _use_merged(p: dict, key: str) -> bool:
    return key in p and kops.current_kernel_policy().use_merged_projections()


def dense_expert(p: dict, x: jnp.ndarray, name: Optional[str] = None) -> jnp.ndarray:
    """Batched-expert linear: x (E, C, d_in) with stacked weights (E, ...)."""
    _tap_pre(name, x, expert=True)
    if "qu_t" in p:
        # expert axis becomes a kernel grid dimension on the fused
        # pallas path (one launch for all experts); ref falls back to a
        # per-expert vmap of the two-stage oracle.
        er = p.get("eff_rank")
        y = kops.lowrank_binary_matmul_expert(x, p["qv"], p["qu_t"],
                                              p["s1"], p["s2"],
                                              eff_rank=int(er) if er else None)
    elif "lu" in p:
        y = jax.vmap(_ste_matmul)(
            {"lu": p["lu"], "lv": p["lv"], "s1": p["s1"], "s2": p["s2"]}, x)
    else:
        y = jnp.einsum("ecd,edf->ecf", x, p["w"].astype(x.dtype))
    return _tap_post(name, y, expert=True)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, D/2)
    if ang.ndim == 2:                                # (S, D/2) -> broadcast B, H
        ang = ang[None, :, None, :]
    else:                                            # (B, S, D/2)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional qk-norm / bias / sliding window), flash-chunked
# --------------------------------------------------------------------------


def init_attention(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, cfg.qkv_bias, dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.qkv_bias, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, False, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _mask(q_pos, k_pos, window: int, causal: bool = True):
    """q_pos (Sq,) or (B,Sq) per-slot; k_pos (Sk,).
    Returns bool (Sq,Sk) or (B,Sq,Sk)."""
    q = q_pos[..., :, None]
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[0]), bool)
    if causal:
        m = m & (k_pos <= q)
    if window:
        m = m & (k_pos > q - window)
    return m


def sdpa(q, k, v, mask, scale):
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,Dk/Dv), mask (Sq,Sk) or per-slot
    (B,Sq,Sk) -> (B,Sq,Hq,Dv)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    msk = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    s = jnp.where(msk, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, Sq, Hq, -1)


def sdpa_flash(q, k, v, q_pos, k_pos, scale, window=0,
               q_chunk=512, kv_chunk=1024):
    """Memory-bounded attention: outer scan over query chunks, inner scan
    over key chunks with an online softmax (flash-attention algorithm in
    pure JAX; XLA pipelines it, and activation footprint is O(chunk^2))."""
    B, Sq, Hq, D = q.shape
    Hkv, Dv = k.shape[2], v.shape[-1]
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    Sk = k.shape[1]
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0

    qc = q.reshape(B, nq, q_chunk, Hkv, G, D)
    qp = q_pos.reshape(nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, Hkv, D)
    vc = v.reshape(B, nk, kv_chunk, Hkv, Dv)
    kp = k_pos.reshape(nk, kv_chunk)

    def q_body(_, qi):
        qblk, qpos = qi                                   # (B,cq,Hkv,G,D), (cq,)

        @jax.checkpoint
        def kv_body(carry, ki):
            # rematted: without this the backward pass materializes the
            # (..., q_chunk, kv_chunk) pexp tensor for EVERY (layer, q, kv)
            # chunk triple at once — O(S^2) residents (see EXPERIMENTS.md
            # §Perf iteration 1).
            m_run, l_run, acc = carry
            kblk, vblk, kpos = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk).astype(jnp.float32) * scale
            msk = _mask(qpos, kpos, window)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, s.max(-1))
            alpha = jnp.exp(m_run - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", pexp.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
            jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32),
        )
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_body, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kp),
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(v.dtype)                  # (B,Hkv,G,cq,Dv)

    _, outs = jax.lax.scan(q_body, None, (qc.transpose(1, 0, 2, 3, 4, 5), qp))
    # outs: (nq, B, Hkv, G, cq, Dv) -> (B, Sq, Hq, Dv)
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, Dv)
    return o


def _cache_write(buf, new, cache_pos):
    """Write `new` (B,S,...) into `buf` (B,Smax,...) at sequence offset
    `cache_pos` — a scalar shared by the batch, or a (B,) vector of
    per-slot offsets (continuous-batching decode)."""
    new = new.astype(buf.dtype)
    if jnp.ndim(cache_pos):
        return jax.vmap(lambda b, n, p: jax.lax.dynamic_update_slice(
            b, n, (p,) + (0,) * (b.ndim - 1)))(buf, new, cache_pos)
    return jax.lax.dynamic_update_slice(
        buf, new, (0, cache_pos) + (0,) * (buf.ndim - 2))


def paged_cache_write(pool, new, block_table, row):
    """Write S tokens into a paged KV pool (serve.paging): token j of
    `new` (B, S, ...) lands in row ``row[b] + j`` of slot b's virtual
    rectangle — page ``block_table[b, r // page_size]``, offset ``r %
    page_size`` (rows wrap modulo the virtual rectangle, a no-op for
    linear tables where written rows never reach the table width).
    S == 1 is the normal decode write; S > 1 is the speculative verify
    forward re-writing the draft rows exactly. Inactive slots' block
    tables are all-zero, so their masked writes hit the null page
    (trash) instead of a neighbour.
    pool: (n_pages, page_size, ...); block_table: (B, pages); row: (B,).
    """
    ps = pool.shape[1]
    S = new.shape[1]
    rows = (row[:, None] + jnp.arange(S)) % (block_table.shape[1] * ps)
    page = jnp.take_along_axis(block_table, rows // ps, axis=1)   # (B, S)
    return pool.at[page, rows % ps].set(new.astype(pool.dtype))


def gather_pages(pool, block_table):
    """Gather a slot's pages into its virtual rectangle:
    (n_pages, page_size, ...) x (B, pages) -> (B, pages*page_size, ...).
    Virtual row index == (ring-wrapped) cache position, so the plain
    decode mask applies; rows from unmapped null-page entries sit past
    every valid position and mask out."""
    g = jnp.take(pool, block_table, axis=0)
    return g.reshape(block_table.shape[0], -1, *pool.shape[2:])


def _cache_valid(k_pos, cache_pos, S):
    """Rows of the cache holding real entries: (Smax,) for scalar
    cache_pos, (B,1,Smax) for per-slot (B,) cache_pos."""
    if jnp.ndim(cache_pos):
        return (k_pos[None, :] < cache_pos[:, None] + S)[:, None, :]
    return k_pos < cache_pos + S


def _decode_mask(q_pos, cache_pos, n_rows, window):
    """Decode mask over a cache buffer that may be a ring (hybrid
    sliding window: cache_pos == q_pos % window, so absolute positions
    and row indices diverge after the first wrap). ``cache_pos`` is the
    write offset of the FIRST query; query j writes at ``cache_pos + j``
    and row r last held the key of absolute position
    ``(q + j) - ((cache_pos + j - r) mod n_rows)``; a negative value
    means the row was never written. Causality is implicit (row
    positions never exceed the query's own position — rows written by
    later queries of a multi-token call reconstruct as negative while
    written positions stay below n_rows, the linear-cache invariant of
    the speculative verify forward). For a linear cache
    (cache_pos == q_pos) this reduces to the plain causal+window mask.
    q_pos: (S,) or per-slot (B,S); cache_pos scalar or (B,).
    Returns (S, n_rows) or (B, S, n_rows)."""
    r = jnp.arange(n_rows)
    S = q_pos.shape[-1]
    j = jnp.arange(S)
    if jnp.ndim(cache_pos):
        cp = cache_pos[:, None] + j[None, :]                 # (B, S)
        delta = (cp[:, :, None] - r[None, None, :]) % n_rows
        abs_pos = q_pos[:, :, None] - delta                  # (B, S, rows)
    else:
        cp = cache_pos + j                                   # (S,)
        delta = (cp[:, None] - r[None, :]) % n_rows          # (S, rows)
        abs_pos = q_pos[..., :, None] - delta
    m = abs_pos >= 0
    if window:
        m = m & (abs_pos > q_pos[..., :, None] - window)
    return m


def attention(p, cfg, x, positions, cache=None, cache_pos=None,
              block_table=None):
    """GQA attention. Returns (out, new_cache).

    cache: None (training) or dict(k=(B,Smax,Hkv,D), v=...) being filled.
    cache_pos: write offset for decode — scalar, or (B,) per-slot vector
    (with positions (B,S)) for slot-scheduled continuous batching.
    positions: (S,) absolute, or (B,S) per-slot.
    block_table: (B, pages) int32 — the cache is a paged pool
    (k/v: (n_pages, page_size, Hkv, D), see serve.paging) and this is a
    decode over per-slot rows (S == 1 normally; S > 1 for the
    speculative verify forward, token j at row cache_pos + j): writes
    go through :func:`paged_cache_write`
    and the read walks the block table (``kernels.ops.paged_attention``
    — Pallas gather kernel on TPU, gather + rectangle oracle elsewhere).
    For the hybrid sliding-window ring, `cache_pos` arrives pre-wrapped
    modulo the virtual ring (pages * page_size).
    """
    flash_threshold = cfg.flash_threshold
    B, S, _ = x.shape
    hd = cfg.head_dim
    if (cache is not None and block_table is not None and S == 1
            and _TAP[0] is None and not cfg.qk_norm
            and _use_merged(p, "wqkv") and "b" not in p["wqkv"]
            and "qu_t" in p.get("wo", {}) and "b" not in p["wo"]):
        # fused decode step: QKV → paged attention → wo in ONE kernel
        # (kernels.megakernel). Returns None for non-qualifying launches
        # (TP mesh, oversized rank, ...) — fall through to the unfused
        # chain below, which is online-softmax-equal.
        er = p["wqkv"].get("eff_rank")
        ero = p["wo"].get("eff_rank")
        mega = kops.decode_step_megakernel(
            x[:, 0], p["wqkv"], p["wo"], cache["k"], cache["v"],
            block_table, positions[:, 0], cache_pos, head_dim=hd,
            dims=(cfg.n_heads * hd, cfg.n_kv_heads * hd),
            theta=cfg.rope_theta, scale=1.0 / math.sqrt(hd),
            window=cfg.sliding_window,
            eff_rank=int(er) if er else None,
            eff_rank_o=int(ero) if ero else None)
        if mega is not None:
            y, k_new, v_new = mega
            ck = paged_cache_write(cache["k"], k_new[:, None],
                                   block_table, cache_pos)
            cv = paged_cache_write(cache["v"], v_new[:, None],
                                   block_table, cache_pos)
            return y[:, None], {"k": ck, "v": cv}
    if _use_merged(p, "wqkv"):
        q, k, v = dense_merged(
            p["wqkv"], x, ("attn.wq", "attn.wk", "attn.wv"),
            (cfg.n_heads * hd, cfg.n_kv_heads * hd, cfg.n_kv_heads * hd))
        q = q.reshape(B, S, cfg.n_heads, hd)
        k = k.reshape(B, S, cfg.n_kv_heads, hd)
        v = v.reshape(B, S, cfg.n_kv_heads, hd)
    else:
        q = dense(p["wq"], x, "attn.wq").reshape(B, S, cfg.n_heads, hd)
        k = dense(p["wk"], x, "attn.wk").reshape(B, S, cfg.n_kv_heads, hd)
        v = dense(p["wv"], x, "attn.wv").reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(hd)
    window = cfg.sliding_window
    G = cfg.n_heads // cfg.n_kv_heads

    if cache is None:
        # GQA via k/v head-repeat: the grouped (Hkv, G) reshape is not
        # representable as a tiling of the model axis when Hkv < axis
        # size, and GSPMD silently replicates the whole attention block
        # (§Perf iteration 1). Repeating k/v to Hq heads keeps a clean
        # head axis that shards 16-way; the repeat itself is free on the
        # TP axis (each shard only materializes its own heads).
        if G > 1:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        q = constrain(q, "dp", None, "tp", None)
        k = constrain(k, "dp", None, "tp", None)
        v = constrain(v, "dp", None, "tp", None)
        if S >= flash_threshold:
            o = sdpa_flash(q, k, v, positions, positions, scale, window,
                           cfg.flash_q_chunk, cfg.flash_kv_chunk)
        else:
            o = sdpa(q, k, v, _mask(positions, positions, window), scale)
        o = constrain(o, "dp", None, "tp", None)
        new_cache = None
    elif block_table is not None:
        # paged decode (per-slot positions; S tokens land at rows
        # cache_pos..cache_pos+S-1): page-mapped write, block-table-
        # walking gather attention.
        ck = paged_cache_write(cache["k"], k, block_table, cache_pos)
        cv = paged_cache_write(cache["v"], v, block_table, cache_pos)
        new_cache = {"k": ck, "v": cv}
        o = kops.paged_attention(q, ck, cv, block_table, positions[:, 0],
                                 cache_pos, window=window, scale=scale)
    else:
        ck = _cache_write(cache["k"], k, cache_pos)
        cv = _cache_write(cache["v"], v, cache_pos)
        new_cache = {"k": ck, "v": cv}
        if S > 1:
            # prompt prefill (cache was empty at cache_pos=0): attend over
            # the fresh k/v directly — identical math, and it never runs
            # flash over the (possibly sequence-sharded) cache buffer.
            if G > 1:
                k = jnp.repeat(k, G, axis=2)
                v = jnp.repeat(v, G, axis=2)
            q = constrain(q, "dp", None, "tp", None)
            k = constrain(k, "dp", None, "tp", None)
            v = constrain(v, "dp", None, "tp", None)
            if S >= flash_threshold:
                o = sdpa_flash(q, k, v, positions, positions, scale, window,
                               cfg.flash_q_chunk, cfg.flash_kv_chunk)
            else:
                o = sdpa(q, k, v, _mask(positions, positions, window), scale)
            o = constrain(o, "dp", None, "tp", None)
        else:
            # single-token decode: grouped GQA against the cache (which
            # stays at Hkv heads — sharded on heads when divisible, else
            # on sequence; softmax/contraction over a sharded sequence
            # costs three small all-reduces). With per-slot cache_pos the
            # mask is (B,1,Smax): each slot attends to its own prefix.
            # _decode_mask also handles the hybrid ring buffer, where
            # cache_pos wraps modulo the window.
            msk = _decode_mask(positions, cache_pos, ck.shape[1], window)
            o = sdpa(q, ck, cv, msk, scale)
    return dense(p["wo"], o.reshape(B, S, -1), "attn.wo"), new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed KV cache, absorbed decode path
# --------------------------------------------------------------------------


def init_mla(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    return {
        "wq": init_linear(ks[0], d, H * (dn + dr), False, dtype),
        "w_dkv": init_linear(ks[1], d, dc, False, dtype),      # KV down-proj
        "w_kr": init_linear(ks[2], d, dr, False, dtype),       # shared rope key
        "w_uk": init_linear(ks[3], dc, H * dn, False, dtype),  # K up-proj
        "w_uv": init_linear(ks[4], dc, H * dv, False, dtype),  # V up-proj
        "wo": init_linear(ks[5], H * dv, d, False, dtype),
        "kv_norm": jnp.ones((dc,), dtype),
    }


def mla_attention(p, cfg, x, positions, cache=None, cache_pos=None,
                  block_table=None):
    """MLA. Cache stores the *compressed* c_kv + shared rope key — the
    paper-relevant serving trick (cache is kv_lora_rank + rope_dim wide).

    block_table: the cache is a paged pool (serve.paging) and this is a
    single-token decode — writes are page-mapped and the read gathers
    the slot's pages before the usual absorbed-latent scoring (pure-jax
    gather; the Pallas gather kernel covers the GQA path only, the MLA
    latent math stays in XLA — see docs/kernels.md)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q = dense(p["wq"], x, "attn.wq").reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(dense(p["w_dkv"], x, "attn.w_dkv"), p["kv_norm"], cfg.norm_eps)  # (B,S,dc)
    k_rope = apply_rope(dense(p["w_kr"], x, "attn.w_kr")[:, :, None, :], positions,
                        cfg.rope_theta)                                 # (B,S,1,dr)

    scale = 1.0 / math.sqrt(dn + dr)
    if cache is not None and block_table is not None:
        ckv_pool = paged_cache_write(cache["c_kv"], c_kv, block_table,
                                     cache_pos)
        kr_pool = paged_cache_write(cache["k_rope"], k_rope, block_table,
                                    cache_pos)
        new_cache = {"c_kv": ckv_pool, "k_rope": kr_pool}
        c_kv = gather_pages(ckv_pool, block_table)        # (B, V, dc)
        k_rope = gather_pages(kr_pool, block_table)       # (B, V, 1, dr)
        msk = _decode_mask(positions, cache_pos, c_kv.shape[1], 0)
    elif cache is not None:
        c_kv = _cache_write(cache["c_kv"], c_kv, cache_pos)
        k_rope = _cache_write(cache["k_rope"], k_rope, cache_pos)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        T = c_kv.shape[1]
        k_pos = jnp.arange(T)
        msk = _mask(positions, k_pos, 0) & _cache_valid(k_pos, cache_pos, S)
    else:
        new_cache = None
        T = S
        k_pos = positions
        msk = _mask(positions, k_pos, 0)

    w_uk = p["w_uk"]["w"].astype(x.dtype).reshape(dc, H, dn)
    w_uv = p["w_uv"]["w"].astype(x.dtype).reshape(dc, H, dv)
    # absorbed scores: q_nope @ W_uk gives per-head query in latent space,
    # scored directly against the compressed cache (no K materialization).
    q_lat = jnp.einsum("bshd,chd->bshc", q_nope, w_uk)           # (B,S,H,dc)
    s = jnp.einsum("bshc,btc->bhst", q_lat, c_kv).astype(jnp.float32)
    s += jnp.einsum("bshd,btxd->bhst", q_rope,
                    k_rope.astype(q_rope.dtype)).astype(jnp.float32)
    s *= scale
    s = jnp.where(msk[:, None] if msk.ndim == 3 else msk[None, None], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btc->bshc", prob, c_kv)             # (B,S,H,dc)
    o = jnp.einsum("bshc,chd->bshd", o_lat, w_uv)                # absorbed V up
    return dense(p["wo"], o.reshape(B, S, H * dv), "attn.wo"), new_cache


# --------------------------------------------------------------------------
# cross-attention (VLM layers) — gated, non-causal, image K/V cacheable
# --------------------------------------------------------------------------


def init_cross_attention(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, False, dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, False, dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, False, dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, False, dtype),
        "gate": jnp.zeros((), dtype),
        "q_norm": jnp.ones((hd,), dtype),
        "k_norm": jnp.ones((hd,), dtype),
    }


def cross_attention(p, cfg, x, image_kv):
    """image_kv: (k, v) precomputed from image embeddings, each
    (B, n_img, Hkv, D). Gated output (tanh gate, llama-3.2-vision style)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = dense(p["wq"], x, "xattn.wq").reshape(B, S, cfg.n_heads, hd)
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = image_kv
    n_img = k.shape[1]
    msk = jnp.ones((S, n_img), bool)
    o = sdpa(q, k, v, msk, 1.0 / math.sqrt(hd))
    return jnp.tanh(p["gate"]).astype(x.dtype) * dense(p["wo"], o.reshape(B, S, -1), "xattn.wo")


def image_kv(p, cfg, image_embeds):
    """Project stubbed image patch embeddings once (prefill / per-batch)."""
    B, n_img, _ = image_embeds.shape
    hd = cfg.head_dim
    k = dense(p["wk"], image_embeds, "xattn.wk").reshape(B, n_img, cfg.n_kv_heads, hd)
    k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    v = dense(p["wv"], image_embeds, "xattn.wv").reshape(B, n_img, cfg.n_kv_heads, hd)
    return k, v


# --------------------------------------------------------------------------
# FFN — SwiGLU
# --------------------------------------------------------------------------


def init_ffn(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], d_model, d_ff, False, dtype),
        "w_up": init_linear(ks[1], d_model, d_ff, False, dtype),
        "w_down": init_linear(ks[2], d_ff, d_model, False, dtype),
    }


def ffn(p, x, prefix="ffn"):
    if _use_merged(p, "wgu"):
        d_ff = p["wgu"]["qu_t"].shape[-1]   # gate/up share d_out
        g, u = dense_merged(p["wgu"], x,
                            (prefix + ".w_gate", prefix + ".w_up"),
                            (d_ff, d_ff))
    else:
        g = dense(p["w_gate"], x, prefix + ".w_gate")
        u = dense(p["w_up"], x, prefix + ".w_up")
    g = constrain(g, "dp", None, "tp")
    u = constrain(u, "dp", None, "tp")
    return dense(p["w_down"], silu(g) * u, prefix + ".w_down")


# --------------------------------------------------------------------------
# MoE — sort-based capacity dispatch (production) + dense oracle (tests)
# --------------------------------------------------------------------------


def init_moe(key, cfg, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    std = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E), jnp.float32) * std
                         ).astype(jnp.float32)},   # router stays FP32
        "w_gate": {"w": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * std).astype(dtype)},
        "w_up": {"w": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * std).astype(dtype)},
        "w_down": {"w": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                         * (1.0 / math.sqrt(f))).astype(dtype)},
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def _route(p, cfg, xf):
    logits = (xf.astype(jnp.float32) @ p["router"]["w"])        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi, probs


def _dp_groups(T: int) -> int:
    """Dispatch group count == data-parallel degree (1 when no policy)."""
    pol = _current_act_shard()
    if pol is None or pol.get("dp") is None:
        return 1
    g = _axis_len(pol["mesh"], pol["dp"])
    return g if T % g == 0 else 1


def _dispatch_group(xg, wg, ig, E: int, capacity: int):
    """Sort-based capacity dispatch for one token group.
    xg (t, d); wg/ig (t, k). Returns (buf (E, cap, d), dest, st, sw, keep)."""
    t, d = xg.shape
    k = ig.shape[-1]
    flat_e = ig.reshape(-1)                                     # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = wg.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E))
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < capacity
    dest = jnp.where(keep, se * capacity + rank, E * capacity)  # overflow->trash
    buf = jnp.zeros((E * capacity + 1, d), xg.dtype).at[dest].set(
        xg[st] * keep[:, None].astype(xg.dtype))
    return buf[: E * capacity].reshape(E, capacity, d), dest, st, sw, keep


def _combine_group(ob, dest, st, sw, keep, t: int):
    """(E*cap, d) expert outputs -> (t, d) token outputs for one group."""
    d = ob.shape[-1]
    ob = jnp.concatenate([ob, jnp.zeros((1, d), ob.dtype)], axis=0)
    contrib = ob[dest] * (sw * keep).astype(ob.dtype)[:, None]
    return jnp.zeros((t, d), ob.dtype).at[st].add(contrib)


def moe(p, cfg, x, capacity: Optional[int] = None):
    """Capacity-bounded sort-based MoE with *grouped* dispatch.

    Tokens are dispatched within data-parallel groups (GShard pattern):
    each group builds its own (E, cap_local, d) buffer with purely local
    scatters, and the group->expert transpose of the sharded dim is the
    all-to-all GSPMD emits. Without grouping, the single global scatter
    is unpartitionable and the whole (E, cap_global, d) buffer
    replicates on every device (§Perf iteration: 306 GB -> fits)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.n_experts_per_tok
    E = cfg.n_experts
    G = _dp_groups(T)
    t = T // G
    xf = x.reshape(T, d)
    topw, topi, _ = _route(p, cfg, xf)

    if capacity is None:
        capacity = int(math.ceil(t * k / E * cfg.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)

    xg = constrain(xf.reshape(G, t, d), "dp", None, None)
    wg = topw.reshape(G, t, k)
    ig = topi.reshape(G, t, k)
    buf, dest, st, sw, keep = jax.vmap(
        lambda a, b, c: _dispatch_group(a, b, c, E, capacity))(xg, wg, ig)

    # (G, E, cap, d) -> (E, G*cap, d): dp-shard -> expert-shard transpose
    # (the all-to-all); the token dim stays dp-sharded so the expert
    # buffer is 2-axis sharded — E on model, tokens on data.
    eb = constrain(buf.transpose(1, 0, 2, 3).reshape(E, G * capacity, d),
                   "tp", "dp", None)
    h = silu(dense_expert(p["w_gate"], eb, "moe.w_gate")) \
        * dense_expert(p["w_up"], eb, "moe.w_up")
    h = constrain(h, "tp", "dp", None)
    ob = dense_expert(p["w_down"], h, "moe.w_down")     # (E, G*cap, d)
    ob_g = constrain(
        ob.reshape(E, G, capacity, d).transpose(1, 0, 2, 3),
        "dp", None, None, None).reshape(G, E * capacity, d)
    yf = jax.vmap(lambda o, de, s, w_, kp: _combine_group(o, de, s, w_,
                                                          kp, t))(
        ob_g, dest, st, sw, keep)
    y = yf.reshape(B, S, d).astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + ffn(p["shared"], x, prefix="moe.shared")
    return y


def moe_dense_oracle(p, cfg, x):
    """Reference: run every expert on every token (tests only)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    topw, topi, _ = _route(p, cfg, xf)
    w_full = jnp.zeros((xf.shape[0], cfg.n_experts), jnp.float32)
    w_full = w_full.at[jnp.arange(xf.shape[0])[:, None], topi].set(topw)
    h = jnp.einsum("td,edf->tef", xf, p["w_gate"]["w"].astype(xf.dtype))
    u = jnp.einsum("td,edf->tef", xf, p["w_up"]["w"].astype(xf.dtype))
    o = jnp.einsum("tef,efd->ted", silu(h) * u, p["w_down"]["w"].astype(xf.dtype))
    y = jnp.einsum("ted,te->td", o, w_full.astype(o.dtype)).reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + ffn(p["shared"], x)
    return y


# --------------------------------------------------------------------------
# Mamba2 (SSD) — chunked parallel form + O(1) recurrent decode step
# --------------------------------------------------------------------------


def init_mamba2(key, cfg, dtype=jnp.bfloat16):
    """Mamba2 mixer with *split* input projections (z / x / B / C / dt
    instead of the reference fused in_proj). Depthwise conv is per-channel
    so splitting is exact; the split is what makes model-axis tensor
    parallelism possible on TPU — x/z (and thus the SSD head dim) shard on
    ``model`` while the small B/C/dt streams stay replicated (DESIGN.md
    §3/§4)."""
    ks = jax.random.split(key, 7)
    d, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    gn = g * n
    p = {
        "wz": init_linear(ks[0], d, di, False, dtype),
        "wx": init_linear(ks[1], d, di, False, dtype),
        "wB": init_linear(ks[2], d, gn, False, dtype),
        "wC": init_linear(ks[3], d, gn, False, dtype),
        "wdt": init_linear(ks[4], d, H, False, dtype),
        "out_proj": init_linear(ks[5], di, d, False, dtype),
        "conv_x": (jax.random.normal(ks[6], (cfg.ssm_conv, di),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_B": (jax.random.normal(ks[6], (cfg.ssm_conv, gn),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_C": (jax.random.normal(ks[6], (cfg.ssm_conv, gn),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (jax.random.uniform(ks[6], (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))),
        "norm_w": jnp.ones((di,), dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv via K shifted adds. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        y = y + pad[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk):
    """Chunked state-space-dual scan (Mamba2 Alg. from arXiv:2405.21060).

    xh: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) negative,
    Bm/Cm: (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    L = min(chunk, S)
    S0 = S
    if S % L:
        # zero-pad to a chunk multiple: padded steps have dt=0, so they
        # neither decay the state (exp(0)=1) nor inject input — exact.
        pad = L - S % L
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // L

    xc = xh.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, L, G, N), rep, axis=3)   # (B,nc,L,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, L, G, N), rep, axis=3)

    dA = dtc * A[None, None, None, :]                            # (B,nc,L,H) <=0
    dA_cs = jnp.cumsum(dA, axis=2)                               # inclusive

    # --- intra-chunk (block-diagonal "attention") -------------------------
    # decay L[i,j] = exp(dA_cs[i] - dA_cs[j]) for j<=i
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]     # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bclhn,bcmhn->bclmh", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    w_ij = scores * decay * dtc[:, :, None, :, :]                # dt_j factor
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w_ij, xc.astype(jnp.float32))

    # --- chunk summary states --------------------------------------------
    seg = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)                   # decay to end
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                        (seg * dtc).astype(jnp.float32),
                        Bc.astype(jnp.float32), xc.astype(jnp.float32))

    # --- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                    # (B,nc,H)

    def body(carry, inp):
        st_prev = carry                                          # (B,H,P,N)
        st_c, dec = inp                                          # (B,H,P,N),(B,H)
        new = st_prev * dec[:, :, None, None] + st_c
        return new, st_prev

    st0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final, prevs = jax.lax.scan(
        body, st0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prevs = prevs.transpose(1, 0, 2, 3, 4)                       # (B,nc,H,P,N)

    inter_decay = jnp.exp(dA_cs)                                 # (B,nc,L,H)
    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                         Cc.astype(jnp.float32), prevs, inter_decay)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y[:, :S0], final


def _mamba_streams(p, x):
    """Project the five input streams (taps named per linear)."""
    z = dense(p["wz"], x, "mixer.wz")
    xs = dense(p["wx"], x, "mixer.wx")
    Bm = dense(p["wB"], x, "mixer.wB")
    Cm = dense(p["wC"], x, "mixer.wC")
    dt = dense(p["wdt"], x, "mixer.wdt")
    return z, xs, Bm, Cm, dt


def _conv_step(buf, new, w, b):
    """One-token depthwise causal conv from a (B, K-1, C) ring buffer.
    new: (B, 1, C). Returns (y (B, C) f32 pre-activation, new buffer)."""
    cat = jnp.concatenate([buf, new.astype(buf.dtype)], axis=1)   # (B,K,C)
    y = (cat.astype(jnp.float32) * w.astype(jnp.float32)[None]).sum(1) \
        + b.astype(jnp.float32)
    return y, cat[:, 1:]


def mamba2(p, cfg, x, state=None):
    """Mamba2 mixer. state: None (training / full-seq) or dict with
    'ssm' (B,H,P,N) f32 and conv ring buffers for decode."""
    B, S, d = x.shape
    di, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    z, xs, Bm, Cm, dt = _mamba_streams(p, x)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                     # (H,) < 0

    if state is None:
        xs = silu(_causal_conv(xs, p["conv_x"], p["conv_bx"]))
        Bm = silu(_causal_conv(Bm, p["conv_B"], p["conv_bB"]))
        Cm = silu(_causal_conv(Cm, p["conv_C"], p["conv_bC"]))
        xs = constrain(xs.reshape(B, S, H, P), "dp", None, "tp", None)
        Bm = Bm.reshape(B, S, g, n)
        Cm = Cm.reshape(B, S, g, n)
        y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
        y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
        new_state = None
    else:
        # single-token recurrent step (S == 1)
        xs1, cx = _conv_step(state["conv_x"], xs, p["conv_x"], p["conv_bx"])
        Bm1, cB = _conv_step(state["conv_B"], Bm, p["conv_B"], p["conv_bB"])
        Cm1, cC = _conv_step(state["conv_C"], Cm, p["conv_C"], p["conv_bC"])
        xs = silu(xs1).reshape(B, H, P)
        Bm = jnp.repeat(silu(Bm1).reshape(B, g, n), H // g, axis=1)
        Cm = jnp.repeat(silu(Cm1).reshape(B, g, n), H // g, axis=1)
        dt1 = dt[:, 0]                                           # (B,H)
        dA = jnp.exp(dt1 * A[None, :])                           # (B,H)
        ssm = state["ssm"] * dA[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt1, Bm, xs)
        y = jnp.einsum("bhn,bhpn->bhp", Cm, ssm)
        y = y + p["D"][None, :, None] * xs
        y = y[:, None]                                           # (B,1,H,P)
        new_state = {"ssm": ssm, "conv_x": cx, "conv_B": cB, "conv_C": cC}

    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * silu(z), p["norm_w"], cfg.norm_eps)
    return dense(p["out_proj"], y, "mixer.out_proj"), new_state
