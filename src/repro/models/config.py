"""Model configuration shared by every architecture family.

One frozen (hashable -> jit-static) dataclass covers the whole assigned
pool: dense / MoE / MLA / SSM / hybrid / VLM / audio backbones. Family-
specific behaviour is driven by feature fields, not subclasses, so the
transformer assembly stays a single code path that `jax.lax.scan`s over a
stacked layer pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # defaults to d_model // n_heads
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False           # qwen1.5-style bias on q/k/v
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    first_k_dense: int = 0           # deepseek: leading dense-FFN layers
    dense_d_ff: int = 0              # hidden size of those dense layers
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0            # 0 => standard GQA attention
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    ssm_groups: int = 1

    # --- hybrid (zamba2): one *shared* attention block every k SSM layers
    attn_every: int = 0

    # --- attention windowing (lets the hybrid run 500k decode) ---
    sliding_window: int = 0          # 0 => full causal

    # --- VLM: every k-th layer is a gated cross-attention layer ---
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # --- audio (musicgen): multi-codebook token streams ---
    n_codebooks: int = 0

    # --- execution policy ---
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 2048           # sequence-chunked cross-entropy; 0 = off
    grad_accum: int = 1              # microbatch accumulation inside train_step
    flash_threshold: int = 4096      # use flash-chunked attention at S >= this
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 1024

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    # ---- derived ----
    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def is_ssm_layer_stack(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        # total depthwise-conv channels across the x/B/C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def qk_head_dim(self) -> int:
        if self.is_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        import repro.models.transformer as T
        return T.count_params(self)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Produce a reduced smoke-test config of the same family."""
    return dataclasses.replace(cfg, **overrides)
