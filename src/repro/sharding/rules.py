"""Per-family PartitionSpec rules (DP / FSDP / TP / EP / pod axis).

Mesh contract (see ``repro.launch.mesh``): axes are ``("data", "model")``
single-pod or ``("pod", "data", "model")`` multi-pod. Policy:

- **batch**  is sharded over every data-parallel axis (``pod`` + ``data``).
- **params** are FSDP-sharded over ``data`` on one dim and tensor-parallel
  over ``model`` on the other (Megatron pairing: column-parallel
  wq/wk/wv/w_gate/w_up, row-parallel wo/w_down); replicated across pods
  (pure DP on the DCN-mapped ``pod`` axis; gradient all-reduce is
  hierarchical).
- **MoE experts** are expert-parallel on ``model``; router stays
  replicated (it is <0.01% of params).
- **Mamba2 mixers** use split z/x/B/C/dt projections (see
  ``layers.init_mamba2``): the wide z/x streams are TP-sharded on
  ``model`` (columns == SSD heads, so the chunked SSD shards by head);
  B/C/dt are small and replicated; out_proj is row-parallel.
- **Quantized linears** (packed low-rank binary) follow the same
  Megatron pairing as their FP counterparts: column-parallel projections
  (wq/wk/wv/w_gate/w_up, mamba wz/wx) shard U with its s1 scale on
  d_out over ``model`` (V/s2 replicated — each device runs the whole
  fused kernel on its output shard, no collective); row-parallel
  projections (wo/w_down/out_proj) shard V on packed d_in with its s2
  scale (U/s1 replicated — partial outputs finish with ONE psum).
  ``qv_sharded`` additionally r-shards V on column-parallel linears
  (residency optimization for training/FSDP; the serving launch keeps V
  replicated, see :data:`SERVE`).
- **KV caches**: kv-head dim on ``model`` when divisible, else the
  sequence dim (GSPMD handles softmax/contraction over a sharded
  sequence with small all-reduces); batch on data axes.

Every rule checks divisibility against the mesh axis size and falls back
to ``None`` (replicated) — uneven shardings are never emitted, so
``.lower().compile()`` is deterministic across all 10 archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel axes, outermost first ((pod, data) or (data,))."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Tunable knobs, exercised by the §Perf hillclimb. qv_sharded
    defaults ON after §Perf iteration 4 (r-dim TP of the packed V factor
    — halves quantized-param residency for ~1ms of extra all-gather);
    set False to reproduce the paper-faithful replicated-V baseline."""
    fsdp: bool = True              # shard params over `data` (ZeRO-3 style)
    fsdp_pod: bool = False         # extend FSDP over the pod axis too
    qv_sharded: bool = True        # shard packed V on r (beyond-paper TP)
    seq_shard_cache: bool = True   # allow sequence-sharded KV caches


DEFAULT = ShardingPolicy()

# Serving placement (InferenceEngine): tensor-parallel only. No FSDP —
# there is no optimizer state to amortize and decode activations are
# tiny — and V stays replicated so every device can run the whole fused
# kernel on its local shard (the paper-faithful baseline layout).
SERVE = ShardingPolicy(fsdp=False, qv_sharded=False)

# Megatron pairing for quantized linears, keyed on the parent linear
# name (the packed leaves live one level below, e.g. layers/attn/wq/qu_t).
# Column-parallel: output dim sharded, input replicated. Row-parallel:
# input dim sharded, output reduced with one psum. Shared with
# kernels.ops, which mirrors this pairing in its shard_map launch.
COL_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "wz", "wx",
                "wqkv", "wgu")
ROW_PARALLEL = ("wo", "w_down", "out_proj")


def tp_role(name) -> Optional[str]:
    """'col' | 'row' | None for a linear's name. Accepts bare parent
    keys ('wo'), rule paths ('layers/attn/wo') and tap names
    ('attn.wo')."""
    if not name:
        return None
    leaf = str(name).replace(".", "/").rsplit("/", 1)[-1]
    if leaf in COL_PARALLEL:
        return "col"
    if leaf in ROW_PARALLEL:
        return "row"
    return None


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax releases (moved out of jax.experimental;
    check_rep renamed check_vma). Replication checks are disabled: the
    kernel launches below psum explicitly where reduction is needed."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _fit(dim: int, axis, mesh: Mesh):
    """axis if dim divides evenly over it, else None."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 \
        else None


class _Ruler:
    def __init__(self, cfg, mesh: Mesh, policy: ShardingPolicy):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        self.tp = "model" if "model" in mesh.axis_names else None
        fsdp: Any = None
        if policy.fsdp and "data" in mesh.axis_names:
            fsdp = ("pod", "data") if (policy.fsdp_pod
                                       and "pod" in mesh.axis_names) else "data"
        self.fsdp = fsdp

    # -- helpers ----------------------------------------------------------

    def _two(self, shape, a0, a1):
        """Spec for the trailing 2 dims; leading dims -> None (scan axes)."""
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _fit(shape[-2], a0, self.mesh),
                 _fit(shape[-1], a1, self.mesh))

    def _one(self, shape, a0):
        lead = (None,) * (len(shape) - 1)
        return P(*lead, _fit(shape[-1], a0, self.mesh))

    # -- the rule table ----------------------------------------------------

    def spec(self, path: str, leaf) -> P:
        shape = leaf.shape
        name = path.rsplit("/", 1)[-1]
        cfg, mesh = self.cfg, self.mesh
        tp, fsdp = self.tp, self.fsdp

        if len(shape) == 0:
            return P()

        # ---- quantized leaves (packed low-rank binary) --------------------
        # Leading dims are scan stacks (layers / vlm groups) and stay
        # unsharded EXCEPT the expert dim of MoE leaves, which is
        # expert-parallel on the model axis (per-expert factors whole).
        if name in ("qu_t", "qv", "s1", "s2", "rmask"):
            base = 2 if name in ("qu_t", "qv") else 1
            lead = len(shape) - base
            spec = [None] * len(shape)
            # expert-parallel applies to true expert stacks only; the
            # dense *shared*-expert FFN under /moe/ is a plain linear
            # and takes the Megatron col/row pairing below (matching
            # the role layers.dense launches it with).
            expert = ("/moe/" in path or path.startswith("moe/")) \
                and "/shared/" not in path
            parent = path.split("/")[-2] if "/" in path else ""
            role = tp_role(parent)
            if expert and lead >= 1:
                spec[lead - 1] = _fit(shape[lead - 1], tp, mesh)
            elif role == "row":
                # row-parallel: V/s2 shard on (packed) d_in; U/s1 stay
                # replicated and the launch finishes with one psum. The
                # s2 check mirrors qv's packed dim so the pair never
                # shards inconsistently (kp % 32N == 0 <=> kp//32 % N).
                if name == "qv":                  # (..., d_in//32, r)
                    spec[-2] = _fit(shape[-2], tp, mesh)
                elif name == "s2":                # (..., d_in)
                    spec[-1] = tp if tp is not None and \
                        shape[-1] % (32 * _axis_size(mesh, tp)) == 0 \
                        else None
            elif role == "col":
                # column-parallel: U/s1 shard on d_out, shard-local
                # launch. Role-less packed linears (MLA w_dkv/w_kr,
                # mamba wB/wC/wdt) stay fully replicated — their FP
                # counterparts are not TP-sharded either, and the
                # kernel launch in ops dispatches them single-device,
                # so placement and launch always agree.
                if name == "qu_t":            # (..., r//32, d_out)
                    spec[-1] = _fit(shape[-1], tp, mesh)
                elif name == "qv" and self.policy.qv_sharded:
                    spec[-1] = _fit(shape[-1], tp, mesh)  # (.., K//32, r)
                elif name == "s1":
                    spec[-1] = _fit(shape[-1], tp, mesh)
            return P(*spec)
        # STE latents (block reconstruction runs single-host; replicate)
        if name in ("lu", "lv"):
            return P(*(None,) * len(shape))

        # ---- embeddings / head --------------------------------------------
        if name == "embed":
            if cfg.family == "audio":  # (K, V, d)
                return P(None, _fit(shape[-2], tp, mesh),
                         _fit(shape[-1], fsdp, mesh))
            return self._two(shape, tp, fsdp)        # (V, d)
        if "lm_head" in path and name == "w":        # (d, V)
            return self._two(shape, fsdp, tp)

        # ---- MoE -----------------------------------------------------------
        if "/moe/" in path or path.startswith("moe/"):
            if "router" in path:
                return P(*(None,) * len(shape))
            if "shared" in path:                     # dense shared expert FFN
                if name == "w" and ("w_down" in path):
                    return self._two(shape, tp, fsdp)
                if name == "w":
                    return self._two(shape, fsdp, tp)
                return self._one(shape, tp) if name == "b" \
                    else P(*(None,) * len(shape))
            if name == "w":                          # (..., E, d, f) experts
                lead = (None,) * (len(shape) - 3)
                ep = _fit(shape[-3], tp, mesh)
                return P(*lead, ep, _fit(shape[-2], fsdp, mesh), None)

        # ---- attention (incl. MLA / cross-attn) ----------------------------
        if name == "w":
            col = any(s in path for s in
                      ("/wq/", "/wk/", "/wv/", "/w_uk/", "/w_uv/",
                       "/w_gate/", "/w_up/"))
            row = any(s in path for s in ("/wo/", "/w_down/"))
            if col:
                # MLA up-projections contract over the small lora rank; only
                # the wide output dim is TP-sharded.
                a0 = fsdp if not any(s in path for s in ("/w_uk/", "/w_uv/")) \
                    else None
                return self._two(shape, a0, tp)
            if row:
                return self._two(shape, tp, fsdp)
            if any(s in path for s in ("/w_dkv/", "/w_kr/")):
                return self._two(shape, fsdp, None)
            # mamba2 split projections: z/x wide streams are TP-sharded
            # (columns == SSD heads); B/C/dt streams stay replicated.
            if any(s in path for s in ("/wz/", "/wx/")):
                return self._two(shape, fsdp, tp)
            if any(s in path for s in ("/wB/", "/wC/", "/wdt/")):
                return self._two(shape, fsdp, None)
            if "out_proj" in path:                   # row-parallel
                return self._two(shape, tp, fsdp)
        if name == "b":
            col = any(s in path for s in ("/wq/", "/wk/", "/wv/"))
            return self._one(shape, tp if col else None)

        # ---- mamba conv / gated-norm ride with the TP-sharded d_inner ------
        if name in ("conv_x", "conv_bx") or (name == "norm_w"
                                             and "mixer" in path):
            return self._one(shape, tp)

        # ---- everything else (norms, gates, conv, SSM params) -------------
        return P(*(None,) * len(shape))


def _path_str(kp) -> str:
    parts = []
    for p in kp:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(cfg, params, mesh: Mesh,
                 policy: ShardingPolicy = DEFAULT):
    """PartitionSpec tree mirroring `params` (works on SDS trees too)."""
    ruler = _Ruler(cfg, mesh, policy)
    return jax.tree_util.tree_map_with_path(
        lambda kp, l: ruler.spec(_path_str(kp), l), params)


def batch_pspecs(cfg, batch, mesh: Mesh, grad_accum: int = 1):
    """Batch dim -> all DP axes; everything else replicated. With
    grad_accum > 1 the leading dim is the microbatch scan axis
    (replicated) and the *second* dim is the sharded batch."""
    dp = data_axes(mesh)
    bdim = 1 if grad_accum > 1 else 0

    def spec(leaf):
        if len(leaf.shape) <= bdim:
            return P(*(None,) * len(leaf.shape))
        b = leaf.shape[bdim]
        a = dp if dp and b % _axis_size(mesh, dp) == 0 else None
        out = [None] * len(leaf.shape)
        out[bdim] = a
        return P(*out)

    return jax.tree.map(spec, batch)


def cache_pspecs(cfg, cache, mesh: Mesh,
                 policy: ShardingPolicy = DEFAULT, paged: bool = False):
    """KV / SSM cache sharding: batch on DP axes; heads (or sequence) on
    model. Cache leaves carry a leading layer-stack dim.

    paged: the attention leaves are page pools (serve.paging —
    ``(L, n_pages, page_size, Hkv, hd)`` instead of a batch-indexed
    rectangle). They shard the kv-head dim on ``model`` exactly like
    the rectangular pool; there is no sequence-dim fallback (the page
    dims must stay whole for block-table addressing), so non-divisible
    head counts replicate — matching the replicated single-device
    launch fallback of ``kernels.ops.paged_attention``. State leaves
    (SSM / conv / image KV) stay batch-indexed and keep their specs."""
    dp = data_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None

    def spec(path: str, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = path.rsplit("/", 1)[-1]
        # (L, B, ...) — batch at dim 1
        def b_axis(i=1):
            return dp if dp and shape[i] % _axis_size(mesh, dp) == 0 else None

        if paged and name in ("k", "v", "c_kv", "k_rope") \
                and "cross_kv" not in path:
            if name in ("k", "v"):        # (L[, G], NP, PS, Hkv, hd)
                lead = len(shape) - 4
                return P(*((None,) * lead), None, None,
                         _fit(shape[-2], tp, mesh), None)
            return P(*(None,) * len(shape))   # MLA pools: latent dims small

        if name in ("k", "v"):            # (L[, G], B, S, Hkv, hd)
            lead = len(shape) - 4            # layer-stack dims before batch
            h_ax = _fit(shape[-2], tp, mesh)
            s_ax = None
            if h_ax is None and policy.seq_shard_cache:
                s_ax = _fit(shape[-3], tp, mesh)
            return P(*((None,) * lead), b_axis(lead), s_ax, h_ax, None)
        if name == "c_kv":                # (L, B, S, dc)
            return P(None, b_axis(), _fit(shape[-2], tp, mesh), None)
        if name == "k_rope":              # (L, B, S, 1, dr)
            return P(None, b_axis(), _fit(shape[-3], tp, mesh), None, None)
        if name == "ssm":                 # (L, B, H, P, N)
            return P(None, b_axis(), _fit(shape[-3], tp, mesh), None, None)
        if name == "conv_x":              # (L, B, K-1, d_inner)
            return P(None, b_axis(), None, _fit(shape[-1], tp, mesh))
        if name in ("conv_B", "conv_C"):  # small replicated streams
            return P(None, b_axis(), None, None)
        return P(*(None,) * len(shape))

    return jax.tree_util.tree_map_with_path(
        lambda kp, l: spec(_path_str(kp), l), cache)


def replicate_specs(tree):
    return jax.tree.map(lambda l: P(*(None,) * len(l.shape)), tree)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
