"""Partition-spec rules for every parallelism axis (DP / FSDP / TP /
EP), shared by training cells, the multi-pod dry-run and the
tensor-parallel serving engine — see :mod:`repro.sharding.rules` for
the mesh contract and the Megatron col/row pairing of packed
(quantized) linears, and docs/serving.md for how the engine consumes
these placements.
"""
from repro.sharding.rules import (  # noqa: F401
    batch_pspecs, cache_pspecs, data_axes, param_pspecs, replicate_specs,
    shard_map_compat, to_shardings, tp_role, ShardingPolicy, DEFAULT, SERVE)

__all__ = [
    "ShardingPolicy", "DEFAULT", "SERVE",
    "param_pspecs", "batch_pspecs", "cache_pspecs", "replicate_specs",
    "to_shardings", "data_axes", "tp_role", "shard_map_compat",
]
