from repro.sharding.rules import (  # noqa: F401
    batch_pspecs, cache_pspecs, data_axes, param_pspecs, replicate_specs,
    ShardingPolicy)
