"""Slot scheduling for the continuous-batching serving engine.

The engine owns a fixed pool of ``n_slots`` decode slots over one
persistent cache. This module holds the host-side bookkeeping — which
request occupies which slot, what is still queued, when admission is
allowed — plus the cache-tree helpers that make a slot a first-class
unit on device:

- :func:`bucket_length` — power-of-two prompt-length buckets so the
  per-slot prefill compiles once per bucket, not once per distinct
  prompt length (or per wave).
- :func:`cache_insert_slot` — scatter a freshly prefilled single-slot
  cache into one slot of the pooled cache (admission mid-flight).
- :func:`cache_select_active` — keep finished slots' cache entries
  bit-identical until they are refilled (active-slot masking), which
  also freezes recurrent SSM state for inactive slots.

Admission policies:

- ``"continuous"`` — any freed slot is refilled immediately from the
  queue (the default; what the paper's serving claim needs).
- ``"wave"`` — a new batch is admitted only once every slot is free;
  this reproduces the drain-then-refill schedule of the legacy
  ``BatchServer`` and exists for the compatibility shim + benchmarks.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ADMISSION_POLICIES = ("continuous", "wave")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                # (S,) or (S, K) token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: Optional[np.ndarray] = None
    # wall-clock budget in seconds, measured from submit(). None = no
    # deadline. The engine expires the request (terminal status
    # "expired") at the first tick boundary past the deadline, whether
    # it is still queued or mid-decode — see docs/serving.md §Failure
    # handling.
    deadline_s: Optional[float] = None


def bucket_length(n: int, max_len: int, floor: int = 8) -> int:
    """Smallest power-of-two bucket >= n (floored, capped at max_len)."""
    b = max(floor, 1)
    while b < n:
        b <<= 1
    return max(min(b, max_len), n)


def pick_preemption_victim(candidates: List[Tuple[int, int, int]]) -> int:
    """Cost-aware preemption policy: given ``(slot, recompute_cost,
    admission_step)`` triples for every active slot, pick the victim
    whose eviction wastes the least work — the minimum recompute cost
    (tokens its resume must re-prefill that the prefix index does not
    already cover). Ties break youngest-first (largest admission step,
    then slot), which degenerates to the pre-prefix-cache youngest-
    first policy when every cost is equal."""
    assert candidates, "no active slot to preempt"
    return min(candidates, key=lambda t: (t[1], -t[2], -t[0]))[0]


def _batch_axis(path) -> int:
    # VLM self-attn caches are stacked (groups, per-1, batch, ...);
    # every other cache leaf carries batch at axis 1.
    if path and getattr(path[0], "key", None) == "self_layers":
        return 2
    return 1


def cache_insert_slot(pool, single, slot):
    """Insert `single` (a batch=1 cache pytree) into slot `slot` of the
    pooled cache. Leaves below rank 2 (e.g. the hybrid window size) are
    batch-free metadata and kept from the pool. Pure jnp scatters, so a
    mesh-placed pool (tensor-parallel engine: kv-heads / sequence dim
    sharded on `model`, see quant.surgery.place_cache_on_mesh) is
    partitioned by GSPMD — the slot stays a batch-dim index and never
    crosses the sharded dims."""
    def ins(path, b, s):
        if jnp.ndim(b) < 2:
            return b
        start = [0] * jnp.ndim(b)
        start[_batch_axis(path)] = slot
        return jax.lax.dynamic_update_slice(b, s.astype(b.dtype),
                                            tuple(start))
    return jax.tree_util.tree_map_with_path(ins, pool, single)


def cache_select_active(new, old, active):
    """Per-slot select: active slots take the freshly written cache,
    finished/empty slots keep their old entries bit-identical — a
    decode step is a no-op for them until the slot is refilled. The
    `active` mask broadcasts along the batch axis only, so the select
    is elementwise-local under any cache sharding (no resharding in the
    tensor-parallel engine's decode step)."""
    def sel(path, n, o):
        if jnp.ndim(n) < 2:
            return n
        shape = [1] * jnp.ndim(n)
        shape[_batch_axis(path)] = -1
        return jnp.where(active.reshape(shape), n, o)
    return jax.tree_util.tree_map_with_path(sel, new, old)


class SlotScheduler:
    """Host-side slot allocator: a queue of pending requests and a
    fixed pool of slots, with pluggable admission policy."""

    def __init__(self, n_slots: int, admission: str = "continuous"):
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{ADMISSION_POLICIES}, got {admission!r}")
        self.n_slots = n_slots
        self.admission = admission
        self.slots: List[Optional[int]] = [None] * n_slots  # uid per slot
        self.pending: Deque = deque()

    def submit(self, item) -> None:
        self.pending.append(item)

    def requeue(self, item) -> None:
        """Return a preempted item to the *front* of the queue (it was
        admitted once already; FIFO order is preserved for the rest)."""
        self.pending.appendleft(item)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free_slots())

    def admit_batch(self, gate=None) -> List[Tuple[int, object]]:
        """Pair pending requests with slots per the admission policy.
        Marks the returned slots occupied.

        gate: optional ``gate(item) -> bool`` resource check (the paged
        engine's free-page watermark). Admission stops at the first
        gated-out item — strict FIFO, so a big request at the head
        waits for pages instead of being starved by later small ones."""
        free = self.free_slots()
        if not self.pending or not free:
            return []
        if self.admission == "wave" and len(free) != self.n_slots:
            return []                      # wait for the wave to drain
        out = []
        for slot in free:
            if not self.pending:
                break
            if gate is not None and not gate(self.pending[0]):
                break                      # head-of-line: wait for pages
            item = self.pending.popleft()
            self.slots[slot] = getattr(item, "uid", -1)
            out.append((slot, item))
        return out

    def reap(self, should_drop) -> List[object]:
        """Remove queued items for which ``should_drop(item)`` is true
        (cancelled / past-deadline requests) and return them, preserving
        the queue order of the survivors. The engine finalizes the
        dropped handles; queued items own no pages, so there is nothing
        else to free."""
        dropped = [it for it in self.pending if should_drop(it)]
        if dropped:
            self.pending = deque(it for it in self.pending
                                 if not should_drop(it))
        return dropped

    def release(self, slot: int) -> None:
        self.slots[slot] = None
