"""Deterministic fault injection for the serving engine
(docs/serving.md §Failure handling).

A :class:`FaultPlan` is a *schedule* of faults — each a
:class:`Fault` record naming a kind, the engine step it arms at, and
(where relevant) a target uid — that the engine consults at its seams:
tick boundaries (``on_step``), the admission gate (``on_gate``), the
prefill path (``on_prefill`` / ``poison_prefill``), the speculative
commit cycle (``on_spec_cycle``) and the decode step
(``before_decode``). Everything is keyed off the engine's own step
counter and uids — no wall clock, no ambient randomness — so a chaos
run is bit-for-bit reproducible from the plan (and
:meth:`FaultPlan.random` builds a plan from a seed).

Fault kinds:

- ``"cancel"`` — client cancellation at a tick boundary.
- ``"cancel_prefill"`` — cancellation landing *between* the target's
  prefill and its slot activation (the admission unwind path).
- ``"cancel_spec"`` — cancellation landing inside the speculative
  commit/rollback cycle (reaped at the next tick boundary).
- ``"expire"`` — force the target's deadline into the past (a
  deterministic deadline storm needs no real sleeping).
- ``"dry_pool"`` — borrow ``pages`` pages out of the pool for ``hold``
  steps (``PagedKVState.borrow_pages``), forcing preemptions and
  admission queueing while accounting stays exact.
- ``"preempt"`` — a forced preemption storm: evict ``pages`` (>=1)
  cost-ranked victims this step.
- ``"evict_prefix"`` — evict up to ``pages`` refcount-zero cached
  prefix pages *between* the admission gate's match and ``kv.admit``
  (the race the gate's protect/unprotect discipline must survive).
- ``"device_error"`` — raise :class:`InjectedDeviceError` immediately
  before the decode step's device call (the recoverable class: the
  donated pool buffer is still intact).
- ``"poison_prefill"`` — overwrite the target's prefill logits with
  NaN (a poison request the engine must isolate to that handle).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

KINDS = ("cancel", "cancel_prefill", "cancel_spec", "expire", "dry_pool",
         "preempt", "evict_prefix", "device_error", "poison_prefill")


class InjectedDeviceError(RuntimeError):
    """Simulated device failure in the decode step, raised before the
    donated device call (see ``InferenceEngine._on_device_fault``)."""

    def __init__(self, uid: Optional[int] = None):
        super().__init__(f"injected device error"
                         + (f" (attributed to request {uid})"
                            if uid is not None else ""))
        self.uid = uid


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: arms at engine step ``step`` and fires at
    the first matching seam after that (each fault fires once)."""
    step: int
    kind: str
    uid: Optional[int] = None          # target request, where relevant
    pages: int = 2                     # dry_pool/evict_prefix size,
    #                                    preempt victim count
    hold: int = 2                      # dry_pool: steps pages stay out

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class FaultPlan:
    """A deterministic schedule of :class:`Fault` records plus the
    runtime state of a chaos run (what fired when, pages currently
    borrowed). Pass to ``InferenceEngine(..., faults=plan)`` or
    ``model.engine(..., faults=plan)``; ``plan.fired`` is the replay
    log two identically-seeded runs must agree on."""

    def __init__(self, faults: Sequence[Fault],
                 seed: Optional[int] = None):
        self.faults = sorted(faults,
                             key=lambda f: (f.step, KINDS.index(f.kind),
                                            -1 if f.uid is None else f.uid))
        self.seed = seed
        self.fired: List[Tuple[int, str, Optional[int]]] = []
        self._spent = [False] * len(self.faults)
        self._borrowed: List[Tuple[int, List[int]]] = []  # (due, pages)

    @classmethod
    def random(cls, seed: int, uids: Sequence[int], n_steps: int,
               kinds: Sequence[str] = KINDS, n_faults: int = 8,
               pages: int = 2) -> "FaultPlan":
        """Seeded random plan: `n_faults` faults over `n_steps` steps
        targeting `uids`. Same arguments => same plan, always."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            uid = int(uids[int(rng.integers(len(uids)))]) if uids else None
            faults.append(Fault(step=int(rng.integers(1, max(2, n_steps))),
                                kind=kind, uid=uid,
                                pages=int(rng.integers(1, pages + 1))))
        return cls(faults, seed=seed)

    # ---- internal ---------------------------------------------------------

    def _due(self, eng, kind: str, uid: Optional[int] = None):
        """Armed, unspent faults of `kind` (optionally for `uid`)."""
        step = eng.stats["steps"]
        for i, f in enumerate(self.faults):
            if self._spent[i] or f.kind != kind or f.step > step:
                continue
            if uid is not None and f.uid != uid:
                continue
            yield i, f

    def _fire(self, eng, i: int) -> None:
        f = self.faults[i]
        self._spent[i] = True
        self.fired.append((int(eng.stats["steps"]), f.kind, f.uid))

    def _handle(self, eng, uid):
        h = eng.handles.get(uid)
        return h if h is not None and not h.finished else None

    # ---- engine seams -----------------------------------------------------

    def on_step(self, eng) -> None:
        """Tick boundary, before the engine reaps: cancellations,
        forced deadline expiry, dry-pool borrow/return, preemption
        storms."""
        for due, pages in [b for b in self._borrowed
                           if b[0] <= eng.stats["steps"]]:
            eng.kv.return_pages(pages)
            self._borrowed.remove((due, pages))
        for i, f in list(self._due(eng, "cancel")):
            h = self._handle(eng, f.uid)
            if h is not None:
                h.cancel("fault-injected cancel")
                self._fire(eng, i)
        for i, f in list(self._due(eng, "expire")):
            h = self._handle(eng, f.uid)
            if h is not None:
                h.deadline_at = eng.clock() - 1.0   # already past
                self._fire(eng, i)
        for i, f in list(self._due(eng, "dry_pool")):
            if eng.paged:
                pages = eng.kv.borrow_pages(f.pages)
                if pages:
                    self._borrowed.append(
                        (eng.stats["steps"] + f.hold, pages))
                    self._fire(eng, i)
        for i, f in list(self._due(eng, "preempt")):
            if eng.paged and eng.active.any():
                for _ in range(max(1, f.pages)):
                    if not eng.active.any():
                        break
                    eng._preempt(eng._select_victim())
                self._fire(eng, i)

    def on_gate(self, eng) -> None:
        """Inside the admission gate, after the prefix match/protect:
        evict cached prefix pages — protected chains must survive."""
        for i, f in list(self._due(eng, "evict_prefix")):
            if eng.prefix is not None:
                eng.prefix.reclaim(f.pages)
                self._fire(eng, i)

    def poison_prefill(self, eng, uid: int) -> bool:
        """True => overwrite this admission's prefill logits with NaN."""
        for i, _ in list(self._due(eng, "poison_prefill", uid)):
            self._fire(eng, i)
            return True
        return False

    def on_prefill(self, eng, handle) -> None:
        """Between a request's prefill and its slot activation."""
        for i, _ in list(self._due(eng, "cancel_prefill", handle.uid)):
            handle.cancel("fault-injected cancel mid-prefill")
            self._fire(eng, i)

    def on_spec_cycle(self, eng) -> None:
        """Inside the speculative commit/rollback cycle, between the
        batched verify and the per-slot commit+trim."""
        for i, f in list(self._due(eng, "cancel_spec")):
            h = self._handle(eng, f.uid)
            if h is not None:
                h.cancel("fault-injected cancel mid-spec-rollback")
                self._fire(eng, i)

    def before_decode(self, eng) -> None:
        """Immediately before the decode step's device call."""
        for i, f in list(self._due(eng, "device_error")):
            self._fire(eng, i)
            raise InjectedDeviceError(f.uid)

    # ---- reporting --------------------------------------------------------

    @property
    def pending_faults(self) -> int:
        return self._spent.count(False)

    @property
    def borrowed_pages(self) -> int:
        """Pages currently held out of the pool by dry_pool faults —
        drivers should keep ticking until this is 0 before auditing
        for leaks (the engine returns them at the next due tick)."""
        return sum(len(pages) for _, pages in self._borrowed)

    def summary(self) -> dict:
        return {"seed": self.seed,
                "scheduled": len(self.faults),
                "fired": list(self.fired),
                "unfired": [dataclasses.asdict(self.faults[i])
                            for i, s in enumerate(self._spent) if not s]}
