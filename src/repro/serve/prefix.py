"""Prefix cache: hash-keyed shared KV pages over the paged pool
(docs/serving.md §Prefix caching).

Shared-system-prompt traffic re-prefills the same leading tokens into
private pages on every admission — at sub-1-bit weights the KV pool is
the serving-memory bottleneck, so that duplication is exactly the bytes
worth deduplicating. This module is the index that makes prompt-prefix
KV a shared, refcounted resource:

- The unit of sharing is one **page-aligned token chunk** (`page_size`
  tokens <-> one KV page). Chunk ``i`` of a prompt is keyed by a
  **chained hash** over every chunk up to and including it, so a key
  identifies the chunk *in its exact left context* — two prompts share
  page ``i`` only if they agree on all ``(i+1) * page_size`` leading
  tokens. Entries store the raw chunk tokens and compare them on every
  lookup, so a hash collision (or a reused uid, or any other aliasing)
  degrades to a miss, never to wrong KV.
- :meth:`match` walks the chain for an incoming prompt and returns the
  longest indexed prefix with its page ids; the engine maps those pages
  read-only (``PagedKVState.admit(shared=...)``) and prefills only the
  uncached suffix.
- :meth:`register` adopts a freshly prefilled slot's full-chunk pages
  into the index (``mark_cached``). Registered pages hold only rows
  below the owner's committed frontier, so the owner's decode/spec
  writes never land in them; the first write that *would* (a full-cover
  admission re-emitting from the prompt tail) goes through the
  allocator's copy-on-write instead.
- Eviction is **LRU at refcount zero only**: :meth:`reclaim` — wired as
  the allocator's ``reclaim_cb`` — walks least-recently-matched leaf
  entries whose pages no slot maps and returns them to the free list.
  Interior chain entries (children > 0) leave only after every indexed
  extension has, so a surviving key always has its whole chain behind
  it.

The index holds token->page mappings, never KV values; everything
device-side stays in the one paged pool. KV for a token sequence is a
deterministic function of the tokens (greedy, text-only families), so
serving through the index is token-identical to the no-sharing engine
by construction — the bench asserts it at every point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serve.paging import PagedKVState


@dataclasses.dataclass
class _Entry:
    """One indexed chunk: `key` = chained hash of chunks[0..i], `page`
    = the pool page holding its KV rows. `tokens` (raw bytes) guards
    against hash collisions; `children` counts indexed extensions (leaf
    <=> 0); `tick` is the LRU clock (bumped on match/register)."""
    key: int
    parent: Optional[int]
    tokens: bytes
    page: int
    children: int = 0
    tick: int = 0


def _chunk_key(parent: Optional[int], chunk: np.ndarray) -> Tuple[int, bytes]:
    b = np.ascontiguousarray(chunk, np.int32).tobytes()
    return hash((parent, b)), b


class PrefixCache:
    """Host-side prefix index over one :class:`PagedKVState`.

    Built by the engine (paged, linear-only-table families); wires
    itself in as the allocator's reclaim/evictable callbacks. `stats`
    is the engine's counter dict — eviction bumps ``evicted_pages``."""

    def __init__(self, kv: PagedKVState, stats: Optional[Dict] = None):
        assert kv.has_linear and not kv.has_ring, \
            "prefix caching requires a linear-only page table"
        self.kv = kv
        self.page_size = kv.page_size
        self.stats = stats if stats is not None else {"evicted_pages": 0}
        self.entries: Dict[int, _Entry] = {}
        self._tick = 0
        # keys pinned for the current admission batch: matched in the
        # gate but not yet ref'd by kv.admit — reclaim must not evict
        # them in between (engine clears after the batch commits).
        self.protected: Set[int] = set()
        kv.reclaim_cb = self.reclaim
        kv.evictable_cb = self.evictable_count

    def __len__(self) -> int:
        return len(self.entries)

    # ---- lookup -----------------------------------------------------------

    def match(self, tokens: np.ndarray, probe: bool = False
              ) -> Tuple[int, List[int], List[int]]:
        """Longest indexed prefix of `tokens` (full chunks only).
        Returns ``(matched_tokens, page_ids, keys)``. Bumps the LRU
        tick of every matched entry unless `probe` (victim costing
        must not distort recency)."""
        toks = np.asarray(tokens)
        pages: List[int] = []
        keys: List[int] = []
        parent: Optional[int] = None
        self._tick += 1
        for i in range(toks.shape[0] // self.page_size):
            chunk = toks[i * self.page_size:(i + 1) * self.page_size]
            key, b = _chunk_key(parent, chunk)
            e = self.entries.get(key)
            if e is None or e.tokens != b:
                break
            if not probe:
                e.tick = self._tick
            pages.append(e.page)
            keys.append(key)
            parent = key
        return len(pages) * self.page_size, pages, keys

    def match_len(self, tokens: np.ndarray) -> int:
        """Probe-only: indexed-prefix length in tokens (the part of a
        re-prefill the index would cover — preemption victim costing)."""
        return self.match(tokens, probe=True)[0]

    # ---- registration -----------------------------------------------------

    def register(self, tokens: np.ndarray, n: int,
                 table_row: np.ndarray) -> int:
        """Adopt the full-chunk pages of `tokens[:n]` (just prefilled
        into a slot whose linear block-table row is `table_row`) into
        the index. Chunks already indexed are skipped — the existing
        entry's page is canonical (sequential host admissions: had it
        existed at match time it would have been shared). Returns the
        number of pages newly adopted."""
        toks = np.asarray(tokens)
        parent: Optional[int] = None
        adopted = 0
        self._tick += 1
        for i in range(min(int(n), toks.shape[0]) // self.page_size):
            chunk = toks[i * self.page_size:(i + 1) * self.page_size]
            key, b = _chunk_key(parent, chunk)
            e = self.entries.get(key)
            if e is not None:
                if e.tokens != b:      # hash collision: stop the chain
                    break
                e.tick = self._tick
                parent = key
                continue
            page = int(table_row[i])
            assert page != 0, "registering an unmapped page"
            self.entries[key] = _Entry(key, parent, b, page,
                                       tick=self._tick)
            self.kv.mark_cached(page)
            if parent is not None:
                self.entries[parent].children += 1
            adopted += 1
            parent = key
        return adopted

    # ---- pinning (admission-batch window) ---------------------------------

    def protect(self, keys: Sequence[int]) -> None:
        self.protected.update(keys)

    def unprotect_all(self) -> None:
        self.protected.clear()

    # ---- eviction ---------------------------------------------------------

    def _evictable(self, e: _Entry) -> bool:
        return (e.children == 0 and e.key not in self.protected
                and self.kv.ref[e.page] == 0)

    def evictable_count(self) -> int:
        """How many pages :meth:`reclaim` could free right now —
        counts transitively: evicting a leaf may expose its parent."""
        # children-count simulation without touching the index
        extra: Dict[int, int] = {}
        out = 0
        # LRU order is irrelevant for the count; walk leaves repeatedly
        frontier = [e for e in self.entries.values() if self._evictable(e)]
        seen: Set[int] = set()
        while frontier:
            nxt: List[_Entry] = []
            for e in frontier:
                if e.key in seen:
                    continue
                seen.add(e.key)
                out += 1
                if e.parent is not None:
                    p = self.entries[e.parent]
                    extra[p.key] = extra.get(p.key, 0) + 1
                    if (p.children - extra[p.key] == 0
                            and p.key not in self.protected
                            and self.kv.ref[p.page] == 0):
                        nxt.append(p)
            frontier = nxt
        return out

    def reclaim(self, k: int) -> int:
        """Evict least-recently-matched leaf entries with refcount-zero
        pages until `k` pages are freed (or nothing is evictable);
        wired as ``PagedKVState.reclaim_cb``. Returns pages freed."""
        freed = 0
        while freed < k:
            cands = [e for e in self.entries.values() if self._evictable(e)]
            if not cands:
                break
            e = min(cands, key=lambda c: c.tick)
            del self.entries[e.key]
            if e.parent is not None:
                self.entries[e.parent].children -= 1
            if self.kv.uncache(e.page):
                freed += 1
                self.stats["evicted_pages"] = \
                    self.stats.get("evicted_pages", 0) + 1
        return freed

    def check_invariants(self) -> None:
        """Audit index <-> pool consistency (docs/serving.md §Failure
        handling): every indexed page is marked cached in the pool and
        not free, no two entries claim one page, every non-root parent
        exists, and children counts match the index. Raises
        :class:`paging.PageAccountingError` — run by
        ``engine.check_invariants()`` on faults / debug ticks."""
        from repro.serve.paging import PageAccountingError

        def fail(msg):
            raise PageAccountingError(f"prefix index violated: {msg}")

        owner: Dict[int, int] = {}
        kids: Dict[int, int] = {}
        free = set(self.kv._free)
        for e in self.entries.values():
            if not self.kv.cached[e.page]:
                fail(f"entry {e.key} page {e.page} not marked cached")
            if e.page in free:
                fail(f"entry {e.key} page {e.page} is on the free list")
            if e.page in owner:
                fail(f"page {e.page} indexed by entries {owner[e.page]} "
                     f"and {e.key}")
            owner[e.page] = e.key
            if e.parent is not None:
                if e.parent not in self.entries:
                    fail(f"entry {e.key} parent {e.parent} missing")
                kids[e.parent] = kids.get(e.parent, 0) + 1
        for e in self.entries.values():
            if e.children != kids.get(e.key, 0):
                fail(f"entry {e.key} children {e.children} != indexed "
                     f"extensions {kids.get(e.key, 0)}")

    def clear(self) -> int:
        """Drop every entry (benchmark resets). All pages must be at
        refcount zero — i.e. the engine is drained."""
        total = 0
        while True:
            freed = self.reclaim(len(self.entries) + 1)
            total += freed
            if not freed:
                break
        assert not self.entries, "clear() with live sharers still mapped"
        return total
