"""Paged KV cache: a global pool of fixed-size KV pages + per-slot
block tables (docs/serving.md §Paged KV cache).

The rectangular pooled cache reserves ``max_batch x max_len`` rows per
attention leaf no matter what each slot actually holds; once the
weights are sub-1-bit (the paper's 25.8x compression) that rectangle
*is* the serving-memory bottleneck. Here the persistent cache becomes
one pool of ``n_pages`` pages of ``page_size`` rows, and each slot maps
only the pages for tokens it has actually written:

- :class:`PagedKVState` — host-side free-list allocator + per-slot
  block tables. Pages are reserved at admission for the prompt
  (``admit``), lazily one page at a time as decode crosses a page
  boundary (``ensure``), and freed when the slot completes or is
  preempted (``release``). Page 0 is the *null page*: unmapped block-
  table entries point at it, so inactive slots' masked decode writes
  land in trash instead of corrupting a neighbour.
- :func:`init_paged_cache` — the device pool. Attention leaves swap
  their ``(batch, rows)`` dims for ``(n_pages, page_size)``; state
  leaves with no sequence extent (SSM / conv states, the VLM image KV)
  stay slot-indexed rectangles.
- :func:`paged_insert_slot` / :func:`paged_select_active` — the paged
  twins of ``scheduler.cache_insert_slot`` / ``cache_select_active``,
  used by the engine's jitted (cache-donating) insert and decode steps.

Two page kinds exist: ``"linear"`` (ordinary caches — page ``j`` of a
slot holds absolute rows ``[j*page_size, (j+1)*page_size)``) and
``"ring"`` (the hybrid family's shared-attention sliding-window ring —
fully mapped at admission, writes wrap modulo the slot's virtual ring
``ring_pages * page_size``). Because block tables are ordered by
logical page, a slot's gathered pages form a virtual rectangle whose
row index equals the row's (possibly ring-wrapped) cache position — so
the decode read is exactly the rectangular decode mask over the gather
(`kernels.ref.paged_attention_ref`, Pallas gather kernel in
`kernels.paged_attention`).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.util import _path_str
from repro.models import transformer as T
from repro.serve.scheduler import _batch_axis

# leaf name -> offset of the sequence dim from the right; the batch dim
# (rectangular) / page dim (paged) sits directly left of it. Covers the
# plain GQA cache, MLA's compressed cache, and any leading layer-stack
# dims (the VLM (groups, per-1) stack included).
_SEQ_OFF = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 3}


class PageAccountingError(AssertionError):
    """A page-pool invariant was violated (leaked page, refcount
    mismatch, block table mapping a freed page, ...). Raised by
    :meth:`PagedKVState.check_invariants`; engine-fatal — unlike a
    poison request, broken accounting cannot be isolated to one slot."""


def page_kind(path: str) -> Optional[str]:
    """'linear' | 'ring' | None for a cache-leaf path. The VLM image KV
    (`cross_kv`) has no sequence growth and stays rectangular."""
    parts = path.split("/")
    if parts[-1] not in _SEQ_OFF or "cross_kv" in parts:
        return None
    return "ring" if "shared_attn" in parts else "linear"


def cache_page_kinds(cfg, max_len: int) -> Set[str]:
    """Which page kinds `cfg`'s cache contains (empty set => nothing to
    page, e.g. pure-SSM families; the engine then stays rectangular)."""
    tree = jax.eval_shape(lambda: T.init_cache(cfg, 1, max_len))
    kinds = set()
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        k = page_kind(_path_str(kp))
        if k:
            kinds.add(k)
    return kinds


def init_paged_cache(cfg, batch: int, max_len: int, n_pages: int,
                     page_size: int):
    """Pool-shaped cache: every pageable leaf becomes
    ``(*stack, n_pages, page_size, *tail)``; everything else keeps the
    rectangular ``init_cache`` layout (slot-indexed state).

    The rectangular layout is only ever inspected abstractly
    (``eval_shape``) — allocating it for real would spike init memory
    to rectangle + pool, defeating an overcommitted pool on exactly the
    deployments it exists for."""
    rect = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))

    def conv(kp, leaf):
        path = _path_str(kp)
        name = path.rsplit("/", 1)[-1]
        if page_kind(path) is None:
            if name == "window":   # value leaf: the hybrid ring length
                return min(max_len, cfg.sliding_window or max_len)
            return jnp.zeros(leaf.shape, leaf.dtype)
        ax = len(leaf.shape) - _SEQ_OFF[name]
        s = leaf.shape
        return jnp.zeros(s[:ax - 1] + (n_pages, page_size) + s[ax + 1:],
                         leaf.dtype)

    return jax.tree_util.tree_map_with_path(conv, rect)


def kv_cache_bytes(cache) -> int:
    """Bytes held by the attention-cache leaves (k/v/c_kv/k_rope) —
    the quantity paging shrinks; SSM state is O(1)/slot either way."""
    total = 0
    for kp, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if _path_str(kp).rsplit("/", 1)[-1] in _SEQ_OFF:
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return int(total)


def paged_insert_slot(cache, single, slot, tables):
    """Insert a freshly prefilled batch-1 *rectangular* cache into slot
    `slot` of the paged pool. `tables`: ``{kind: (pages_kind,) int32}``
    page-id vector for this slot, unmapped tail entries 0 (null page).

    Pageable leaves scatter page-granular row blocks of the rectangle
    into the slot's pages (rows past the rectangle pad with zeros; rows
    in unmapped tail pages all land on the null page, which is trash by
    design). Rectangular leaves (SSM state, image KV, metadata) keep
    the batch-dim scatter of ``scheduler.cache_insert_slot``.
    """
    def ins(kp, pool, s):
        path = _path_str(kp)
        kind = page_kind(path)
        if kind is None:
            if jnp.ndim(pool) < 2:
                return pool
            start = [0] * jnp.ndim(pool)
            start[_batch_axis(kp)] = slot
            return jax.lax.dynamic_update_slice(pool, s.astype(pool.dtype),
                                                tuple(start))
        ids = tables[kind]
        np_ax = jnp.ndim(pool) - _SEQ_OFF[path.rsplit("/", 1)[-1]] - 1
        ps = pool.shape[np_ax + 1]
        x = jax.lax.squeeze(s, (np_ax,))          # drop the batch=1 dim
        rows = x.shape[np_ax]
        pad = [(0, 0)] * x.ndim
        pad[np_ax] = (0, ids.shape[0] * ps - rows)
        x = jnp.pad(x, pad)
        x = x.reshape(x.shape[:np_ax] + (ids.shape[0], ps)
                      + x.shape[np_ax + 1:])
        idx = (slice(None),) * np_ax + (ids,)
        return pool.at[idx].set(x.astype(pool.dtype))

    return jax.tree_util.tree_map_with_path(ins, cache, single)


def copy_page(cache, src, dst):
    """Device-side copy-on-write: duplicate page `src` into page `dst`
    across every pageable leaf of the pool (rectangular leaves pass
    through untouched). `src`/`dst` are traced scalars, so one jitted
    compilation covers every COW in the engine's lifetime; the engine
    donates the pool so XLA updates the `dst` page in place."""
    def cp(kp, leaf):
        path = _path_str(kp)
        if page_kind(path) is None:
            return leaf
        ax = len(leaf.shape) - _SEQ_OFF[path.rsplit("/", 1)[-1]] - 1
        row = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=ax)
        return jax.lax.dynamic_update_slice_in_dim(leaf, row, dst, axis=ax)
    return jax.tree_util.tree_map_with_path(cp, cache)


def paged_select_active(new, old, active):
    """Per-slot active select for a paged cache: pool leaves pass
    through untouched — paged decode writes are slot-isolated by
    construction (inactive slots map the null page) — while rectangular
    leaves keep the batch-dim select of
    ``scheduler.cache_select_active``."""
    def sel(kp, n, o):
        if page_kind(_path_str(kp)) is not None or jnp.ndim(n) < 2:
            return n
        shape = [1] * jnp.ndim(n)
        shape[_batch_axis(kp)] = -1
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree_util.tree_map_with_path(sel, new, old)


class PagedKVState:
    """Host-side page allocator + per-slot block tables.

    Pages [1, n_pages) are allocatable; page 0 is the null page. The
    default pool (``n_pages=None``) holds full capacity — one worst-case
    slot footprint per slot, no overcommit, so the paged engine is a
    drop-in for the rectangular one. Pass a smaller ``n_pages`` (e.g.
    via ``ServeConfig.kv_pool_pages``) to overcommit: admission then
    gates on free pages (``can_admit``, FIFO head-of-line), decode
    reserves lazily (``ensure``) and the engine preempts a slot if the
    pool runs truly dry (victim = lowest recompute cost, engine-side).

    Pages are **refcounted** (docs/serving.md §Prefix caching): ``ref``
    counts slot block-table mappings and ``cached`` marks pages held by
    the prefix index (serve.prefix). ``admit`` can map already-filled
    shared pages (``shared=``) read-only into a new slot, and a page
    returns to the free list only when its last mapping drops *and* the
    index no longer holds it — ``release`` (preemption/completion) and
    ``trim`` (speculative rollback) only ever decrement, so a page with
    live sharers is never zeroed or reused. Writes into a shared page go
    through :meth:`cow` first (fresh private copy, table rewired). When
    the free list runs short, ``reclaim_cb`` (wired to the prefix
    index's LRU eviction) is invoked before allocation fails.
    """

    def __init__(self, cfg, max_batch: int, max_len: int, page_size: int,
                 n_pages: Optional[int] = None, watermark: int = 0,
                 kinds: Optional[Set[str]] = None):
        if kinds is None:
            kinds = cache_page_kinds(cfg, max_len)
        if not kinds:
            raise ValueError(f"family {cfg.family!r} has no pageable KV "
                             f"cache")
        ps = max(1, min(int(page_size), max_len))
        self.page_size = ps
        self.has_linear = "linear" in kinds
        self.has_ring = "ring" in kinds
        self.lin_pages = -(-max_len // ps) if self.has_linear else 0
        win = min(max_len, cfg.sliding_window or max_len)
        self.ring_pages = -(-win // ps) if self.has_ring else 0
        per_slot = self.lin_pages + self.ring_pages
        if n_pages is None:
            n_pages = max_batch * per_slot + 1
        if n_pages < per_slot + 1:
            raise ValueError(
                f"kv_pool_pages={n_pages} cannot hold one slot's worst "
                f"case ({per_slot} pages + the null page); a lone "
                f"request could never complete")
        self.n_pages = int(n_pages)
        self.watermark = int(watermark)
        self.tables: Dict[str, np.ndarray] = {}
        if self.has_linear:
            self.tables["linear"] = np.zeros((max_batch, self.lin_pages),
                                             np.int32)
        if self.has_ring:
            self.tables["ring"] = np.zeros((max_batch, self.ring_pages),
                                           np.int32)
        self._free = list(range(self.n_pages - 1, 0, -1))  # pop() ascending
        self._slot_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self._mapped = [0] * max_batch        # linear pages mapped per slot
        # per-page sharing state: ref = live slot mappings, cached = the
        # prefix index holds the page (serve.prefix). free <=> ref == 0
        # and not cached. Page 0 (null) is never ref'd or cached.
        self.ref = np.zeros(self.n_pages, np.int32)
        self.cached = np.zeros(self.n_pages, bool)
        # pages borrowed out of the pool by an external holder (fault
        # injection today; the disaggregated page-transfer path later).
        # Each holds one reference that check_invariants accounts for.
        self.external: Set[int] = set()
        # wired by the engine when a prefix cache exists: reclaim_cb(k)
        # evicts up to k refcount-zero cached pages (LRU) back to the
        # free list; evictable_cb() counts how many such evictions are
        # currently possible (for admission headroom).
        self.reclaim_cb: Optional[Callable[[int], int]] = None
        self.evictable_cb: Optional[Callable[[], int]] = None
        self.peak_used_pages = 0
        self._device_tables: Optional[Dict[str, jnp.ndarray]] = None

    # ---- accounting -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    @property
    def available_pages(self) -> int:
        """Pages obtainable right now: free list + cached pages the
        prefix index could evict on demand (refcount zero)."""
        ev = self.evictable_cb() if self.evictable_cb is not None else 0
        return len(self._free) + ev

    @property
    def shared_page_count(self) -> int:
        """Pages currently mapped by more than one slot."""
        return int((self.ref > 1).sum())

    @property
    def cached_page_count(self) -> int:
        """Pages currently held by the prefix index."""
        return int(self.cached.sum())

    def pages_for_prompt(self, n: int) -> int:
        lin = -(-n // self.page_size) if self.has_linear else 0
        return lin + self.ring_pages

    def can_admit(self, n: int) -> bool:
        return self.available_pages - self.pages_for_prompt(n) \
            >= self.watermark

    # ---- lifecycle --------------------------------------------------------

    def _ensure_free(self, k: int) -> bool:
        """Grow the free list to >= k pages, evicting refcount-zero
        cached pages through ``reclaim_cb`` if needed. False => the pool
        is truly dry (every page is mapped or pinned by a live sharer)."""
        if len(self._free) < k and self.reclaim_cb is not None:
            self.reclaim_cb(k - len(self._free))
        return len(self._free) >= k

    def _alloc(self, k: int) -> List[int]:
        assert self._ensure_free(k), "allocator invariant violated"
        out = [self._free.pop() for _ in range(k)]
        for p in out:
            assert self.ref[p] == 0 and not self.cached[p], \
                f"page {p} on the free list with live sharers"
            self.ref[p] = 1
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return out

    def _unref(self, page: int) -> bool:
        """Drop one slot mapping of `page`; returns True when the page
        went back to the free list (last mapping, not index-held)."""
        self.ref[page] -= 1
        assert self.ref[page] >= 0, f"page {page} refcount underflow"
        if self.ref[page] == 0 and not self.cached[page]:
            self._free.append(page)
            return True
        return False

    def admit(self, slot: int, n: int,
              shared: Sequence[int] = ()) -> Dict[str, np.ndarray]:
        """Reserve pages for an `n`-token prompt entering `slot`;
        returns the per-kind page-id vectors for ``paged_insert_slot``
        (== the slot's fresh block-table rows).

        shared: already-filled page ids (from a prefix-index match)
        mapped read-only as the slot's *leading* linear pages — their
        refcounts bump (pinning them against eviction) and only the
        remaining suffix pages are allocated fresh. Refs are taken
        before any allocation, so a reclaim triggered by the suffix
        allocation can never evict the pages being shared."""
        assert not self._slot_pages[slot], f"slot {slot} pages leaked"
        self._device_tables = None
        ids: Dict[str, np.ndarray] = {}
        if self.has_linear:
            k = -(-n // self.page_size)
            assert len(shared) <= k, "shared prefix longer than prompt"
            for p in shared:
                self.ref[p] += 1
            pages = list(shared) + self._alloc(k - len(shared))
            self._slot_pages[slot].extend(pages)
            self._mapped[slot] = k
            row = self.tables["linear"][slot]
            row[:] = 0
            row[:k] = pages
            ids["linear"] = row.copy()
        else:
            assert not shared, "shared pages require a linear table"
        if self.has_ring:
            pages = self._alloc(self.ring_pages)
            self._slot_pages[slot].extend(pages)
            self.tables["ring"][slot] = pages
            ids["ring"] = np.asarray(pages, np.int32)
        return ids

    def ensure(self, slot: int, row: int) -> bool:
        """Lazy per-decode-step reservation: map the linear page that
        will hold `row` (the next cache write). False => pool exhausted
        (caller preempts). Ring pages are fully mapped at admission."""
        return self.reserve_rows(slot, row + 1)

    def reserve_rows(self, slot: int, n_rows: int) -> bool:
        """Map linear pages so rows ``[0, n_rows)`` of `slot` are
        writable. Unlike the one-page-per-step :meth:`ensure`, this may
        map several pages at once — the speculative decode cycle writes
        up to k+1 rows (k drafts + the verify row) before the next host
        sync. False => pool exhausted with the reservation *partially*
        applied; the caller preempts somebody and retries (already-
        mapped pages stay mapped, so retrying is idempotent)."""
        if not self.has_linear:
            return True
        need = -(-n_rows // self.page_size)
        while self._mapped[slot] < need:
            if not self._ensure_free(1):
                return False
            page = self._alloc(1)[0]
            self._slot_pages[slot].append(page)
            self.tables["linear"][slot, self._mapped[slot]] = page
            self._mapped[slot] += 1
            self._device_tables = None
        return True

    def trim(self, slot: int, n_rows: int) -> int:
        """Rollback: unmap linear pages past the one holding row
        ``n_rows - 1`` (the last *committed* cache write) and return
        them to the free list. Returns the number of pages freed.

        This is how rejected speculative drafts give their pages back:
        the cycle reserves rows up to ``pos + k``, the verify forward
        accepts ``a <= k`` tokens, and the pages covering only rejected
        rows are trimmed. The rejected rows themselves need no device-
        side cleanup — rows past the committed frontier reconstruct to
        negative absolute positions in the decode mask and are never
        read (see `kernels.ref.paged_attention_ref`).

        Refcount-aware: a trimmed page only reaches the free list when
        this slot held its last mapping and the prefix index does not —
        a shared or cached page merely loses this slot's reference, so
        speculative rollback can never hand a sharer's live KV to the
        allocator."""
        if not self.has_linear:
            return 0
        keep = -(-n_rows // self.page_size)
        mapped = self._mapped[slot]
        if keep >= mapped:
            return 0
        row = self.tables["linear"][slot]
        dropped = [int(p) for p in row[keep:mapped]]
        row[keep:mapped] = 0
        for p in dropped:
            # by value: _slot_pages interleaves linear and ring pages
            self._slot_pages[slot].remove(p)
        for p in reversed(dropped):
            self._unref(p)
        self._mapped[slot] = keep
        self._device_tables = None
        return len(dropped)

    def release(self, slot: int) -> None:
        """Drop the slot's page mappings and zero its block-table rows
        (a later occupant can never read a stale mapping). Pages with
        other live sharers — or held by the prefix index — survive with
        their refcount/cached state; only exclusive uncached pages
        return to the free list."""
        for p in reversed(self._slot_pages[slot]):
            self._unref(p)
        self._slot_pages[slot] = []
        self._mapped[slot] = 0
        for t in self.tables.values():
            t[slot] = 0
        self._device_tables = None

    def borrow_pages(self, k: int) -> List[int]:
        """Take up to `k` pages out of the pool for an external holder
        (reclaiming cached pages if needed) and return their ids. The
        borrowed pages hold one reference each, so accounting stays
        exact while they are out — :meth:`check_invariants` keeps
        passing. Seam for fault injection (``serve.faults`` dry-pool)
        and, later, cross-engine page transfer; give them back with
        :meth:`return_pages`."""
        out: List[int] = []
        while len(out) < k and self._ensure_free(1):
            page = self._alloc(1)[0]
            self.external.add(page)
            out.append(page)
        return out

    def return_pages(self, pages: Sequence[int]) -> None:
        """Give borrowed pages back to the pool."""
        for p in pages:
            assert p in self.external, f"page {p} was not borrowed"
            self.external.discard(p)
            self._unref(p)

    def check_invariants(self) -> None:
        """Audit the whole pool; raise :class:`PageAccountingError` on
        the first violation. O(n_pages + slots * pages_per_slot) pure
        host work — cheap enough to run on every fault and, in debug
        mode (``ServeConfig.debug``), on every engine tick.

        Checked: free ⟺ (ref == 0 and not index-held); refcounts equal
        the slot-mapping count (+1 per borrowed page); block-table rows
        point only at pages their slot owns (never a freed page, never
        the null page as a mapped entry); linear rows are mapped as a
        dense prefix of exactly ``_mapped`` pages; no page is leaked
        (unreachable yet absent from the free list)."""
        def fail(msg: str):
            raise PageAccountingError(f"page accounting violated: {msg}")

        free = set(self._free)
        if len(free) != len(self._free):
            fail("duplicate pages on the free list")
        if 0 in free:
            fail("null page on the free list")
        if self.ref[0] != 0 or self.cached[0]:
            fail("null page acquired a reference")
        counts = np.zeros(self.n_pages, np.int64)
        for slot, pages in enumerate(self._slot_pages):
            if len(set(pages)) != len(pages):
                fail(f"slot {slot} maps a page twice")
            for p in pages:
                if not 0 < p < self.n_pages:
                    fail(f"slot {slot} owns out-of-range page {p}")
                counts[p] += 1
        for p in self.external:
            counts[p] += 1
        for p in range(1, self.n_pages):
            if counts[p] != self.ref[p]:
                fail(f"page {p}: ref={int(self.ref[p])} but "
                     f"{int(counts[p])} live mappings")
            if p in free and (self.ref[p] != 0 or self.cached[p]):
                fail(f"page {p} free with live sharers "
                     f"(ref={int(self.ref[p])}, "
                     f"cached={bool(self.cached[p])})")
            if p not in free and self.ref[p] == 0 and not self.cached[p]:
                fail(f"page {p} leaked (unreferenced, uncached, not on "
                     f"the free list)")
        for kind, tab in self.tables.items():
            for slot in range(tab.shape[0]):
                own = set(self._slot_pages[slot])
                mapped = [int(p) for p in tab[slot] if p != 0]
                for p in mapped:
                    if p not in own:
                        fail(f"slot {slot} {kind} table maps page {p} "
                             f"it does not own"
                             + (" (freed)" if p in free else ""))
                if len(set(mapped)) != len(mapped):
                    fail(f"slot {slot} {kind} table maps a page twice")
        if self.has_linear:
            for slot in range(self.tables["linear"].shape[0]):
                row = self.tables["linear"][slot]
                m = self._mapped[slot]
                if (row[:m] == 0).any() or (row[m:] != 0).any():
                    fail(f"slot {slot} linear row not a dense prefix of "
                         f"{m} mapped pages")
                ring = (len(self._slot_pages[slot])
                        - int((self.tables.get("ring",
                               np.zeros((0, 0)))[slot] != 0).sum())
                        if self.has_ring else len(self._slot_pages[slot]))
                if ring != m:
                    fail(f"slot {slot} owns {ring} linear pages but maps "
                         f"{m}")

    # ---- prefix-cache sharing (serve.prefix) ------------------------------

    def mark_cached(self, page: int) -> None:
        """The prefix index now holds `page` (pins it against free-list
        reuse even at refcount zero, until :meth:`uncache`)."""
        assert page != 0 and self.ref[page] > 0, \
            f"page {page} must be live when the index adopts it"
        self.cached[page] = True

    def uncache(self, page: int) -> bool:
        """Prefix-index eviction: drop the index's hold on `page`;
        returns True when that freed it (refcount was already zero)."""
        assert self.cached[page], f"page {page} not index-held"
        self.cached[page] = False
        if self.ref[page] == 0:
            self._free.append(page)
            return True
        return False

    def next_shared_write_page(self, slot: int, row0: int,
                               row1: int) -> Optional[int]:
        """First logical linear page index covering rows [row0, row1)
        that `slot` cannot write privately (shared with another slot or
        held by the prefix index); None when the whole range is safe."""
        if not self.has_linear or row0 >= row1:
            return None
        row = self.tables["linear"][slot]
        for i in range(row0 // self.page_size,
                       -(-row1 // self.page_size)):
            p = int(row[i])
            if p and (self.ref[p] > 1 or self.cached[p]):
                return i
        return None

    def cow(self, slot: int, page_idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write logical linear page `page_idx` of `slot`: map a
        fresh private page in its place and return ``(src, dst)`` page
        ids for the device copy (:func:`copy_page`). The shared source
        keeps its other references untouched. None => pool dry even
        after reclaim (caller preempts and retries)."""
        if not self._ensure_free(1):
            return None
        src = int(self.tables["linear"][slot, page_idx])
        assert src != 0, f"slot {slot} page {page_idx} unmapped"
        dst = self._alloc(1)[0]
        self.tables["linear"][slot, page_idx] = dst
        # swap in place: _slot_pages order is unordered bookkeeping
        self._slot_pages[slot].remove(src)
        self._slot_pages[slot].append(dst)
        self._unref(src)
        self._device_tables = None
        return src, dst

    def device_tables(self) -> Dict[str, jnp.ndarray]:
        """Block tables as device arrays for this decode step. Cached —
        steady-state decode (no admission, boundary crossing or
        release) reuses the uploaded copy instead of a per-token H2D
        transfer in the hottest loop."""
        if self._device_tables is None:
            self._device_tables = {k: jnp.asarray(v)
                                   for k, v in self.tables.items()}
        return self._device_tables
