"""Serving stack: slot-scheduled continuous batching (docs/serving.md).

:class:`InferenceEngine` is the serving surface — a fixed pool of
decode slots over one persistent cache (a paged KV pool by default:
fixed-size pages + per-slot block tables, ``repro.serve.paging``),
per-slot positions / budgets / EOS, mid-flight admission with
power-of-two prefill buckets, page-aware overcommit admission, shared
prompt-prefix KV pages (:class:`PrefixCache`, ``repro.serve.prefix``:
refcounted copy-on-write sharing + LRU eviction), optional
tensor-parallel execution over a mesh. :class:`SlotScheduler` holds the
host-side bookkeeping; :class:`BatchServer` is the deprecated
wave-admission shim. Enter through ``api.NanoQuantModel.engine()``.
"""
from repro.serve.scheduler import (  # noqa: F401
    Request, SlotScheduler, bucket_length, pick_preemption_victim)
from repro.serve.paging import PagedKVState  # noqa: F401
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    InferenceEngine, RequestHandle, ServeConfig, make_prefill_step,
    make_serve_step, make_slot_prefill_step, sample_token)
from repro.serve.batcher import BatchServer  # noqa: F401
from repro.serve.speculative import SpecDecodeController  # noqa: F401

__all__ = [
    "InferenceEngine", "RequestHandle", "ServeConfig", "Request",
    "SlotScheduler", "BatchServer", "PagedKVState", "PrefixCache",
    "SpecDecodeController", "bucket_length", "pick_preemption_victim",
    "sample_token", "make_prefill_step", "make_serve_step",
    "make_slot_prefill_step",
]
