from repro.serve.engine import (  # noqa: F401
    InferenceEngine, RequestHandle, ServeConfig, make_prefill_step,
    make_serve_step, make_slot_prefill_step, sample_token)
from repro.serve.scheduler import (  # noqa: F401
    Request, SlotScheduler, bucket_length)
from repro.serve.batcher import BatchServer  # noqa: F401
