from repro.serve.engine import (  # noqa: F401
    ServeConfig, make_prefill_step, make_serve_step, sample_token)
from repro.serve.batcher import BatchServer, Request  # noqa: F401
