"""Serving stack: slot-scheduled continuous batching (docs/serving.md).

:class:`InferenceEngine` is the serving surface — a fixed pool of
decode slots over one persistent cache (a paged KV pool by default:
fixed-size pages + per-slot block tables, ``repro.serve.paging``),
per-slot positions / budgets / EOS, mid-flight admission with
power-of-two prefill buckets, page-aware overcommit admission, shared
prompt-prefix KV pages (:class:`PrefixCache`, ``repro.serve.prefix``:
refcounted copy-on-write sharing + LRU eviction), optional
tensor-parallel execution over a mesh. :class:`SlotScheduler` holds the
host-side bookkeeping; :class:`BatchServer` is the deprecated
wave-admission shim. Enter through ``api.NanoQuantModel.engine()``.

Request lifecycle robustness (docs/serving.md §Failure handling):
per-request deadlines + ``RequestHandle.cancel()`` with explicit
terminal statuses (:class:`RequestError`), graceful
``engine.drain()`` + snapshot/restore (``repro.serve.recovery``),
page-pool invariant auditing (:class:`PageAccountingError`) and the
deterministic fault-injection harness (:class:`FaultPlan`,
``repro.serve.faults``).
"""
from repro.serve.scheduler import (  # noqa: F401
    Request, SlotScheduler, bucket_length, pick_preemption_victim)
from repro.serve.paging import (  # noqa: F401
    PageAccountingError, PagedKVState)
from repro.serve.prefix import PrefixCache  # noqa: F401
from repro.serve.faults import (  # noqa: F401
    Fault, FaultPlan, InjectedDeviceError)
from repro.serve.engine import (  # noqa: F401
    InferenceEngine, RequestError, RequestHandle, ServeConfig,
    TERMINAL_STATUSES, make_prefill_step, make_serve_step,
    make_slot_prefill_step, sample_token)
from repro.serve import recovery  # noqa: F401
from repro.serve.batcher import BatchServer  # noqa: F401
from repro.serve.speculative import SpecDecodeController  # noqa: F401

__all__ = [
    "InferenceEngine", "RequestHandle", "ServeConfig", "Request",
    "SlotScheduler", "BatchServer", "PagedKVState", "PrefixCache",
    "SpecDecodeController", "bucket_length", "pick_preemption_victim",
    "sample_token", "make_prefill_step", "make_serve_step",
    "make_slot_prefill_step",
    # failure handling
    "RequestError", "TERMINAL_STATUSES", "PageAccountingError",
    "Fault", "FaultPlan", "InjectedDeviceError", "recovery",
]
