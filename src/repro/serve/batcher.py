"""DEPRECATED: wave-admission compatibility shim over InferenceEngine.

The wave-lockstep scheduler that used to live here — left-pad a batch,
prefill once, decode everyone to the wave-max budget, drain before
admitting — is gone. Serving is now the slot-scheduled, continuously
batched :class:`repro.serve.engine.InferenceEngine`. ``BatchServer``
remains as a thin shim that drives the engine with ``admission="wave"``
(a new batch is admitted only once every slot is free) so existing
callers keep working; greedy outputs are token-identical per request to
the continuous engine. New code should use ``InferenceEngine`` /
``NanoQuantModel.engine()`` directly.
"""
from __future__ import annotations

import warnings
from typing import Dict, List

from repro.models.config import ModelConfig
from repro.serve.engine import InferenceEngine, ServeConfig
from repro.serve.scheduler import Request  # noqa: F401  (re-export)


class BatchServer:
    """Deprecated wave-scheduled facade over :class:`InferenceEngine`.

    Cache-layout agnostic: it drives whatever layout the engine was
    built with — the default paged KV pool (``ServeConfig.paged``,
    including overcommitted pools whose preemptions requeue work
    mid-wave) or the legacy rectangle (``paged=False``). Extra engine
    kwargs (``mesh=``, ``sharding_policy=``) pass straight through.
    """

    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 max_batch: int = 8, max_len: int = 512, seed: int = 0,
                 **engine_kwargs):
        warnings.warn(
            "BatchServer is deprecated; use InferenceEngine "
            "(NanoQuantModel.engine()) for slot-scheduled continuous "
            "batching", DeprecationWarning, stacklevel=2)
        self.engine = InferenceEngine(params, cfg, scfg,
                                      max_batch=max_batch, max_len=max_len,
                                      seed=seed, admission="wave",
                                      **engine_kwargs)
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.max_batch, self.max_len = max_batch, max_len

    @property
    def queue(self) -> List[Request]:
        # pending holds fresh handles and (paged overcommit) preempted
        # resume records; both lead back to their Request
        return [h.request if hasattr(h, "request") else h.handle.request
                for h in self.engine.scheduler.pending]

    @property
    def done(self) -> Dict[int, Request]:
        return self.engine.done

    def submit(self, req: Request) -> None:
        self.engine.submit(req)

    def step_wave(self) -> List[Request]:
        """Serve one wave to completion; returns its requests."""
        if not self.engine.in_flight:
            return []
        finished = list(self.engine.step())     # admits the wave
        while self.engine.active.any():
            finished.extend(self.engine.step())
        return finished

    def run(self) -> Dict[int, Request]:
        while self.engine.in_flight:
            self.step_wave()
        return self.engine.done
