"""Wave-scheduled request batcher for quantized-model serving.

Requests are admitted into fixed-size waves: prompts are left-padded to
the wave maximum, prefilled once, then decoded in lockstep until every
request hits its token budget or EOS. This is the batched-serving driver
the example application uses; slot-level continuous batching is noted as
future work in DESIGN.md (it needs per-slot cache write offsets).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.engine import ServeConfig, make_prefill_step, \
    make_serve_step, sample_token


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                # (S,) or (S, K) token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: Optional[np.ndarray] = None


class BatchServer:
    def __init__(self, params, cfg: ModelConfig, scfg: ServeConfig,
                 max_batch: int = 8, max_len: int = 512, seed: int = 0):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.max_batch, self.max_len = max_batch, max_len
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg, max_len))
        self._decode = jax.jit(make_serve_step(cfg))
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _pad_prompts(self, reqs: List[Request]):
        S = max(len(r.prompt) for r in reqs)
        S = max(S, 1)
        tshape = (len(reqs), S) + reqs[0].prompt.shape[1:]
        toks = np.zeros(tshape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt     # left pad
        return jnp.asarray(toks), S

    def step_wave(self) -> List[Request]:
        """Serve one wave; returns completed requests."""
        if not self.queue:
            return []
        wave = self.queue[: self.max_batch]
        self.queue = self.queue[self.max_batch:]
        toks, S = self._pad_prompts(wave)
        budget = max(r.max_new_tokens for r in wave)
        budget = min(budget, self.max_len - S)

        logits, cache = self._prefill(self.params, toks)
        outs = []
        for i in range(budget):
            self.key, k = jax.random.split(self.key)
            tok = sample_token(logits, k, self.scfg)
            if self.cfg.family == "audio":
                tok = tok[:, None, :]
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.asarray(S + i))
        gen = np.concatenate(outs, axis=1)             # (B, budget[, K])
        for i, r in enumerate(wave):
            g = gen[i][: r.max_new_tokens]
            if r.eos_id is not None:
                flat = g if g.ndim == 1 else g[..., 0]
                hits = np.nonzero(flat == r.eos_id)[0]
                if hits.size:
                    g = g[: hits[0] + 1]
            r.output = g
            self.done[r.uid] = r
        return wave

    def run(self) -> Dict[int, Request]:
        while self.queue:
            self.step_wave()
        return self.done
