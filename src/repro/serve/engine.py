"""Serving engine: prefill / decode steps + sampling.

``serve_step`` is the unit the decode-shape dry-runs lower: one new token
against a KV (or SSM-state) cache — memory-bound, and exactly where the
paper's packed binary weights pay off (the whole weight stream shrinks
~16x, see §Roofline FP-vs-quantized decode comparison).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.8
    top_k: int = 32
    max_new_tokens: int = 64
    greedy: bool = False


def sample_token(logits: jnp.ndarray, key, scfg: ServeConfig) -> jnp.ndarray:
    """logits (B, 1, V[, K-codebooks already folded]) -> token ids (B, 1).
    Temperature + top-k sampling (paper App. E benchmark settings:
    temperature 0.8, top-k 32)."""
    lf = logits.astype(jnp.float32)
    if lf.ndim == 4:                       # audio: (B, 1, K, V)
        lf = lf.reshape(lf.shape[0], -1, lf.shape[-1])  # (B, K, V)
    else:
        lf = lf[:, -1]                                   # (B, V)
        lf = lf[:, None]                                 # (B, 1, V)
    if scfg.greedy:
        out = jnp.argmax(lf, axis=-1)
    else:
        lf = lf / max(scfg.temperature, 1e-6)
        if scfg.top_k:
            kth = jax.lax.top_k(lf, scfg.top_k)[0][..., -1:]
            lf = jnp.where(lf < kth, -jnp.inf, lf)
        out = jax.random.categorical(key, lf, axis=-1)
    return out.astype(jnp.int32)           # (B, 1) or (B, K)


def make_serve_step(cfg: ModelConfig):
    """(params, token (B,1[,K]), cache, pos) -> (logits, new_cache)."""
    def serve_step(params, token, cache, pos):
        return T.decode_step(params, cfg, token, cache, pos)
    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    """(params, tokens (B,S)[, image_embeds]) -> (last logits, cache).

    The cache is created inside the step (sized max_len or S), so the
    lowered computation covers allocation + fill — what a serving runtime
    executes on admission."""
    def prefill_step(params, tokens, image_embeds=None):
        B, S = tokens.shape[0], tokens.shape[1]
        cache = T.init_cache(cfg, B, max_len or S)
        return T.prefill(params, cfg, tokens, cache, image_embeds)
    return prefill_step


def generate(params, cfg: ModelConfig, tokens, scfg: ServeConfig,
             key=None, image_embeds=None,
             jit_prefill=None, jit_decode=None) -> Tuple[Any, Any]:
    """Host-driven generation loop (prefill once, then decode steps).
    Returns (generated (B, max_new[,K]), per-step logits list)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S = tokens.shape[0], tokens.shape[1]
    max_len = S + scfg.max_new_tokens
    prefill = jit_prefill or jax.jit(make_prefill_step(cfg, max_len))
    decode = jit_decode or jax.jit(make_serve_step(cfg))

    if cfg.family == "vlm":
        logits, cache = prefill(params, tokens, image_embeds)
    else:
        logits, cache = prefill(params, tokens)
    outs = []
    tok = None
    for i in range(scfg.max_new_tokens):
        key, k = jax.random.split(key)
        tok = sample_token(logits, k, scfg)
        if cfg.family == "audio":
            tok = tok[:, None, :]          # (B, 1, K)
        outs.append(tok)
        logits, cache = decode(params, tok, cache, jnp.asarray(S + i))
    gen = jnp.concatenate(outs, axis=1)
    return gen, logits
