"""Serving engine: slot-scheduled continuous batching + the raw
prefill / decode steps and sampling.

``serve_step`` is the unit the decode-shape dry-runs lower: one new token
against a KV (or SSM-state) cache — memory-bound, and exactly where the
paper's packed binary weights pay off (the whole weight stream shrinks
~16x, see §Roofline FP-vs-quantized decode comparison).

:class:`InferenceEngine` is the serving surface built on those steps: a
fixed pool of ``max_batch`` decode slots over one persistent cache,
where each slot carries its own position, token budget and EOS state.
Freed slots are refilled mid-flight by per-slot prefill (prompt lengths
bucketed to powers of two so prefill compiles once per bucket), and
finished slots are masked on device so they are no-ops until refilled.

    engine = InferenceEngine(params, cfg, ServeConfig(), max_batch=8)
    handle = engine.submit(Request(0, prompt), on_token=print)
    for tok in handle:          # streams; pumps engine.step() as needed
        ...
    done = engine.run()         # or drain everything at once
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve.scheduler import (Request, SlotScheduler, bucket_length,
                                   cache_insert_slot, cache_select_active)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.8
    top_k: int = 32
    max_new_tokens: int = 64
    greedy: bool = False


def sample_token(logits: jnp.ndarray, key, scfg: ServeConfig) -> jnp.ndarray:
    """logits (B, 1, V[, K-codebooks already folded]) -> token ids (B, 1).
    Temperature + top-k sampling (paper App. E benchmark settings:
    temperature 0.8, top-k 32)."""
    lf = logits.astype(jnp.float32)
    if lf.ndim == 4:                       # audio: (B, 1, K, V)
        lf = lf.reshape(lf.shape[0], -1, lf.shape[-1])  # (B, K, V)
    else:
        lf = lf[:, -1]                                   # (B, V)
        lf = lf[:, None]                                 # (B, 1, V)
    if scfg.greedy:
        out = jnp.argmax(lf, axis=-1)
    else:
        lf = lf / max(scfg.temperature, 1e-6)
        if scfg.top_k:
            kth = jax.lax.top_k(lf, scfg.top_k)[0][..., -1:]
            lf = jnp.where(lf < kth, -jnp.inf, lf)
        out = jax.random.categorical(key, lf, axis=-1)
    return out.astype(jnp.int32)           # (B, 1) or (B, K)


def make_serve_step(cfg: ModelConfig):
    """(params, token (B,1[,K]), cache, pos) -> (logits, new_cache)."""
    def serve_step(params, token, cache, pos):
        return T.decode_step(params, cfg, token, cache, pos)
    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    """(params, tokens (B,S)[, image_embeds]) -> (last logits, cache).

    The cache is created inside the step (sized max_len or S), so the
    lowered computation covers allocation + fill — what a serving runtime
    executes on admission."""
    def prefill_step(params, tokens, image_embeds=None):
        B, S = tokens.shape[0], tokens.shape[1]
        cache = T.init_cache(cfg, B, max_len or S)
        return T.prefill(params, cfg, tokens, cache, image_embeds)
    return prefill_step


def make_slot_prefill_step(cfg: ModelConfig, max_len: int):
    """(params, tokens (1, bucket[, K]), last_idx) -> (logits, cache).

    The single-slot admission unit: allocates a batch-1 cache sized
    `max_len` (so it inserts into the pooled cache shape-for-shape),
    prefills a right-padded prompt and reads logits at `last_idx`, the
    final real token. `last_idx` is traced, so one compilation covers
    every prompt length inside a bucket."""
    def prefill_step(params, tokens, last_idx, image_embeds=None):
        cache = T.init_cache(cfg, tokens.shape[0], max_len)
        return T.prefill(params, cfg, tokens, cache, image_embeds,
                         last_idx=last_idx)
    return prefill_step


def generate(params, cfg: ModelConfig, tokens, scfg: ServeConfig,
             key=None, image_embeds=None,
             jit_prefill=None, jit_decode=None) -> Tuple[Any, Any]:
    """Host-driven generation loop (prefill once, then decode steps).
    Returns (generated (B, max_new[,K]), per-step logits list)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S = tokens.shape[0], tokens.shape[1]
    max_len = S + scfg.max_new_tokens
    prefill = jit_prefill or jax.jit(make_prefill_step(cfg, max_len))
    decode = jit_decode or jax.jit(make_serve_step(cfg))

    if cfg.family == "vlm":
        logits, cache = prefill(params, tokens, image_embeds)
    else:
        logits, cache = prefill(params, tokens)
    outs = []
    tok = None
    for i in range(scfg.max_new_tokens):
        key, k = jax.random.split(key)
        tok = sample_token(logits, k, scfg)
        if cfg.family == "audio":
            tok = tok[:, None, :]          # (B, 1, K)
        outs.append(tok)
        logits, cache = decode(params, tok, cache, jnp.asarray(S + i))
    gen = jnp.concatenate(outs, axis=1)
    return gen, logits


# ===========================================================================
# continuous-batching engine
# ===========================================================================


class RequestHandle:
    """Streaming view of one submitted request.

    `tokens` grows as the engine emits; iterate the handle to stream
    (iteration pumps `engine.step()` when it runs out of buffered
    tokens), or call `result()` to block until completion."""

    def __init__(self, engine: "InferenceEngine", request: Request,
                 on_token: Optional[Callable] = None):
        self._engine = engine
        self.request = request
        self.uid = request.uid
        self.on_token = on_token
        self.tokens: List[Any] = []
        self.done = False
        self.submit_t = time.monotonic()
        self.finish_t: Optional[float] = None

    def _append(self, token) -> None:
        self.tokens.append(token)

    def result(self) -> np.ndarray:
        while not self.done:
            if not self._engine.in_flight:
                raise RuntimeError(
                    f"request {self.uid} unfinished but engine is idle")
            self._engine.step()
        return self.request.output

    def __iter__(self):
        i = 0
        while True:
            if i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            elif self.done:
                return
            else:
                if not self._engine.in_flight:
                    raise RuntimeError(
                        f"request {self.uid} unfinished but engine is idle")
                self._engine.step()

    @property
    def latency(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t


@dataclasses.dataclass
class _SlotTask:
    """Host-side record of the request occupying one decode slot."""
    handle: RequestHandle
    budget: int                        # new tokens still allowed
    toks: List[Any] = dataclasses.field(default_factory=list)


class InferenceEngine:
    """Slot-scheduled, continuously-batched serving engine.

    A fixed pool of `max_batch` decode slots over one persistent cache.
    Each slot carries its own position, budget and EOS state; one fused
    decode step advances every active slot (per-slot positions, cache
    writes and causal masks — see `models.transformer.decode_step`),
    while finished slots are masked on device into no-ops. Freed slots
    are refilled mid-flight: admission prefills the new prompt into a
    single-slot cache (right-padded to a power-of-two bucket so the
    prefill compiles once per bucket) and scatters it into the pool.

    `admission="wave"` reproduces the legacy drain-then-refill
    `BatchServer` schedule for comparison; greedy outputs are identical
    per request under either policy.

    `mesh` (optional) turns the engine tensor-parallel: packed U/s1 and
    V/s2 are placed per `sharding.rules` (Megatron col/row pairing —
    see `quant.surgery.place_on_mesh`), the pooled KV cache shards its
    kv-head (or sequence) dim over the `model` axis, and the jitted
    prefill / decode steps trace under a mesh-carrying `KernelPolicy`
    so every packed linear launches through the shard_map-wrapped fused
    kernel (`kernels.ops`). Greedy outputs are token-identical to the
    unsharded engine in f32 (bf16 near-tie argmaxes can flip under
    partitioned-reduction reorder — see ROADMAP Open items). With
    `mesh=None` (default) nothing changes — single-device dispatch, no
    placement, no collectives.

    Caveat (MoE families): capacity-bounded expert dispatch couples
    batch rows — any slot's tokens (including an inactive slot's masked
    pad row) consume per-expert capacity and can, under tight
    `capacity_factor`, drop an active neighbor's expert assignment.
    This is inherent to batched capacity-bounded MoE decode (the wave
    scheduler routed finished requests' real tokens, which is strictly
    worse); per-request identity with a solo decode holds exactly for
    non-MoE families and for MoE when capacity is not saturated.
    """

    def __init__(self, params, cfg: ModelConfig,
                 scfg: Optional[ServeConfig] = None, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0,
                 admission: str = "continuous", mesh=None,
                 sharding_policy=None):
        if kops.current_kernel_policy().use_merged_projections():
            # serving-side operand grouping: QKV / gate-up projections
            # additionally carry stacked operands so attention and MLP
            # issue one fused kernel launch instead of three/two. The
            # engine's copy only — saved artifacts keep the flat layout.
            from repro.quant.surgery import merge_projection_groups
            params = merge_projection_groups(params)
        self.mesh = mesh
        self._shard_policy = None
        self._kpolicy = None
        if mesh is not None:
            from repro.quant.surgery import place_on_mesh
            from repro.sharding import rules
            self._shard_policy = (sharding_policy if sharding_policy
                                  is not None else rules.SERVE)
            params = place_on_mesh(params, cfg, mesh, self._shard_policy)
            # tp_axis pinned to "model": sharding.rules only ever
            # places on that axis, and launch must agree with placement
            self._kpolicy = dataclasses.replace(
                kops.current_kernel_policy(), mesh=mesh, tp_axis="model")
        self.params, self.cfg = params, cfg
        self.scfg = scfg or ServeConfig()
        self.max_batch, self.max_len = max_batch, max_len
        self.key = jax.random.PRNGKey(seed)
        self.scheduler = SlotScheduler(max_batch, admission)
        self.cache = T.init_cache(cfg, max_batch, max_len)
        if mesh is not None:
            from repro.quant.surgery import place_cache_on_mesh
            self.cache = place_cache_on_mesh(self.cache, cfg, mesh,
                                             self._shard_policy)
        self.pos = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)
        tok_shape = ((max_batch, 1, cfg.n_codebooks)
                     if cfg.family == "audio" else (max_batch, 1))
        self.tokens = np.zeros(tok_shape, np.int32)
        self._tasks: List[Optional[_SlotTask]] = [None] * max_batch
        self._callbacks: List[Tuple[Callable, int, Any]] = []
        self.handles: Dict[int, RequestHandle] = {}
        self.done: Dict[int, Request] = {}
        # observability: per-uid admission/completion step and slot, plus
        # aggregate counters (trace counters increment at trace time only,
        # so they count *compilations*, not calls).
        self.slot_of: Dict[int, int] = {}
        self.admission_step: Dict[int, int] = {}
        self.completion_step: Dict[int, int] = {}
        self.stats: Dict[str, int] = {}
        self.reset_stats()

        slot_prefill = make_slot_prefill_step(cfg, max_len)

        def prefill_fn(params, tokens, last_idx):
            self.stats["prefill_traces"] += 1
            with self._trace_scope():
                return slot_prefill(params, tokens, last_idx)
        self._prefill = jax.jit(prefill_fn)
        # donate the pooled cache: insert/decode consume the old pool and
        # return the next one, so XLA can update it in place instead of
        # materializing a second full KV pool per token (the decode loop
        # is memory-bound — this is the dominant non-weight traffic).
        self._insert = jax.jit(cache_insert_slot, donate_argnums=(0,))

        def decode_fn(params, tokens, cache, pos, active, key):
            self.stats["decode_traces"] += 1
            with self._trace_scope():
                logits, new_cache = T.decode_step(params, cfg, tokens,
                                                  cache, pos)
                new_cache = cache_select_active(new_cache, cache, active)
                tok = sample_token(logits, key, self.scfg)
            if cfg.family == "audio":
                tok = tok[:, None, :]
            keep = active.reshape((-1,) + (1,) * (tok.ndim - 1))
            return jnp.where(keep, tok, 0), new_cache
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    @contextlib.contextmanager
    def _trace_scope(self):
        """Tracing context for the jitted steps. With a mesh, scopes in
        this engine's mesh-carrying kernel policy (shard_map TP kernel
        launches) and activation-sharding constraints — both
        contextvar-based, so concurrent traces from other engines or
        training cells are untouched, and dispatch is baked into the
        traced computation (execution needs no ambient globals)."""
        if self.mesh is None:
            yield
            return
        from repro.models import layers as L
        from repro.sharding import rules
        with L.activation_sharding(
                self.mesh, rules.data_axes(self.mesh),
                "model" if "model" in self.mesh.axis_names else None):
            with kops.kernel_policy(self._kpolicy):
                yield

    # ---- submission -------------------------------------------------------

    def submit(self, req: Request,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Queue a request; returns a streaming handle. `on_token`
        (optional) is called as `on_token(uid, token)` per emitted
        token. Rejects prompts that leave no room to generate; budgets
        beyond `max_len - prompt_len` are truncated."""
        prompt = np.asarray(req.prompt)
        n = prompt.shape[0]
        if n == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be "
                             f">= 1, got {req.max_new_tokens}")
        if n >= self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {n} >= max_len "
                f"{self.max_len} leaves no room to generate — raise "
                f"max_len or truncate the prompt before submitting")
        old = self.handles.get(req.uid)
        if old is not None:
            if not old.done:
                raise ValueError(f"duplicate request uid {req.uid} "
                                 f"still pending or decoding")
            self._forget(req.uid)          # uid reuse after completion
        handle = RequestHandle(self, req, on_token)
        self.handles[req.uid] = handle
        self.scheduler.submit(handle)
        return handle

    # ---- stepping ---------------------------------------------------------

    @property
    def in_flight(self) -> bool:
        return bool(self.scheduler.pending) or bool(self.active.any())

    def step(self) -> List[Request]:
        """One scheduler tick: admit into free slots, then one fused
        decode step across the pool. Returns requests finished now.

        User `on_token` callbacks fire only after every slot's engine
        state (positions, budgets, cache, completion bookkeeping) has
        been committed for the tick — a raising callback cannot leave
        the engine inconsistent (the exception still propagates)."""
        finished = []
        self._callbacks = []
        for slot, handle in self.scheduler.admit_batch():
            fin = self._admit(slot, handle)
            if fin is not None:
                finished.append(fin)
        if self.active.any():
            self.key, k = jax.random.split(self.key)
            tok, self.cache = self._decode(
                self.params, jnp.asarray(self.tokens), self.cache,
                jnp.asarray(self.pos), jnp.asarray(self.active), k)
            tok = np.array(tok)        # writable copy: slots mutate it
            self.tokens = tok
            self.stats["decode_steps"] += 1
            self.stats["wasted_slot_steps"] += int(
                self.max_batch - self.active.sum())
            for slot in range(self.max_batch):
                if not self.active[slot]:
                    continue
                self.pos[slot] += 1
                fin = self._emit(slot, tok[slot][0])
                if fin is not None:
                    finished.append(fin)
        self.stats["steps"] += 1
        callbacks, self._callbacks = self._callbacks, []
        err = None
        for cb, uid, token in callbacks:
            try:
                cb(uid, token)
            except BaseException as e:     # deliver to every consumer,
                err = err or e             # then surface the first error
        if err is not None:
            raise err
        return finished

    def run(self) -> Dict[int, Request]:
        """Drain the queue; returns {uid: completed Request}."""
        while self.in_flight:
            self.step()
        return dict(self.done)

    def reset_stats(self) -> None:
        for k in ("steps", "decode_steps", "wasted_slot_steps",
                  "tokens_emitted", "admissions", "prefill_traces",
                  "decode_traces"):
            self.stats[k] = 0

    def _forget(self, uid: int) -> None:
        for d in (self.handles, self.done, self.slot_of,
                  self.admission_step, self.completion_step):
            d.pop(uid, None)

    def clear_finished(self) -> None:
        """Drop bookkeeping (handles, outputs, step logs) for completed
        requests — reclaims memory on a long-running server. Callers
        keep their RequestHandles; only the engine's references go."""
        for uid in list(self.done):
            self._forget(uid)

    # ---- internals --------------------------------------------------------

    def _admit(self, slot: int, handle: RequestHandle) -> Optional[Request]:
        """Prefill `handle`'s prompt into `slot` and emit its first
        token. Returns the request if it finished immediately."""
        req = handle.request
        prompt = np.asarray(req.prompt, np.int32)
        n = prompt.shape[0]
        if self.cfg.is_ssm_layer_stack:
            # right-padding would leak pad tokens into the recurrent
            # SSM/conv state, so SSM-stack families prefill at the exact
            # prompt length (one compile per distinct length).
            bucket = n
        else:
            bucket = bucket_length(n, self.max_len)
        padded = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
        padded[0, :n] = prompt
        logits, single = self._prefill(self.params, jnp.asarray(padded),
                                       jnp.asarray(n - 1, jnp.int32))
        self.cache = self._insert(self.cache, single,
                                  jnp.asarray(slot, jnp.int32))
        self.key, k = jax.random.split(self.key)
        tok = sample_token(logits, k, self.scfg)       # (1,1) or (1,K)
        if self.cfg.family == "audio":
            tok = tok[:, None, :]                      # (1,1,K)
        tok = np.asarray(tok)
        task = _SlotTask(handle, budget=min(req.max_new_tokens,
                                            self.max_len - n))
        self._tasks[slot] = task
        self.pos[slot] = n
        self.slot_of[req.uid] = slot
        self.admission_step[req.uid] = self.stats["steps"]
        self.stats["admissions"] += 1
        fin = self._emit(slot, tok[0][0])
        if fin is None:
            self.active[slot] = True
            self.tokens[slot] = tok[0]
        return fin

    def _emit(self, slot: int, token) -> Optional[Request]:
        """Record one emitted token for `slot`; finish the slot on EOS
        or budget exhaustion. `token`: scalar (text) or (K,) (audio)."""
        task = self._tasks[slot]
        req = task.handle.request
        task.toks.append(np.asarray(token))
        task.budget -= 1
        self.stats["tokens_emitted"] += 1
        task.handle._append(token)
        if task.handle.on_token is not None:   # deferred to end of step()
            self._callbacks.append((task.handle.on_token,
                                    task.handle.uid, token))
        flat = int(token if np.ndim(token) == 0 else token[0])
        if (req.eos_id is not None and flat == req.eos_id) \
                or task.budget <= 0:
            return self._finish(slot)
        return None

    def _finish(self, slot: int) -> Request:
        task = self._tasks[slot]
        req = task.handle.request
        req.output = np.asarray(task.toks, np.int32)
        self.done[req.uid] = req
        self.completion_step[req.uid] = self.stats["steps"]
        task.handle.done = True
        task.handle.finish_t = time.monotonic()
        self.active[slot] = False
        self._tasks[slot] = None
        self.scheduler.release(slot)
        return req
