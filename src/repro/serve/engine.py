"""Serving engine: slot-scheduled continuous batching + the raw
prefill / decode steps and sampling.

``serve_step`` is the unit the decode-shape dry-runs lower: one new token
against a KV (or SSM-state) cache — memory-bound, and exactly where the
paper's packed binary weights pay off (the whole weight stream shrinks
~16x, see §Roofline FP-vs-quantized decode comparison).

:class:`InferenceEngine` is the serving surface built on those steps: a
fixed pool of ``max_batch`` decode slots over one persistent cache,
where each slot carries its own position, token budget and EOS state.
Freed slots are refilled mid-flight by per-slot prefill (prompt lengths
bucketed to powers of two so prefill compiles once per bucket), and
finished slots are masked on device so they are no-ops until refilled.

    engine = InferenceEngine(params, cfg, ServeConfig(), max_batch=8)
    handle = engine.submit(Request(0, prompt), on_token=print)
    for tok in handle:          # streams; pumps engine.step() as needed
        ...
    done = engine.run()         # or drain everything at once
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve import paging
from repro.serve.faults import InjectedDeviceError as _InjectedDeviceError
from repro.serve.scheduler import (Request, SlotScheduler, bucket_length,
                                   cache_insert_slot, cache_select_active,
                                   pick_preemption_victim)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.8
    top_k: int = 32
    max_new_tokens: int = 64
    greedy: bool = False
    # --- paged KV cache (docs/serving.md §Paged KV cache) ---
    # paged=True (default) backs the engine's persistent cache with a
    # page pool + per-slot block tables (serve.paging); paged=False
    # keeps the rectangular max_batch x max_len pool (the oracle layout,
    # kept for one release). Families with no pageable KV (pure SSM)
    # silently stay rectangular.
    paged: bool = True
    page_size: int = 64                    # KV rows per page (clamped to
    #                                        max_len for tiny servers)
    # total pool pages; None = full capacity (max_batch worst-case slots
    # + the null page — a drop-in for the rectangle). Smaller values
    # OVERCOMMIT: admission gates on free pages, decode reserves lazily,
    # and the engine preempts the youngest slot if the pool runs dry.
    kv_pool_pages: Optional[int] = None
    page_watermark: int = 0                # extra free pages required
    #                                        to admit (beyond the prompt)
    # --- prefix caching (docs/serving.md §Prefix caching) ---
    # prefix_cache=True (default) shares prompt-prefix KV pages across
    # requests through a chained-hash index (serve.prefix): admission
    # maps the longest cached prefix read-only and prefills only the
    # suffix; writes into shared pages copy-on-write; cached pages are
    # evicted LRU at refcount zero under pool pressure. Greedy outputs
    # stay token-identical to the no-sharing engine. Requires the paged
    # linear-only-table cache and a token-determined KV (ring/hybrid,
    # SSM and VLM families silently serve unshared).
    prefix_cache: bool = True
    # --- self-speculative decoding (docs/serving.md §Speculative) ---
    # spec_rank_frac enables the rank-truncated draft: each engine tick
    # drafts up to spec_k tokens through a zero-copy rank-r' view of the
    # packed params (quant.surgery.rank_truncated_view) and verifies
    # them in ONE batched full-rank forward. Greedy outputs stay
    # token-identical to the plain engine. Requires greedy=True and the
    # paged linear-table cache (serve.speculative validates).
    spec_rank_frac: Optional[float] = None  # draft rank fraction (0, 1]
    spec_k: int = 4                         # max draft tokens per cycle
    spec_k_min: int = 1                     # dynamic-k controller floor
    # --- robustness (docs/serving.md §Failure handling) ---
    # debug=True audits the page-pool invariants
    # (paging.check_invariants) and the slot/task alignment at the end
    # of every tick instead of only on faults. Pure host work; meant
    # for tests, chaos runs and bring-up, not the steady-state hot
    # path.
    debug: bool = False
    # --- decode megakernel (docs/kernels.md §Decode megakernel) ---
    # tri-state: None defers to the ambient KernelPolicy (megakernel on
    # by default on the fused merged pallas path); True/False force the
    # policy bit for this engine's traces. Per-launch qualification
    # still applies — non-qualifying shapes (TP mesh, oversized rank)
    # fall back to the unfused chain with identical greedy outputs.
    megakernel: Optional[bool] = None


def sample_token(logits: jnp.ndarray, key, scfg: ServeConfig) -> jnp.ndarray:
    """logits (B, 1, V[, K-codebooks already folded]) -> token ids (B, 1).
    Temperature + top-k sampling (paper App. E benchmark settings:
    temperature 0.8, top-k 32)."""
    lf = logits.astype(jnp.float32)
    if lf.ndim == 4:                       # audio: (B, 1, K, V)
        lf = lf.reshape(lf.shape[0], -1, lf.shape[-1])  # (B, K, V)
    else:
        lf = lf[:, -1]                                   # (B, V)
        lf = lf[:, None]                                 # (B, 1, V)
    if scfg.greedy:
        out = jnp.argmax(lf, axis=-1)
    else:
        lf = lf / max(scfg.temperature, 1e-6)
        if scfg.top_k:
            kth = jax.lax.top_k(lf, scfg.top_k)[0][..., -1:]
            lf = jnp.where(lf < kth, -jnp.inf, lf)
        out = jax.random.categorical(key, lf, axis=-1)
    return out.astype(jnp.int32)           # (B, 1) or (B, K)


def make_serve_step(cfg: ModelConfig):
    """(params, token (B,1[,K]), cache, pos) -> (logits, new_cache)."""
    def serve_step(params, token, cache, pos):
        return T.decode_step(params, cfg, token, cache, pos)
    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    """(params, tokens (B,S)[, image_embeds]) -> (last logits, cache).

    The cache is created inside the step (sized max_len or S), so the
    lowered computation covers allocation + fill — what a serving runtime
    executes on admission."""
    def prefill_step(params, tokens, image_embeds=None):
        B, S = tokens.shape[0], tokens.shape[1]
        cache = T.init_cache(cfg, B, max_len or S)
        return T.prefill(params, cfg, tokens, cache, image_embeds)
    return prefill_step


def make_slot_prefill_step(cfg: ModelConfig, max_len: int):
    """(params, tokens (1, bucket[, K]), last_idx) -> (logits, cache).

    The single-slot admission unit: allocates a batch-1 cache sized
    `max_len` (so it inserts into the pooled cache shape-for-shape),
    prefills a right-padded prompt and reads logits at `last_idx`, the
    final real token. `last_idx` is traced, so one compilation covers
    every prompt length inside a bucket."""
    def prefill_step(params, tokens, last_idx, image_embeds=None):
        cache = T.init_cache(cfg, tokens.shape[0], max_len)
        return T.prefill(params, cfg, tokens, cache, image_embeds,
                         last_idx=last_idx)
    return prefill_step


def generate(params, cfg: ModelConfig, tokens, scfg: ServeConfig,
             key=None, image_embeds=None,
             jit_prefill=None, jit_decode=None) -> Tuple[Any, Any]:
    """Host-driven generation loop (prefill once, then decode steps).
    Returns (generated (B, max_new[,K]), per-step logits list)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S = tokens.shape[0], tokens.shape[1]
    max_len = S + scfg.max_new_tokens
    prefill = jit_prefill or jax.jit(make_prefill_step(cfg, max_len))
    decode = jit_decode or jax.jit(make_serve_step(cfg))

    if cfg.family == "vlm":
        logits, cache = prefill(params, tokens, image_embeds)
    else:
        logits, cache = prefill(params, tokens)
    outs = []
    tok = None
    for i in range(scfg.max_new_tokens):
        key, k = jax.random.split(key)
        tok = sample_token(logits, k, scfg)
        if cfg.family == "audio":
            tok = tok[:, None, :]          # (B, 1, K)
        outs.append(tok)
        logits, cache = decode(params, tok, cache, jnp.asarray(S + i))
    gen = jnp.concatenate(outs, axis=1)
    return gen, logits


# ===========================================================================
# continuous-batching engine
# ===========================================================================


#: Terminal request statuses. "done" is the only successful one;
#: the other three carry a :class:`RequestError` on the handle.
TERMINAL_STATUSES = ("done", "cancelled", "expired", "failed")


class RequestError(RuntimeError):
    """Structured terminal error for one request: the request reached a
    non-successful terminal status (``cancelled`` / ``expired`` /
    ``failed``) while the rest of the engine kept serving. Raised by
    ``RequestHandle.result()`` and at the end of handle iteration;
    also stored on ``handle.error``."""

    def __init__(self, uid: int, status: str, reason: str):
        super().__init__(f"request {uid} {status}: {reason}")
        self.uid = uid
        self.status = status
        self.reason = reason


class RequestHandle:
    """Streaming view of one submitted request.

    `tokens` grows as the engine emits; iterate the handle to stream
    (iteration pumps `engine.step()` when it runs out of buffered
    tokens), or call `result()` to block until completion.

    Lifecycle (docs/serving.md §Failure handling): ``status`` moves
    ``"pending"`` → ``"running"`` (first admission; preemption does not
    move it back) → one of :data:`TERMINAL_STATUSES`. Non-``done``
    terminals carry a :class:`RequestError` on ``error``; ``result()``
    raises it instead of returning a partial array, and iteration
    yields whatever was emitted before the terminal, then raises.
    ``cancel()`` requests cancellation; the engine honours it at the
    next tick boundary (tokens may still arrive in between)."""

    def __init__(self, engine: "InferenceEngine", request: Request,
                 on_token: Optional[Callable] = None):
        self._engine = engine
        self.request = request
        self.uid = request.uid
        self.on_token = on_token
        self.tokens: List[Any] = []
        self.status = "pending"
        self.error: Optional[RequestError] = None
        self.cancel_requested = False
        self.cancel_reason = "cancelled by client"
        self.deadline_at: Optional[float] = None   # engine-clock absolute
        self.submit_t = time.monotonic()
        self.first_token_t: Optional[float] = None
        self.finish_t: Optional[float] = None

    @property
    def finished(self) -> bool:
        """True once the request reached any terminal status."""
        return self.status in TERMINAL_STATUSES

    @property
    def done(self) -> bool:
        """True only for the *successful* terminal status."""
        return self.status == "done"

    def cancel(self, reason: str = "cancelled by client") -> None:
        """Request cancellation. Takes effect at the engine's next tick
        boundary: a queued request is dropped before admission, an
        active slot is torn down with its pages freed exactly (the
        preemption teardown path). No-op once terminal."""
        if not self.finished:
            self.cancel_requested = True
            self.cancel_reason = reason

    def _finalize(self, status: str,
                  error: Optional[RequestError] = None) -> None:
        assert status in TERMINAL_STATUSES, status
        self.status = status
        self.error = error
        self.finish_t = time.monotonic()

    def _append(self, token) -> None:
        if self.first_token_t is None:
            self.first_token_t = time.monotonic()
        self.tokens.append(token)

    def result(self) -> np.ndarray:
        """Block (pumping the engine) until terminal; return the full
        output, or raise this request's :class:`RequestError` if it
        ended cancelled / expired / failed."""
        while not self.finished:
            if not self._engine.in_flight:
                raise RuntimeError(
                    f"request {self.uid} unfinished but engine is idle")
            self._engine.step()
        if self.error is not None:
            raise self.error
        return self.request.output

    def __iter__(self):
        # a fresh iterator per call, starting from token 0 — re-iterating
        # a finished handle replays the buffered tokens instead of
        # silently yielding nothing
        i = 0
        while True:
            if i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            elif self.finished:
                if self.error is not None:
                    raise self.error
                return
            else:
                if not self._engine.in_flight:
                    raise RuntimeError(
                        f"request {self.uid} unfinished but engine is idle")
                self._engine.step()

    @property
    def latency(self) -> Optional[float]:
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token: submission -> first emitted token (the
        admission-queue wait plus the prefill). What prefix caching
        shrinks — both directly (suffix-only prefill) and through
        admission headroom (shared pages are nearly free to admit)."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


class _AbortAdmission(Exception):
    """Internal: a cancel/expire landed mid-prefill (noticed between
    the prefill and slot activation); unwind to the given terminal."""

    def __init__(self, status: str, reason: str):
        super().__init__(f"{status}: {reason}")
        self.status = status
        self.reason = reason


@dataclasses.dataclass
class _SlotTask:
    """Host-side record of the request occupying one decode slot."""
    handle: RequestHandle
    budget: int                        # new tokens still allowed
    toks: List[Any] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Resume:
    """A preempted request re-queued for admission (paged engine, pool
    exhausted mid-decode): re-prefills prompt + already-emitted tokens
    and continues with the remaining budget. Greedy decoding makes the
    recompute token-exact; already-emitted tokens are never re-emitted."""
    handle: RequestHandle
    prompt: np.ndarray                 # original prompt + emitted tokens
    budget: int                        # new tokens still allowed
    emitted: List[Any] = dataclasses.field(default_factory=list)

    @property
    def uid(self) -> int:
        return self.handle.uid


class InferenceEngine:
    """Slot-scheduled, continuously-batched serving engine.

    A fixed pool of `max_batch` decode slots over one persistent cache.
    Each slot carries its own position, budget and EOS state; one fused
    decode step advances every active slot (per-slot positions, cache
    writes and causal masks — see `models.transformer.decode_step`),
    while finished slots are masked on device into no-ops. Freed slots
    are refilled mid-flight: admission prefills the new prompt into a
    single-slot cache (right-padded to a power-of-two bucket so the
    prefill compiles once per bucket) and scatters it into the pool.

    `admission="wave"` reproduces the legacy drain-then-refill
    `BatchServer` schedule for comparison; greedy outputs are identical
    per request under either policy.

    The persistent cache is a **paged KV pool** by default
    (`ServeConfig.paged`, serve.paging): fixed-size pages + per-slot
    block tables instead of a `max_batch x max_len` rectangle. Pages
    are reserved at admission for the prompt, lazily per decode step as
    a slot crosses a page boundary, and freed on completion. With
    `kv_pool_pages` below full capacity the pool *overcommits* total
    sequence capacity: admission gates on free pages (FIFO, queueing
    instead of crashing when exhausted) and a dry pool preempts the
    youngest slot (token-exact re-prefill under greedy). Greedy outputs
    are token-identical to the rectangular engine
    (`ServeConfig(paged=False)`, the oracle layout).

    `mesh` (optional) turns the engine tensor-parallel: packed U/s1 and
    V/s2 are placed per `sharding.rules` (Megatron col/row pairing —
    see `quant.surgery.place_on_mesh`), the pooled KV cache shards its
    kv-head (or sequence) dim over the `model` axis, and the jitted
    prefill / decode steps trace under a mesh-carrying `KernelPolicy`
    so every packed linear launches through the shard_map-wrapped fused
    kernel (`kernels.ops`). Greedy outputs are token-identical to the
    unsharded engine in f32 (bf16 near-tie argmaxes can flip under
    partitioned-reduction reorder — see ROADMAP Open items). With
    `mesh=None` (default) nothing changes — single-device dispatch, no
    placement, no collectives.

    Caveat (MoE families): capacity-bounded expert dispatch couples
    batch rows — any slot's tokens (including an inactive slot's masked
    pad row) consume per-expert capacity and can, under tight
    `capacity_factor`, drop an active neighbor's expert assignment.
    This is inherent to batched capacity-bounded MoE decode (the wave
    scheduler routed finished requests' real tokens, which is strictly
    worse); per-request identity with a solo decode holds exactly for
    non-MoE families and for MoE when capacity is not saturated.
    """

    def __init__(self, params, cfg: ModelConfig,
                 scfg: Optional[ServeConfig] = None, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0,
                 admission: str = "continuous", mesh=None,
                 sharding_policy=None, faults=None, clock=None):
        if kops.current_kernel_policy().use_merged_projections():
            # serving-side operand grouping: QKV / gate-up projections
            # additionally carry stacked operands so attention and MLP
            # issue one fused kernel launch instead of three/two. The
            # engine's copy only — saved artifacts keep the flat layout.
            from repro.quant.surgery import merge_projection_groups
            params = merge_projection_groups(params)
        self.mesh = mesh
        self._shard_policy = None
        self._kpolicy = None
        if mesh is not None:
            from repro.quant.surgery import place_on_mesh
            from repro.sharding import rules
            self._shard_policy = (sharding_policy if sharding_policy
                                  is not None else rules.SERVE)
            params = place_on_mesh(params, cfg, mesh, self._shard_policy)
            # tp_axis pinned to "model": sharding.rules only ever
            # places on that axis, and launch must agree with placement
            self._kpolicy = dataclasses.replace(
                kops.current_kernel_policy(), mesh=mesh, tp_axis="model")
        self.params, self.cfg = params, cfg
        self.scfg = scfg or ServeConfig()
        self.max_batch, self.max_len = max_batch, max_len
        self.key = jax.random.PRNGKey(seed)
        self.scheduler = SlotScheduler(max_batch, admission)
        # deadline clock: monotonic seconds. Injectable so tests and the
        # fault harness can expire requests deterministically.
        self.clock: Callable[[], float] = clock or time.monotonic
        # fault-injection plan (serve.faults.FaultPlan) — None in
        # production; when set, its hooks fire at the engine's seams.
        self.faults = faults
        # drain(): True stops admission of fresh requests (preempted
        # _Resume items still re-admit, so in-flight work can finish).
        self.draining = False
        # paged KV pool (serve.paging) unless disabled or the family has
        # no pageable cache (pure SSM state is O(1)/slot either way)
        self.kv: Optional[paging.PagedKVState] = None
        kinds = paging.cache_page_kinds(cfg, max_len) if self.scfg.paged \
            else set()
        if kinds:
            self.kv = paging.PagedKVState(
                cfg, max_batch, max_len, self.scfg.page_size,
                self.scfg.kv_pool_pages, self.scfg.page_watermark,
                kinds=kinds)
        self.paged = self.kv is not None
        if self.paged:
            self.cache = paging.init_paged_cache(
                cfg, max_batch, max_len, self.kv.n_pages, self.kv.page_size)
        else:
            self.cache = T.init_cache(cfg, max_batch, max_len)
        if mesh is not None:
            from repro.quant.surgery import place_cache_on_mesh
            self.cache = place_cache_on_mesh(self.cache, cfg, mesh,
                                             self._shard_policy,
                                             paged=self.paged)
        self.pos = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)
        tok_shape = ((max_batch, 1, cfg.n_codebooks)
                     if cfg.family == "audio" else (max_batch, 1))
        self.tokens = np.zeros(tok_shape, np.int32)
        self._tasks: List[Optional[_SlotTask]] = [None] * max_batch
        self._callbacks: List[Tuple[Callable, int, Any]] = []
        self.handles: Dict[int, RequestHandle] = {}
        self.done: Dict[int, Request] = {}
        # observability: per-uid admission/completion step and slot, plus
        # aggregate counters (trace counters increment at trace time only,
        # so they count *compilations*, not calls).
        self.slot_of: Dict[int, int] = {}
        self.admission_step: Dict[int, int] = {}
        self.completion_step: Dict[int, int] = {}
        self.stats: Dict[str, int] = {}
        self.reset_stats()

        # prefix cache (serve.prefix): share prompt-prefix KV pages
        # across requests. Linear-only table families with token-
        # determined KV; the VLM's cache depends on image embeddings the
        # index cannot key, so it serves unshared.
        self.prefix = None
        if self.paged and self.scfg.prefix_cache \
                and set(self.kv.tables) == {"linear"} \
                and cfg.family != "vlm":
            from repro.serve.prefix import PrefixCache
            self.prefix = PrefixCache(self.kv, self.stats)

        slot_prefill = make_slot_prefill_step(cfg, max_len)

        def prefill_fn(params, tokens, last_idx):
            self.stats["prefill_traces"] += 1
            with self._trace_scope():
                return slot_prefill(params, tokens, last_idx)
        self._prefill = jax.jit(prefill_fn)

        # suffix prefill (prefix-cache hits): run only the uncached
        # tail of a prompt directly against the donated pool, writing
        # rows [start, start+S) through the slot's linear block table —
        # the admission-sized sibling of the speculative S>1 verify.
        def suffix_fn(params, tokens, start, last_idx, cache, table):
            self.stats["prefill_traces"] += 1
            with self._trace_scope():
                return T.prefill(params, cfg, tokens, cache,
                                 last_idx=last_idx, start_pos=start,
                                 block_tables={"linear": table})
        self._suffix_prefill = jax.jit(suffix_fn, donate_argnums=(4,))
        # copy-on-write page duplication (one compile, traced page ids)
        self._copy_page = jax.jit(paging.copy_page, donate_argnums=(0,))
        # donate the pooled cache: insert/decode consume the old pool and
        # return the next one, so XLA can update it in place instead of
        # materializing a second full KV pool per token (the decode loop
        # is memory-bound — this is the dominant non-weight traffic).
        # Same discipline for the paged pool: the page scatters and
        # block-table-walking decode writes update the donated buffers.
        if self.paged:
            self._insert = jax.jit(paging.paged_insert_slot,
                                   donate_argnums=(0,))
        else:
            self._insert = jax.jit(cache_insert_slot, donate_argnums=(0,))
        select_active = (paging.paged_select_active if self.paged
                         else cache_select_active)

        def decode_fn(params, tokens, cache, pos, active, key, tables):
            self.stats["decode_traces"] += 1
            with self._trace_scope():
                logits, new_cache = T.decode_step(params, cfg, tokens,
                                                  cache, pos,
                                                  block_tables=tables)
                new_cache = select_active(new_cache, cache, active)
                tok = sample_token(logits, key, self.scfg)
            if cfg.family == "audio":
                tok = tok[:, None, :]
            keep = active.reshape((-1,) + (1,) * (tok.ndim - 1))
            return jnp.where(keep, tok, 0), new_cache
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

        self.spec = None
        if self.scfg.spec_rank_frac is not None:
            from repro.serve.speculative import SpecDecodeController
            self.spec = SpecDecodeController(self)

    @contextlib.contextmanager
    def _trace_scope(self):
        """Tracing context for the jitted steps. Scopes in this engine's
        kernel policy (the ambient policy, plus the ServeConfig's
        megakernel override and — with a mesh — the mesh for shard_map
        TP kernel launches) and, with a mesh, activation-sharding
        constraints. Both are contextvar-based, so concurrent traces
        from other engines or training cells are untouched, and dispatch
        is baked into the traced computation (execution needs no ambient
        globals)."""
        pol = self._kpolicy if self._kpolicy is not None \
            else kops.current_kernel_policy()
        if self.scfg.megakernel is not None:
            pol = dataclasses.replace(pol,
                                      megakernel=self.scfg.megakernel)
        if self.mesh is None:
            with kops.kernel_policy(pol):
                yield
            return
        from repro.models import layers as L
        from repro.sharding import rules
        with L.activation_sharding(
                self.mesh, rules.data_axes(self.mesh),
                "model" if "model" in self.mesh.axis_names else None):
            with kops.kernel_policy(pol):
                yield

    # ---- submission -------------------------------------------------------

    def submit(self, req: Request,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Queue a request; returns a streaming handle. `on_token`
        (optional) is called as `on_token(uid, token)` per emitted
        token. Rejects prompts that leave no room to generate; budgets
        beyond `max_len - prompt_len` are truncated."""
        prompt = np.asarray(req.prompt)
        n = prompt.shape[0]
        if n == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be "
                             f">= 1, got {req.max_new_tokens}")
        if n >= self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {n} >= max_len "
                f"{self.max_len} leaves no room to generate — raise "
                f"max_len or truncate the prompt before submitting")
        if prompt.size and (prompt.min() < 0
                            or prompt.max() >= self.cfg.vocab_size):
            raise ValueError(
                f"request {req.uid}: prompt token ids outside "
                f"[0, {self.cfg.vocab_size}) — refusing to embed "
                f"out-of-vocabulary ids")
        if req.deadline_s is not None and req.deadline_s < 0:
            raise ValueError(f"request {req.uid}: deadline_s must be "
                             f">= 0, got {req.deadline_s}")
        if self.paged:
            need = self.kv.pages_for_prompt(n)
            if need + self.kv.watermark > self.kv.n_pages - 1:
                raise ValueError(
                    f"request {req.uid}: prompt needs {need} pages but "
                    f"the pool holds {self.kv.n_pages - 1} (watermark "
                    f"{self.kv.watermark}) — it could never be admitted")
        old = self.handles.get(req.uid)
        if old is not None:
            if not old.finished:
                raise ValueError(f"duplicate request uid {req.uid} "
                                 f"still pending or decoding")
            self._forget(req.uid)          # uid reuse after completion
        handle = RequestHandle(self, req, on_token)
        if req.deadline_s is not None:
            handle.deadline_at = self.clock() + req.deadline_s
        self.handles[req.uid] = handle
        self.scheduler.submit(handle)
        return handle

    # ---- stepping ---------------------------------------------------------

    @property
    def in_flight(self) -> bool:
        return bool(self.scheduler.pending) or bool(self.active.any())

    def step(self) -> List[Request]:
        """One scheduler tick: admit into free slots, then one fused
        decode step across the pool. Returns requests finished now.

        User `on_token` callbacks fire only after every slot's engine
        state (positions, budgets, cache, completion bookkeeping) has
        been committed for the tick — a raising callback cannot leave
        the engine inconsistent (the exception still propagates)."""
        finished = []
        self._callbacks = []
        if self.faults is not None:
            self.faults.on_step(self)
        self._reap()
        gate = None
        if self.paged:
            promised = [0]     # pages owed to earlier admissions in this
            #                    batch (kv.admit runs after admit_batch)

            def gate(item):
                n = self._item_prompt_len(item)
                need = self.kv.pages_for_prompt(n)
                if self.prefix is not None:
                    # a matched prefix is nearly free admission: shared
                    # pages only bump refcounts. A full-cover match
                    # still pays one page — the tail is copy-on-written
                    # so the re-emitted last row has a private home.
                    p, pages, keys = self.prefix.match(
                        self._item_prompt(item))
                    need += (1 if p == n else 0) - len(pages)
                    # pin the matched chain BEFORE the availability
                    # check: available_pages must not count the pages
                    # this item is about to share as evictable slack,
                    # and a later admission's reclaim in this batch
                    # must not evict them before kv.admit refs them
                    # (_admit re-matches; protection guarantees the
                    # fresh match finds at least this chain)
                    self.prefix.protect(keys)
                # the watermark holds back slack for *fresh* work only:
                # a preempted _Resume was already admitted once and its
                # grown prompt (<= one slot's worst case, which always
                # fits) may legitimately exceed what submit() validated
                # — gating it on the watermark could livelock the queue.
                wm = 0 if isinstance(item, _Resume) else self.kv.watermark
                # available_pages counts evictable cached pages too —
                # reclaim frees them on demand during kv.admit
                ok = self.kv.available_pages - promised[0] - need >= wm
                if ok:
                    promised[0] += need
                else:
                    self.stats["page_waits"] += 1
                return ok
        page_gate = gate
        if self.draining or self.faults is not None:
            def gate(item):               # noqa: F811 — wraps page_gate
                if self.draining and not isinstance(item, _Resume):
                    return False          # drain: no fresh admissions
                if self.faults is not None:
                    # e.g. evict a matched prefix chain between the
                    # match and kv.admit — protection must hold it
                    self.faults.on_gate(self)
                return page_gate(item) if page_gate is not None else True
        for slot, handle in self.scheduler.admit_batch(gate):
            fin = self._admit(slot, handle)
            if fin is not None:
                finished.append(fin)
        if self.prefix is not None:
            self.prefix.unprotect_all()
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        int(self.active.sum()))
        if self.active.any():
            t0 = time.monotonic()
            try:
                if self.spec is not None:
                    self.spec.tick(finished)
                else:
                    self._decode_tick(finished)
            except _InjectedDeviceError as e:
                self._on_device_fault(e)
            self.stats["decode_time_s"] += time.monotonic() - t0
        self.stats["steps"] += 1
        if self.scfg.debug:
            self.check_invariants()
        callbacks, self._callbacks = self._callbacks, []
        err = None
        for cb, uid, token in callbacks:
            try:
                cb(uid, token)
            except BaseException as e:     # deliver to every consumer,
                err = err or e             # then surface the first error
        if err is not None:
            raise err
        return finished

    def run(self) -> Dict[int, Request]:
        """Drain the queue; returns {uid: completed Request}."""
        while self.in_flight:
            self.step()
        return dict(self.done)

    # ---- request lifecycle: cancellation, deadlines, drain ----------------

    def _verdict(self, handle: RequestHandle) -> Optional[Tuple[str, str]]:
        """(terminal_status, reason) if `handle` should be reaped now
        (client cancellation or past deadline), else None."""
        if handle.cancel_requested:
            return "cancelled", handle.cancel_reason
        if handle.deadline_at is not None \
                and self.clock() >= handle.deadline_at:
            return "expired", (f"deadline "
                               f"{handle.request.deadline_s}s exceeded")
        return None

    @staticmethod
    def _item_handle(item) -> RequestHandle:
        return item.handle if isinstance(item, _Resume) else item

    def _reap(self) -> None:
        """Tick-boundary reaping: drop cancelled/expired requests from
        the queue and tear down cancelled/expired active slots, freeing
        pages and prefix refcounts exactly (the preemption teardown
        path minus the requeue)."""
        if not (self.scheduler.pending or self.active.any()):
            return
        for item in self.scheduler.reap(
                lambda it: self._verdict(self._item_handle(it)) is not None):
            handle = self._item_handle(item)
            status, reason = self._verdict(handle)
            toks = item.emitted if isinstance(item, _Resume) else []
            self._finalize_aborted(handle, status, reason, toks)
        for slot in np.nonzero(self.active)[0]:
            task = self._tasks[int(slot)]
            v = self._verdict(task.handle)
            if v is not None:
                self._abort_slot(int(slot), *v)

    def _abort_slot(self, slot: int, status: str, reason: str) -> None:
        """Tear down an active slot to a non-successful terminal: the
        preemption teardown (pages + prefix refcounts freed exactly)
        without the requeue, then finalize the handle."""
        task = self._tasks[slot]
        self.active[slot] = False
        self._tasks[slot] = None
        self.slot_of.pop(task.handle.uid, None)
        if self.paged:
            self.kv.release(slot)
        self.scheduler.release(slot)
        self._finalize_aborted(task.handle, status, reason, task.toks)
        if status == "failed":             # every fault audits the pool
            self.check_invariants()

    def _finalize_aborted(self, handle: RequestHandle, status: str,
                          reason: str, toks: List[Any]) -> None:
        """Move `handle` to a non-successful terminal status. Partial
        output (tokens emitted before the terminal) stays readable on
        ``request.output`` / ``handle.tokens``; ``result()`` raises."""
        req = handle.request
        req.output = (np.asarray(toks, np.int32) if toks
                      else np.zeros((0,), np.int32))
        handle._finalize(status, RequestError(req.uid, status, reason))
        self.completion_step[req.uid] = self.stats["steps"]
        self.stats[status] += 1

    def _on_device_fault(self, err: "_InjectedDeviceError") -> None:
        """Recover from a (simulated) device error in the decode step:
        the error is raised *before* the donated device call, so the
        pool buffer is intact — fail the attributed slot with a
        structured RequestError, preempt every other active slot
        (token-exact resume re-prefills them), and audit the pool.
        Models the recoverable class of device faults; a real
        XlaRuntimeError after donation has no cache to resume from."""
        uid = err.uid if err.uid in self.slot_of else None
        if uid is None and self.active.any():
            slot = int(np.nonzero(self.active)[0][-1])
            uid = self._tasks[slot].handle.uid
        self.stats["device_faults"] += 1
        if uid is not None:
            self._abort_slot(self.slot_of[uid], "failed",
                             f"device error in decode step: {err}")
        if self.paged:
            for slot in np.nonzero(self.active)[0]:
                self._preempt(int(slot))
        # else: the rectangular engine keeps its cache (nothing was
        # donated before the raise) and the neighbours continue in place
        self.check_invariants()

    def drain(self, timeout: Optional[float] = None) -> Dict[int, Request]:
        """Graceful drain: stop admitting fresh requests, keep stepping
        until every active slot finishes (or `timeout` seconds of
        engine-clock pass), then checkpoint whatever is still active as
        requeued ``_Resume`` items — ``serve.recovery.snapshot`` can
        persist the result and rebuild an engine that resumes
        token-identically under greedy. Returns requests completed so
        far. Admission stays closed until :meth:`resume_admission`."""
        self.draining = True
        t0 = self.clock()
        while self.active.any():
            if timeout is not None and self.clock() - t0 >= timeout:
                break
            self.step()
        for slot in np.nonzero(self.active)[0]:
            if self.paged:
                self._preempt(int(slot))
            else:
                self._abort_slot(int(slot), "failed",
                                 "drain timeout: rectangular engine "
                                 "cannot checkpoint a live slot")
        return dict(self.done)

    def resume_admission(self) -> None:
        """Reopen admission after :meth:`drain`."""
        self.draining = False

    def check_invariants(self) -> None:
        """Audit page-pool accounting (paging.check_invariants), the
        prefix index (prefix.check_invariants) and engine/slot
        alignment. Raises paging.PageAccountingError on the first
        violation. Run on every fault and, under
        ``ServeConfig(debug=True)``, at the end of every tick."""
        if self.paged:
            self.kv.check_invariants()
        if self.prefix is not None:
            self.prefix.check_invariants()
        for slot in range(self.max_batch):
            task = self._tasks[slot]
            if bool(self.active[slot]) != (task is not None):
                raise paging.PageAccountingError(
                    f"slot {slot}: active={bool(self.active[slot])} but "
                    f"task={'set' if task is not None else 'none'}")
            if task is not None:
                uid = task.handle.uid
                if self.scheduler.slots[slot] != uid:
                    raise paging.PageAccountingError(
                        f"slot {slot}: scheduler owner "
                        f"{self.scheduler.slots[slot]} != task uid {uid}")
                if self.paged and self.kv.has_linear \
                        and self.kv._mapped[slot] * self.kv.page_size \
                        < self.pos[slot]:
                    raise paging.PageAccountingError(
                        f"slot {slot}: pos {int(self.pos[slot])} beyond "
                        f"mapped rows "
                        f"{self.kv._mapped[slot] * self.kv.page_size}")

    def _decode_tick(self, finished: List[Request]) -> None:
        """One fused single-token decode across the pool: reserve the
        next cache row per active slot (possibly preempting), run the
        jitted decode, commit positions and emit. Shared by the plain
        step and the speculative controller's k<1 fallback."""
        if self.paged:
            self._ensure_decode_pages()
        if not self.active.any():          # everything self-preempted
            return
        if self.faults is not None:
            # raises _InjectedDeviceError *before* the donated device
            # call, so the pool buffer is still valid for recovery
            self.faults.before_decode(self)
        tables = self.kv.device_tables() if self.paged else {}
        self.key, k = jax.random.split(self.key)
        tok, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.pos), jnp.asarray(self.active), k, tables)
        tok = np.array(tok)        # writable copy: slots mutate it
        self.tokens = tok
        self.stats["decode_steps"] += 1
        self.stats["wasted_slot_steps"] += int(
            self.max_batch - self.active.sum())
        for slot in range(self.max_batch):
            if not self.active[slot]:
                continue
            self.pos[slot] += 1
            fin = self._emit(slot, tok[slot][0])
            if fin is not None:
                finished.append(fin)

    def reset_stats(self) -> None:
        for k in ("steps", "decode_steps", "wasted_slot_steps",
                  "tokens_emitted", "admissions", "prefill_traces",
                  "decode_traces", "preemptions", "page_waits",
                  "peak_active", "preempt_recompute_tokens",
                  "spec_cycles", "spec_draft_tokens",
                  "spec_accepted_tokens", "spec_rollback_tokens",
                  "spec_rollback_pages",
                  # prefix cache (docs/serving.md §Prefix caching):
                  # hit/lookup tokens give the hit rate; shared_pages is
                  # the peak pages mapped by >1 slot; cow_copies counts
                  # copy-on-write page duplications; evicted_pages
                  # counts LRU index evictions under pool pressure.
                  "prefix_hit_tokens", "prefix_lookup_tokens",
                  "shared_pages", "cow_copies", "evicted_pages",
                  # failure handling (docs/serving.md §Failure handling):
                  # terminal-status counters + recovered device errors
                  "cancelled", "expired", "failed", "device_faults"):
            self.stats[k] = 0
        # host wall-clock spent in the decode/spec device step + commit
        # (benchmarks divide tokens_emitted by this for decode tok/s)
        self.stats["decode_time_s"] = 0.0

    def kv_cache_bytes(self) -> int:
        """Bytes held by the persistent attention-cache leaves — the
        paged pool's footprint vs the rectangle's (paging.kv_cache_bytes)."""
        return paging.kv_cache_bytes(self.cache)

    def _forget(self, uid: int) -> None:
        for d in (self.handles, self.done, self.slot_of,
                  self.admission_step, self.completion_step):
            d.pop(uid, None)

    def clear_finished(self) -> None:
        """Drop bookkeeping (handles, outputs, step logs) for completed
        requests — reclaims memory on a long-running server. Callers
        keep their RequestHandles; only the engine's references go."""
        for uid in list(self.done):
            self._forget(uid)

    # ---- internals --------------------------------------------------------

    @staticmethod
    def _item_prompt(item) -> np.ndarray:
        """Tokens an admission unit will prefill (resumes prefill
        prompt + already-emitted tokens — so a resume's own previously
        registered chunks match, which is exactly the preemption
        recompute the prefix index refunds)."""
        if isinstance(item, _Resume):
            return item.prompt
        return np.asarray(item.request.prompt, np.int32)

    @staticmethod
    def _item_prompt_len(item) -> int:
        """Prompt rows an admission unit will prefill (resumes prefill
        prompt + already-emitted tokens)."""
        return InferenceEngine._item_prompt(item).shape[0]

    def _admit(self, slot: int, item) -> Optional[Request]:
        """Failure-isolated admission: a poison request (non-finite
        prefill logits, a malformed prompt that slipped past submit,
        any exception its own prefill raises) fails *that* handle with
        a structured RequestError — its partial slot state is torn down
        page-exactly and the other slots keep decoding. Page-accounting
        violations stay engine-fatal: broken pool bookkeeping cannot be
        attributed to one request."""
        try:
            return self._admit_impl(slot, item)
        except paging.PageAccountingError:
            raise
        except _AbortAdmission as e:       # cancel/expire mid-prefill
            self._teardown_admission(slot, item, e.status, e.reason)
        except Exception as e:
            self._teardown_admission(slot, item, "failed",
                                     f"{type(e).__name__}: {e}")
            self.check_invariants()        # every fault audits the pool
        return None

    def _teardown_admission(self, slot: int, item, status: str,
                            reason: str) -> None:
        """Unwind a partially-admitted slot (kv.admit / table writes may
        or may not have happened — release is tolerant of both) and
        finalize the handle."""
        handle = self._item_handle(item)
        self.active[slot] = False
        self._tasks[slot] = None
        self.slot_of.pop(handle.uid, None)
        if self.paged:
            self.kv.release(slot)
        self.scheduler.release(slot)
        toks = item.emitted if isinstance(item, _Resume) else []
        self._finalize_aborted(handle, status, reason, toks)

    def _admit_impl(self, slot: int, item) -> Optional[Request]:
        """Prefill `item`'s prompt into `slot` and emit its next token.
        `item` is a fresh RequestHandle or a preempted _Resume. Returns
        the request if it finished immediately."""
        if isinstance(item, _Resume):
            handle, prompt = item.handle, item.prompt
            budget_cap, prior = item.budget, item.emitted
        else:
            handle, prior = item, []
            prompt = np.asarray(handle.request.prompt, np.int32)
            budget_cap = handle.request.max_new_tokens
        req = handle.request
        n = prompt.shape[0]
        if isinstance(item, _Resume):
            # every row of the resume prefill is recomputed work (the
            # original prefill + decode already produced them once) —
            # same unit as spec_rollback_tokens, so preemption cost and
            # speculative rollback cost are directly comparable.
            self.stats["preempt_recompute_tokens"] += int(n)
        hit = (0, [])
        if self.prefix is not None:
            # match fresh (not the gate's estimate): an earlier _admit
            # in this same batch may have registered chunks this prompt
            # can now share. Gate-matched entries are protected, so the
            # fresh match only ever covers MORE than the gate promised
            # pages for — and kv.admit refs the pages immediately, with
            # no reclaim possible in between (same host thread).
            p, pages, _ = self.prefix.match(prompt)
            hit = (p, pages)
            self.stats["prefix_lookup_tokens"] += int(n)
            self.stats["prefix_hit_tokens"] += int(p)
        if hit[0] > 0:
            logits = self._admit_shared(slot, prompt, n, *hit)
        else:
            if self.cfg.is_ssm_layer_stack:
                # right-padding would leak pad tokens into the recurrent
                # SSM/conv state, so SSM-stack families prefill at the
                # exact prompt length (one compile per distinct length).
                bucket = n
            else:
                bucket = bucket_length(n, self.max_len)
            padded = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
            padded[0, :n] = prompt
            logits, single = self._prefill(self.params, jnp.asarray(padded),
                                           jnp.asarray(n - 1, jnp.int32))
            if self.paged:
                ids = self.kv.admit(slot, n)       # gated by admit_batch
                self.cache = self._insert(
                    self.cache, single, jnp.asarray(slot, jnp.int32),
                    {k: jnp.asarray(v) for k, v in ids.items()})
            else:
                self.cache = self._insert(self.cache, single,
                                          jnp.asarray(slot, jnp.int32))
        if self.faults is not None \
                and self.faults.poison_prefill(self, req.uid):
            logits = jnp.full_like(logits, jnp.nan)
        if not bool(jnp.isfinite(logits.astype(jnp.float32)).all()):
            # checked BEFORE prefix.register: NaN logits mean the
            # prefilled KV is suspect too, and a registered chunk would
            # poison every future sharer of those pages
            raise ValueError("non-finite prefill logits (poison request)")
        if self.faults is not None:
            self.faults.on_prefill(self, handle)
        v = self._verdict(handle)
        if v is not None:                  # cancel/expire mid-prefill
            raise _AbortAdmission(*v)
        if self.prefix is not None:
            # adopt this slot's full-chunk pages; chunks already indexed
            # (including everything just mapped shared) are skipped
            self.prefix.register(prompt, n, self.kv.tables["linear"][slot])
            self.stats["shared_pages"] = max(self.stats["shared_pages"],
                                             self.kv.shared_page_count)
        self.key, k = jax.random.split(self.key)
        tok = sample_token(logits, k, self.scfg)       # (1,1) or (1,K)
        if self.cfg.family == "audio":
            tok = tok[:, None, :]                      # (1,1,K)
        tok = np.asarray(tok)
        task = _SlotTask(handle, budget=min(budget_cap, self.max_len - n),
                         toks=list(prior))
        handle.status = "running"          # sticky across preemption
        self._tasks[slot] = task
        self.pos[slot] = n
        self.slot_of[req.uid] = slot
        self.admission_step[req.uid] = self.stats["steps"]
        self.stats["admissions"] += 1
        fin = self._emit(slot, tok[0][0])
        if fin is None:
            self.active[slot] = True
            self.tokens[slot] = tok[0]
        return fin

    def _admit_shared(self, slot: int, prompt: np.ndarray, n: int,
                      p: int, pages: List[int]) -> jnp.ndarray:
        """Prefix-hit admission: map the `p` matched tokens' pages
        (`pages`) read-only into `slot` and prefill only the uncached
        suffix directly into the pool (the start-offset prefill path).
        A full-cover match (p == n) still re-emits from the last prompt
        token, so its row is copy-on-written first and exactly one
        token is re-prefilled. Returns the next-token logits."""
        self.kv.admit(slot, n, shared=pages)
        start = n - 1 if p == n else p
        ok = self._cow_rows(slot, start, n)
        assert ok, "admission COW starved: gate promised the page"
        suffix = prompt[start:]
        ps = self.kv.page_size
        # clamp the compile bucket to the slot's row capacity: bucketed
        # pad rows past it would wrap (paged_cache_write writes modulo
        # table_width * page_size) and trash the shared prefix pages
        bucket = min(bucket_length(suffix.shape[0], self.max_len),
                     self.kv.lin_pages * ps - start)
        padded = np.zeros((1, bucket) + prompt.shape[1:], np.int32)
        padded[0, :suffix.shape[0]] = suffix
        table = jnp.asarray(self.kv.tables["linear"][slot:slot + 1])
        logits, self.cache = self._suffix_prefill(
            self.params, jnp.asarray(padded),
            jnp.asarray([start], jnp.int32),
            jnp.asarray(suffix.shape[0] - 1, jnp.int32),
            self.cache, table)
        return logits

    def _ensure_decode_pages(self) -> None:
        """Lazy page reservation before a decode step: every active slot
        must have the page its next cache write lands in (privately —
        a shared page is copy-on-written first). If the pool runs dry,
        the cheapest-to-recompute active slot is preempted — requeued
        at the queue front as a _Resume (re-prefill prompt + emitted,
        token-exact under greedy) — until the write fits. The victim
        may be the needy slot itself (it then self-preempts rather than
        evicting a costlier neighbour); each preemption shrinks the
        active set, one slot's worst case fits the pool by construction
        (PagedKVState rejects smaller pools), and a preempted slot's
        registered prefix pages stay evictable-on-demand — so a lone
        survivor always progresses."""
        for slot in np.nonzero(self.active)[0]:
            while self.active[slot] and not self._reserve_decode_rows(
                    int(slot), int(self.pos[slot]) + 1):
                self._preempt(self._select_victim())

    def _reserve_decode_rows(self, slot: int, n_rows: int) -> bool:
        """Make rows [pos, n_rows) of `slot` privately writable: map
        their pages, then copy-on-write any the slot shares (with the
        prefix index or another slot). False => pool dry even after
        LRU eviction; the caller preempts and retries (both steps are
        idempotent). Shared by the plain decode tick (n_rows = pos+1)
        and the speculative cycle (pos+k+1)."""
        if not self.kv.reserve_rows(slot, n_rows):
            return False
        return self._cow_rows(slot, int(self.pos[slot]), n_rows)

    def _cow_rows(self, slot: int, row0: int, row1: int) -> bool:
        """Copy-on-write every shared page covering upcoming writes to
        rows [row0, row1) of `slot`. False => pool dry."""
        while True:
            idx = self.kv.next_shared_write_page(slot, row0, row1)
            if idx is None:
                return True
            pair = self.kv.cow(slot, idx)
            if pair is None:
                return False
            self.cache = self._copy_page(self.cache,
                                         jnp.asarray(pair[0], jnp.int32),
                                         jnp.asarray(pair[1], jnp.int32))
            self.stats["cow_copies"] += 1

    def _select_victim(self) -> int:
        """Preemption victim = the active slot with the lowest
        recompute cost: the tokens its resume would re-prefill that the
        prefix index does NOT already cover (scheduler.
        pick_preemption_victim; ties break youngest-first). Without a
        prefix index nothing is covered, so cost is simply the resume
        length."""
        cands = []
        for s in np.nonzero(self.active)[0]:
            s = int(s)
            task = self._tasks[s]
            resume = np.concatenate(
                [np.asarray(task.handle.request.prompt, np.int32),
                 np.asarray(task.toks, np.int32).reshape(
                     (len(task.toks),)
                     + np.asarray(task.handle.request.prompt).shape[1:])],
                axis=0)
            cost = resume.shape[0]
            if self.prefix is not None:
                cost -= self.prefix.match_len(resume)
            cands.append((s, cost,
                          self.admission_step.get(task.handle.uid, -1)))
        return pick_preemption_victim(cands)

    def _preempt(self, slot: int) -> None:
        """Evict `slot` mid-decode: free its pages and requeue the rest
        of its generation as a _Resume. Its handle keeps streaming —
        emitted tokens are never replayed."""
        task = self._tasks[slot]
        emitted = np.asarray(task.toks, np.int32)
        prompt = np.concatenate(
            [np.asarray(task.handle.request.prompt, np.int32), emitted],
            axis=0)
        self.active[slot] = False
        self._tasks[slot] = None
        self.slot_of.pop(task.handle.uid, None)   # queued, not placed
        self.kv.release(slot)
        self.scheduler.release(slot)
        self.scheduler.requeue(_Resume(task.handle, prompt, task.budget,
                                       list(task.toks)))
        self.stats["preemptions"] += 1

    def _emit(self, slot: int, token) -> Optional[Request]:
        """Record one emitted token for `slot`; finish the slot on EOS
        or budget exhaustion. `token`: scalar (text) or (K,) (audio)."""
        task = self._tasks[slot]
        req = task.handle.request
        task.toks.append(np.asarray(token))
        task.budget -= 1
        self.stats["tokens_emitted"] += 1
        task.handle._append(token)
        if task.handle.on_token is not None:   # deferred to end of step()
            self._callbacks.append((task.handle.on_token,
                                    task.handle.uid, token))
        flat = int(token if np.ndim(token) == 0 else token[0])
        if (req.eos_id is not None and flat == req.eos_id) \
                or task.budget <= 0:
            return self._finish(slot)
        return None

    def _finish(self, slot: int) -> Request:
        task = self._tasks[slot]
        req = task.handle.request
        req.output = np.asarray(task.toks, np.int32)
        self.done[req.uid] = req
        self.completion_step[req.uid] = self.stats["steps"]
        task.handle._finalize("done")
        self.active[slot] = False
        self._tasks[slot] = None
        if self.paged:
            # free-on-completion: the slot's pages return to the pool
            # and its block-table rows zero out, so a reused uid (or the
            # next occupant) can neither leak pages nor read a stale
            # mapping (clear_finished() only reclaims host bookkeeping).
            self.kv.release(slot)
        self.scheduler.release(slot)
        return req
