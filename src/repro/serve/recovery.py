"""Engine snapshot / restore: drain → snapshot → rebuild → resume
(docs/serving.md §Failure handling).

A snapshot is the *host-side* resume state only — per request: the
original prompt, the tokens emitted so far, the remaining budget and
deadline. No KV pages are serialized: restore re-prefills
prompt+emitted through the engine's ``_Resume`` path (the same form a
preemption requeues), which is token-exact under greedy decoding. That
keeps snapshots tiny (a few ints per token), makes them valid across
engine configurations (a restored engine may use a different pool
size, page size, batch, mesh — or a freshly restarted process), and
reuses accounting that is already invariant-checked instead of
inventing a second KV serialization format.

    done = engine.drain(timeout=30.0)      # stop admission, checkpoint
    recovery.save_snapshot(engine, path)
    ...                                    # process may die here
    fresh = model.engine(scfg, ...)        # new process / new engine
    handles = recovery.restore(fresh, recovery.load_snapshot(path))
    fresh.run()                            # resumes token-identically

``launch/serve.py --snapshot PATH`` wires this under
``launch/supervisor.py`` for crash-restart serving.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.serve.engine import InferenceEngine, RequestHandle, _Resume
from repro.serve.scheduler import Request

SNAPSHOT_VERSION = 1


def snapshot(engine: InferenceEngine) -> Dict[str, Any]:
    """Capture every unfinished request as a JSON-serializable resume
    record: active slots first (in slot order), then the scheduler
    queue in FIFO order — so a restored engine re-admits in the order
    the source engine was serving. Call after ``drain()`` for a
    quiesced snapshot; snapshotting a live engine is also safe (the
    records are pure host state), it just captures mid-flight
    positions."""
    items: List[Dict[str, Any]] = []

    def add(handle: RequestHandle, emitted: List[Any], budget: int):
        req = handle.request
        deadline_left = None
        if handle.deadline_at is not None:
            deadline_left = max(0.0, handle.deadline_at - engine.clock())
        items.append({
            "uid": int(req.uid),
            "prompt": np.asarray(req.prompt).tolist(),
            "max_new_tokens": int(req.max_new_tokens),
            "eos_id": None if req.eos_id is None else int(req.eos_id),
            "emitted": [np.asarray(t).tolist() for t in emitted],
            "budget": int(budget),
            "deadline_left_s": deadline_left,
        })

    for slot in np.nonzero(engine.active)[0]:
        task = engine._tasks[int(slot)]
        add(task.handle, task.toks, task.budget)
    for item in engine.scheduler.pending:
        if isinstance(item, _Resume):
            add(item.handle, item.emitted, item.budget)
        else:
            add(item, [], item.request.max_new_tokens)
    return {"version": SNAPSHOT_VERSION, "max_len": int(engine.max_len),
            "greedy": bool(engine.scfg.greedy), "items": items}


def save_snapshot(engine: InferenceEngine, path: str) -> str:
    """Snapshot to `path` atomically (tmp + ``os.replace`` — a crash
    mid-write leaves the previous snapshot intact)."""
    snap = snapshot(engine)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".snap.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(snap, f)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_snapshot(path: str) -> Dict[str, Any]:
    with open(path) as f:
        snap = json.load(f)
    version = snap.get("version")
    if version != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot {path!r}: version {version} not "
                         f"supported (expected {SNAPSHOT_VERSION})")
    return snap


def restore(engine: InferenceEngine, snap: Dict[str, Any],
            on_token: Optional[Callable] = None
            ) -> Dict[int, RequestHandle]:
    """Resubmit every snapshot record into `engine`; returns
    {uid: handle}. Records with emitted tokens enter through the
    ``_Resume`` path — prompt+emitted re-prefill, remaining budget —
    so under greedy decoding the total output (already-emitted tokens
    pre-buffered on the handle + the tokens decoded here) is identical
    to the run the snapshot interrupted. Remaining deadline budget
    carries over (a record whose deadline already lapsed expires at
    the first tick)."""
    if snap["max_len"] > engine.max_len:
        raise ValueError(
            f"snapshot needs max_len >= {snap['max_len']}, engine has "
            f"{engine.max_len} — resumed prompts could not fit")
    handles: Dict[int, RequestHandle] = {}
    for it in snap["items"]:
        prompt = np.asarray(it["prompt"], np.int32)
        req = Request(it["uid"], prompt,
                      max_new_tokens=it["max_new_tokens"],
                      eos_id=it["eos_id"],
                      deadline_s=it["deadline_left_s"])
        handle = engine.submit(req, on_token=on_token)
        emitted = [np.asarray(t, np.int32) for t in it["emitted"]]
        if emitted:
            # swap the fresh queue entry for a _Resume carrying the
            # already-emitted tokens (exactly what preemption requeues)
            popped = engine.scheduler.pending.pop()
            assert popped is handle, "submit() no longer queues at tail"
            stack = np.asarray(emitted, np.int32).reshape(
                (len(emitted),) + prompt.shape[1:])
            engine.scheduler.submit(_Resume(
                handle, np.concatenate([prompt, stack], axis=0),
                it["budget"], emitted))
            for t in emitted:              # replay into the stream view
                handle._append(t)
        handles[it["uid"]] = handle
    return handles
