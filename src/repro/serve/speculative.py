"""Self-speculative decoding: rank-truncated draft + batched paged
verification (docs/serving.md §Speculative decoding).

NanoQuant's low-rank binary factorization carries a free draft model:
truncating the rank-r factors to r' < r is a strictly cheaper
approximate forward pass at ZERO extra storage — the draft is the same
packed buffers read through a static effective rank
(`quant.surgery.rank_truncated_view`; the kernels read sub-extents, see
`kernels.ops`). The full-rank model is the exact verifier, so greedy
outputs are **token-identical** to the non-speculative engine by
construction.

One engine tick becomes one fused device call (`lax.scan` draft loop +
one multi-token verify forward under a single jit):

1. **Draft** — k single-token decode steps through the truncated view,
   greedy-sampling d_1..d_k. Draft KV lands in the slot's own pages at
   rows ``pos..pos+k-1`` (draft tokens are just extra rows — the paged
   pool and block tables are untouched machinery).
2. **Verify** — ONE full-rank forward over ``[t_0, d_1..d_k]`` (S=k+1
   queries at positions ``pos..pos+k``), REwriting those rows with
   exact full-rank KV and emitting the exact next token e_i after every
   prefix. Multi-token paged causality needs no new masking: a row
   written by a later query of the same call reconstructs to a negative
   absolute position for every earlier query
   (`kernels.ref.paged_attention_ref`).
3. **Commit / rollback** — the acceptance length a = number of leading
   i with d_{i+1} == e_i; tokens e_0..e_a are committed (a+1 per cycle,
   ≥1 always — e_0 is exactly what the plain engine would emit).
   Rows past the new frontier are dead (negative reconstruction ⇒
   never read), so rollback is purely host-side: ``PagedKVState.trim``
   returns pages covering only rejected rows to the pool — the same
   token-exact accounting the preemption resume path relies on.

Committed token i of a cycle only ever attends to KV of rows holding
the committed prefix (acceptance guarantees rows ``pos+1..pos+i`` hold
d_j == e_{j-1}), and every row was rewritten full-rank by the verify —
hence exact identity, whatever the draft proposes.

A dynamic-k controller shrinks the draft length when acceptance drops
(EMA-gated, one jit cache entry per distinct k in
``[spec_k_min, spec_k]``) so a badly-truncated draft degrades toward
plain decode instead of burning k wasted rows per cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.quant.surgery import rank_truncated_view
from repro.serve import paging

# dynamic-k controller: shrink when the EMA of per-cycle acceptance
# fraction (a/k averaged over active slots) falls below SHRINK, grow
# when it exceeds GROW. Hysteresis band keeps k stable in steady state.
_EMA_BETA = 0.2
_SHRINK_BELOW = 0.4
_GROW_ABOVE = 0.8


class SpecDecodeController:
    """Per-engine speculative decode driver (one per InferenceEngine,
    built by the engine when ``ServeConfig.spec_rank_frac`` is set).

    Holds the zero-copy draft view, the per-k jitted draft+verify
    cycle cache, per-slot acceptance tracking (``acceptance`` maps uid
    -> [accepted, drafted]) and the dynamic-k state. ``tick`` replaces
    the engine's single-token decode tick."""

    def __init__(self, engine):
        scfg = engine.scfg
        frac = scfg.spec_rank_frac
        if not (0.0 < frac <= 1.0):
            raise ValueError(
                f"spec_rank_frac must be in (0, 1], got {frac}")
        if scfg.spec_k < 1 or scfg.spec_k_min < 1 \
                or scfg.spec_k_min > scfg.spec_k:
            raise ValueError(
                f"need 1 <= spec_k_min <= spec_k, got "
                f"spec_k_min={scfg.spec_k_min} spec_k={scfg.spec_k}")
        if not scfg.greedy:
            raise ValueError(
                "speculative decoding requires greedy=True: the verify "
                "forward replays the draft deterministically, and "
                "token identity with the plain engine is only defined "
                "for greedy sampling")
        if not engine.paged:
            raise ValueError(
                "speculative decoding requires the paged KV cache "
                "(draft tokens live in the slot's pages; rollback is "
                "page trimming) — this family/config has none")
        if set(engine.kv.tables) != {"linear"}:
            raise ValueError(
                "speculative decoding supports linear page tables only "
                "(sliding-window ring pools wrap draft rows over "
                f"committed KV); got kinds {sorted(engine.kv.tables)}")
        if engine.cfg.is_ssm_layer_stack:
            raise ValueError(
                "speculative decoding is undefined for recurrent-state "
                "families: rejected drafts cannot be rolled out of an "
                "SSM/conv state by page trimming")
        if engine.cfg.family == "audio":
            raise ValueError("speculative decoding does not support "
                             "multi-codebook audio decode")
        self.engine = engine
        self.rank_frac = float(frac)
        self.k_min = int(scfg.spec_k_min)
        self.k_max = int(scfg.spec_k)
        self.k = self.k_max
        # zero-copy: every array leaf of the view IS the corresponding
        # engine.params leaf (rank_truncated_view only adds static
        # EffRank markers), so the draft adds no weight memory and no
        # placement work — sharded params stay sharded.
        self.draft_params = rank_truncated_view(engine.params, frac)
        self._cycles: Dict[int, callable] = {}
        self.acceptance: Dict[int, List[int]] = {}
        self._ema = None

    # ---- reporting --------------------------------------------------------

    def acceptance_rate(self, uid=None) -> float:
        """Accepted / drafted over the engine lifetime (or one uid)."""
        if uid is not None:
            a, d = self.acceptance.get(uid, (0, 0))
        else:
            a = sum(v[0] for v in self.acceptance.values())
            d = sum(v[1] for v in self.acceptance.values())
        return a / d if d else 0.0

    # ---- fused draft + verify cycle ---------------------------------------

    def _cycle(self, k: int):
        if k not in self._cycles:
            self._cycles[k] = self._build_cycle(k)
        return self._cycles[k]

    def _build_cycle(self, k: int):
        eng = self.engine
        cfg = eng.cfg

        def cycle(params, draft, tokens, cache, pos, active, tables):
            eng.stats["decode_traces"] += 1
            with eng._trace_scope():
                def body(carry, _):
                    tok, c, p = carry
                    lg, c = T.decode_step(draft, cfg, tok, c, p,
                                          block_tables=tables)
                    nxt = jnp.argmax(lg[:, -1].astype(jnp.float32),
                                     axis=-1).astype(jnp.int32)
                    return (nxt[:, None], c, p + 1), nxt

                (_, c, _), drafts = jax.lax.scan(
                    body, (tokens, cache, pos), None, length=k)
                drafts = jnp.moveaxis(drafts, 0, 1)          # (B, k)
                xs = jnp.concatenate([tokens, drafts], axis=1)
                lg, c = T.decode_step(params, cfg, xs, c, pos,
                                      block_tables=tables)
                # exact[:, i] = full-rank greedy token after prefix
                # ..t0,d_1..d_i — e_0 is the plain engine's next token
                exact = jnp.argmax(lg.astype(jnp.float32),
                                   axis=-1).astype(jnp.int32)  # (B, k+1)
                match = (drafts == exact[:, :k]).astype(jnp.int32)
                acc = jnp.cumprod(match, axis=1).sum(axis=1)   # (B,)
                c = paging.paged_select_active(c, cache, active)
            return exact, acc, c

        return jax.jit(cycle, donate_argnums=(3,))

    # ---- the tick ---------------------------------------------------------

    def tick(self, finished) -> None:
        """Speculative replacement for the engine's decode tick: one
        fused draft+verify call, then host-side commit + rollback."""
        eng = self.engine
        # cap k so the verify's last write row pos+k stays < max_len
        # for every active slot (the linear table covers max_len rows —
        # the invariant the causality masking rests on)
        k = self.k
        for s in np.nonzero(eng.active)[0]:
            k = min(k, eng.max_len - 1 - int(eng.pos[s]))
        if k < 1:
            # some slot is on its last row: no draft headroom this tick
            eng._decode_tick(finished)
            return
        # reserve pages for rows [0, pos+k+1) per slot — the cycle
        # writes k+1 rows before the next host sync — COWing any the
        # slot shares (prefix cache) so draft writes never touch a
        # sharer's KV. Dry pool preempts the cheapest-to-recompute slot
        # (identical policy to _ensure_decode_pages).
        for s in np.nonzero(eng.active)[0]:
            while eng.active[s] and not eng._reserve_decode_rows(
                    int(s), int(eng.pos[s]) + k + 1):
                eng._preempt(eng._select_victim())
        if not eng.active.any():
            return
        slots = np.nonzero(eng.active)[0]
        if eng.faults is not None:
            # raises before the donated cycle call (recoverable: the
            # engine preempt-resumes the survivors, fails the target)
            eng.faults.before_decode(eng)
        tables = eng.kv.device_tables()
        exact, acc, eng.cache = self._cycle(k)(
            eng.params, self.draft_params, jnp.asarray(eng.tokens),
            eng.cache, jnp.asarray(eng.pos), jnp.asarray(eng.active),
            tables)
        exact, acc = np.array(exact), np.array(acc)
        if eng.faults is not None:
            # cancel-mid-spec-rollback: lands between the batched
            # verify and the commit+trim below; the commit still runs
            # (cancellation is honoured at the next tick boundary), so
            # rollback accounting must stay exact for a doomed slot
            eng.faults.on_spec_cycle(eng)
        eng.stats["decode_steps"] += 1
        eng.stats["spec_cycles"] += 1
        eng.stats["wasted_slot_steps"] += int(eng.max_batch - len(slots))
        accept_fracs = []
        for s in slots:
            s = int(s)
            a = int(acc[s])
            accept_fracs.append(a / k)
            eng.stats["spec_draft_tokens"] += k
            eng.stats["spec_accepted_tokens"] += a
            eng.stats["spec_rollback_tokens"] += k - a
            rec = self.acceptance.setdefault(
                eng._tasks[s].handle.uid, [0, 0])
            rec[0] += a
            rec[1] += k
            committed = 0
            for i in range(a + 1):
                eng.pos[s] += 1
                committed += 1
                fin = eng._emit(s, exact[s][i])
                if fin is not None:       # EOS / budget: slot released
                    finished.append(fin)
                    break
            if eng.active[s]:
                # next tick feeds the last committed token at pos
                eng.tokens[s] = exact[s, committed - 1]
                # rollback: pages covering only rejected rows (past the
                # committed frontier pos) go back to the pool
                eng.stats["spec_rollback_pages"] += eng.kv.trim(
                    s, int(eng.pos[s]))
        # dynamic k: EMA of the batch acceptance fraction
        if accept_fracs:
            f = sum(accept_fracs) / len(accept_fracs)
            self._ema = f if self._ema is None else \
                (1 - _EMA_BETA) * self._ema + _EMA_BETA * f
            if self._ema < _SHRINK_BELOW and self.k > self.k_min:
                self.k -= 1
            elif self._ema > _GROW_ABOVE and self.k < self.k_max:
                self.k += 1
