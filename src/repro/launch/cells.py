"""Cell builders: (arch x shape x mesh) -> AOT-lowerable jit functions.

One "cell" is an assigned architecture at one input-shape point on one
mesh. ``lower_cell`` produces the jax.stages.Lowered object the dry-run
compiles and the roofline analysis reads. Serving cells (prefill /
decode) lower against the *quantized* parameter structs by default — the
paper's deployment scenario; pass ``quantized=False`` for the FP
comparison rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import shapes as SH
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.quant.surgery import abstract_quantized_params
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.sharding import rules
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optim import AdamW


# memory-policy overrides per arch at train_4k (microbatching keeps the
# per-device activation footprint inside v5e HBM; see EXPERIMENTS.md §Perf)
GRAD_ACCUM: Dict[str, int] = {
    "qwen1.5-110b": 16,
    "qwen3-moe-235b-a22b": 8,
    "llama-3.2-vision-90b": 8,
    "qwen3-4b": 4,
    "deepseek-v2-lite-16b": 4,
    "musicgen-medium": 2,
    "mamba2-370m": 4,
    "zamba2-1.2b": 4,
    "llama3.2-1b": 2,
    "qwen1.5-0.5b": 2,
}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: Any
    mode: str                     # train | prefill | decode
    cfg: Any
    fn: Any                       # the python step callable
    args: tuple                   # ShapeDtypeStructs (positional)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    quantized: bool = False
    grad_accum: int = 1

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jitted().lower(*self.args)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape: str, mesh,
               quantized: Optional[bool] = None,
               policy: rules.ShardingPolicy = rules.DEFAULT,
               grad_accum: Optional[int] = None,
               target_bpw: float = 1.0,
               cfg_overrides: Optional[dict] = None) -> Cell:
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = SH.SHAPES[shape]
    mode = cell.mode
    # SPMD-partitionable path for AOT
    kops.set_kernel_policy(kops.KernelPolicy(mode="ref"))
    # pin activation shardings (GSPMD propagation alone replicates
    # attention when kv-heads < the model axis — §Perf iteration 1)
    from repro.models import layers as L
    L.set_activation_sharding(mesh, rules.data_axes(mesh),
                              "model" if "model" in mesh.axis_names
                              else None)

    if mode == "train":
        accum = grad_accum if grad_accum is not None \
            else GRAD_ACCUM.get(arch, 1)
        tcfg = TrainConfig(grad_accum=accum)
        step = make_train_step(cfg, tcfg)
        params = SH.param_specs(cfg)
        pspecs = rules.param_pspecs(cfg, params, mesh, policy)
        opt = AdamW(lr=1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        ospecs = type(opt_state)(step=P(), m=pspecs, v=pspecs)
        eff = jax.ShapeDtypeStruct((), jax.numpy.float32)
        batch = SH.input_specs(cfg, shape, accum)["batch"]
        bspecs = rules.batch_pspecs(cfg, batch, mesh, accum)
        in_sh = ( _ns(mesh, pspecs), _ns(mesh, ospecs),
                  NamedSharding(mesh, P()), _ns(mesh, bspecs))
        out_sh = ( _ns(mesh, pspecs), _ns(mesh, ospecs),
                   NamedSharding(mesh, P()),
                   _ns(mesh, {"loss": P(), "grad_norm": P(), "lr": P()}))
        return Cell(arch, shape, mesh, mode, cfg, step,
                    (params, opt_state, eff, batch), in_sh, out_sh,
                    donate=(0, 1), grad_accum=accum)

    # ---- serving cells -----------------------------------------------------
    q = True if quantized is None else quantized
    if q:
        params = abstract_quantized_params(cfg, target_bpw=target_bpw)
    else:
        params = SH.param_specs(cfg)
    pspecs = rules.param_pspecs(cfg, params, mesh, policy)

    if mode == "prefill":
        step = make_prefill_step(cfg)
        specs = SH.input_specs(cfg, shape)
        args = [params, specs["tokens"]]
        in_sh = [_ns(mesh, pspecs),
                 _ns(mesh, rules.batch_pspecs(cfg, specs["tokens"], mesh))]
        if cfg.family == "vlm":
            args.append(specs["image_embeds"])
            in_sh.append(_ns(mesh, rules.batch_pspecs(
                cfg, specs["image_embeds"], mesh)))
        return Cell(arch, shape, mesh, mode, cfg, step, tuple(args),
                    tuple(in_sh), None, quantized=q)

    if mode == "decode":
        step = make_serve_step(cfg)
        specs = SH.input_specs(cfg, shape)
        cspecs = rules.cache_pspecs(cfg, specs["cache"], mesh, policy)
        args = (params, specs["token"], specs["cache"], specs["pos"])
        in_sh = (_ns(mesh, pspecs),
                 _ns(mesh, rules.batch_pspecs(cfg, specs["token"], mesh)),
                 _ns(mesh, cspecs),
                 NamedSharding(mesh, P()))
        out_sh = (None, _ns(mesh, cspecs))
        return Cell(arch, shape, mesh, mode, cfg, step, args, in_sh,
                    out_sh, donate=(2,), quantized=q)

    raise ValueError(mode)
