"""Quantization driver: train-or-load an FP teacher, run the NanoQuant
pipeline through the ``repro.api`` facade, save the packed artifact, and
report sizes + perplexities.

    PYTHONPATH=src python -m repro.launch.quantize --arch llama3.2-1b \
        --bpw 1.0 --teacher-steps 150 --out /tmp/nq

Fault tolerance (docs/quantization.md): ``--journal-dir`` makes the run
crash-safe (per-block journaling through ``checkpoint.journal``);
``--resume`` picks up a killed run from its journal and produces a
bit-identical artifact. ``--supervise`` re-execs this driver under
``launch/supervisor.py`` with restart-on-crash and hang detection keyed
to the per-block ``[quant] heartbeat`` lines; restarted children get
``--resume`` appended automatically. ``--crash-at-block N`` injects one
deterministic crash (first attempt only) for drilling the loop.

(Smoke-scale by default: this box is CPU-only. On real hardware the same
driver quantizes the full config from a teacher checkpoint.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro import api
from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.train import TrainConfig, Trainer

HEARTBEAT_RE = r"\[quant\] heartbeat"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=api.list_archs())
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs real hardware)")
    ap.add_argument("--bpw", type=float, default=1.0)
    ap.add_argument("--init-method", default="lb_admm",
                    choices=api.list_init_methods())
    ap.add_argument("--fallback-inits", default="dbf_admm,dual_svid",
                    help="comma-separated init-method ladder tried when "
                         "a block diverges ('' disables fallbacks)")
    ap.add_argument("--teacher-steps", type=int, default=150)
    ap.add_argument("--calib-samples", type=int, default=16)
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--out", default="")
    ap.add_argument("--teacher-ckpt", default="")
    ap.add_argument("--rank-align", type=int, default=32)
    # pipeline budget knobs (CI smoke uses tiny values)
    ap.add_argument("--admm-iters", type=int, default=40)
    ap.add_argument("--t-pre", type=int, default=40)
    ap.add_argument("--t-post", type=int, default=60)
    ap.add_argument("--t-glob", type=int, default=60)
    # fault tolerance (docs/quantization.md)
    ap.add_argument("--journal-dir", default="",
                    help="per-block progress journal dir (enables "
                         "--resume and supervised restarts)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from --journal-dir "
                         "(bit-identical artifact)")
    ap.add_argument("--no-preflight", action="store_true",
                    help="skip input validation (calib/params/memory)")
    ap.add_argument("--heartbeat", action="store_true",
                    help="print '[quant] heartbeat ...' per block (what "
                         "--supervise hang detection watches)")
    ap.add_argument("--supervise", action="store_true",
                    help="run under launch/supervisor.py: restart on "
                         "crash, kill+restart on missing heartbeats, "
                         "children resume from --journal-dir")
    ap.add_argument("--hang-timeout", type=float, default=600.0,
                    help="--supervise: seconds without a heartbeat "
                         "before the child is declared hung")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="--supervise: restart budget")
    ap.add_argument("--crash-at-block", type=int, default=-1,
                    help="inject one crash when this block starts, "
                         "first attempt only (restart drill)")
    args = ap.parse_args()

    if args.supervise:
        from repro.launch import supervisor
        if not args.journal_dir:
            ap.error("--supervise needs --journal-dir so restarted "
                     "children can resume instead of redoing everything")
        child = [a for a in sys.argv[1:] if a != "--supervise"]
        for extra in ("--resume", "--heartbeat"):
            if extra not in child:
                child.append(extra)
        raise SystemExit(supervisor.supervise(
            [sys.executable, "-m", "repro.launch.quantize"] + child,
            max_restarts=args.max_restarts,
            hang_timeout=args.hang_timeout,
            heartbeat_pattern=HEARTBEAT_RE))

    cfg = api.get_config(args.arch) if args.full else api.get_smoke(args.arch)
    tcfg = TrainConfig(lr=1e-3, warmup=20, total_steps=args.teacher_steps)

    # ---- FP teacher --------------------------------------------------------
    if args.teacher_ckpt:
        mgr = api.CheckpointManager(args.teacher_ckpt)
        tr = Trainer(cfg, tcfg, train_iterator(cfg, 8, args.calib_seq), mgr)
        tr.restore_or_init()
        if tr.step < args.teacher_steps:
            tr.run(args.teacher_steps - tr.step)
        params = tr.state[0]
    else:
        tr = Trainer(cfg, tcfg, train_iterator(cfg, 8, args.calib_seq),
                     log_every=25)
        tr.restore_or_init()
        tr.run(args.teacher_steps)
        params = tr.state[0]

    corpus = SyntheticCorpus(cfg.vocab_size)
    calib = calib_batches(cfg, args.calib_samples, args.calib_seq,
                          corpus=corpus)
    evalb = calib_batches(cfg, 8, args.calib_seq, seed=99, corpus=corpus)

    # ---- preflight (fail fast, not at block 17) ----------------------------
    if not args.no_preflight:
        pf = api.preflight(params, cfg, calib)
        print(f"[quantize] preflight ok: {pf['n_batches']} batches, "
              f"{pf['n_calib_tokens']} calib tokens, "
              f"~{pf['est_block_bytes'] / 2**20:.0f} MiB/block", flush=True)

    ppl_fp = api.NanoQuantModel.from_fp(params, cfg).perplexity(evalb)

    # ---- fault injection drill --------------------------------------------
    faults = None
    if args.crash_at_block >= 0:
        # fire only on the first attempt (journal still empty) so a
        # supervised restart makes progress instead of re-crashing
        already = (api.QuantJournal(args.journal_dir).n_completed_blocks()
                   if args.journal_dir else 0)
        if already == 0:
            faults = api.QuantFaultPlan(
                [api.QuantFault(block=args.crash_at_block,
                                kind="crash_block")])

    heartbeat = None
    if args.heartbeat:
        def heartbeat(msg):
            print(f"[quant] heartbeat {msg}", flush=True)

    # ---- NanoQuant ---------------------------------------------------------
    qcfg = api.QuantConfig(target_bpw=args.bpw, rank_align=args.rank_align,
                           init_method=args.init_method,
                           fallback_inits=args.fallback_inits,
                           admm_iters=args.admm_iters, t_pre=args.t_pre,
                           t_post=args.t_post, t_glob=args.t_glob)
    model = api.NanoQuantModel.quantize(
        params, cfg, calib, qcfg,
        journal_dir=args.journal_dir or None, resume=args.resume,
        faults=faults, heartbeat=heartbeat)
    ppl_q = model.perplexity(evalb)

    sizes = model.size_report()
    print(f"\n[quantize] {cfg.name} target_bpw={args.bpw}")
    print(f"  FP teacher ppl   : {ppl_fp:.3f}")
    print(f"  NanoQuant ppl    : {ppl_q:.3f}")
    print(f"  linears bpw      : {sizes['linears_bpw']:.3f}")
    print(f"  wall time        : {model.report['wall_s']:.1f}s")
    if args.out:
        model.save(args.out)
        with open(os.path.join(args.out, "report.json"), "w") as f:
            json.dump({"ppl_fp": ppl_fp, "ppl_q": ppl_q,
                       "sizes": sizes,
                       "ranks": model.ranks,
                       "wall_s": model.report["wall_s"]}, f, indent=1)
        print(f"  saved to {args.out}")


if __name__ == "__main__":
    main()
