"""Quantization driver: train-or-load an FP teacher, run the NanoQuant
pipeline through the ``repro.api`` facade, save the packed artifact, and
report sizes + perplexities.

    PYTHONPATH=src python -m repro.launch.quantize --arch llama3.2-1b \
        --bpw 1.0 --teacher-steps 150 --out /tmp/nq

(Smoke-scale by default: this box is CPU-only. On real hardware the same
driver quantizes the full config from a teacher checkpoint.)
"""
from __future__ import annotations

import argparse
import json
import os

from repro import api
from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=api.list_archs())
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs real hardware)")
    ap.add_argument("--bpw", type=float, default=1.0)
    ap.add_argument("--init-method", default="lb_admm",
                    choices=api.list_init_methods())
    ap.add_argument("--teacher-steps", type=int, default=150)
    ap.add_argument("--calib-samples", type=int, default=16)
    ap.add_argument("--calib-seq", type=int, default=128)
    ap.add_argument("--out", default="")
    ap.add_argument("--teacher-ckpt", default="")
    ap.add_argument("--rank-align", type=int, default=32)
    # pipeline budget knobs (CI smoke uses tiny values)
    ap.add_argument("--admm-iters", type=int, default=40)
    ap.add_argument("--t-pre", type=int, default=40)
    ap.add_argument("--t-post", type=int, default=60)
    ap.add_argument("--t-glob", type=int, default=60)
    args = ap.parse_args()

    cfg = api.get_config(args.arch) if args.full else api.get_smoke(args.arch)
    tcfg = TrainConfig(lr=1e-3, warmup=20, total_steps=args.teacher_steps)

    # ---- FP teacher --------------------------------------------------------
    if args.teacher_ckpt:
        mgr = api.CheckpointManager(args.teacher_ckpt)
        tr = Trainer(cfg, tcfg, train_iterator(cfg, 8, args.calib_seq), mgr)
        tr.restore_or_init()
        if tr.step < args.teacher_steps:
            tr.run(args.teacher_steps - tr.step)
        params = tr.state[0]
    else:
        tr = Trainer(cfg, tcfg, train_iterator(cfg, 8, args.calib_seq),
                     log_every=25)
        tr.restore_or_init()
        tr.run(args.teacher_steps)
        params = tr.state[0]

    corpus = SyntheticCorpus(cfg.vocab_size)
    calib = calib_batches(cfg, args.calib_samples, args.calib_seq,
                          corpus=corpus)
    evalb = calib_batches(cfg, 8, args.calib_seq, seed=99, corpus=corpus)
    ppl_fp = api.NanoQuantModel.from_fp(params, cfg).perplexity(evalb)

    # ---- NanoQuant ---------------------------------------------------------
    qcfg = api.QuantConfig(target_bpw=args.bpw, rank_align=args.rank_align,
                           init_method=args.init_method,
                           admm_iters=args.admm_iters, t_pre=args.t_pre,
                           t_post=args.t_post, t_glob=args.t_glob)
    model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg)
    ppl_q = model.perplexity(evalb)

    sizes = model.size_report()
    print(f"\n[quantize] {cfg.name} target_bpw={args.bpw}")
    print(f"  FP teacher ppl   : {ppl_fp:.3f}")
    print(f"  NanoQuant ppl    : {ppl_q:.3f}")
    print(f"  linears bpw      : {sizes['linears_bpw']:.3f}")
    print(f"  wall time        : {model.report['wall_s']:.1f}s")
    if args.out:
        model.save(args.out)
        with open(os.path.join(args.out, "report.json"), "w") as f:
            json.dump({"ppl_fp": ppl_fp, "ppl_q": ppl_q,
                       "sizes": sizes,
                       "ranks": model.ranks,
                       "wall_s": model.report["wall_s"]}, f, indent=1)
        print(f"  saved to {args.out}")


if __name__ == "__main__":
    main()
