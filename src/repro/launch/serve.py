"""Serving driver: batched generation from a (quantized) model artifact.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --quantized-ckpt /tmp/nq --requests 16 --max-new 32

A ``--quantized-ckpt`` directory written by ``launch/quantize.py`` (a
``NanoQuantModel`` artifact) is self-describing: the manifest carries the
model config, so ``--arch`` is only needed for the fresh-quantize demo
path. ``--engine continuous`` (default) serves through the
slot-scheduled ``InferenceEngine``; ``--engine wave`` reproduces the
legacy drain-then-refill schedule for comparison. ``--tp N`` serves
tensor-parallel over a ``(data=1, model=N)`` mesh (see docs/serving.md).

Crash-restart serving (docs/serving.md §Failure handling):
``--supervise`` re-execs this driver under ``launch/supervisor.py``
with hang detection keyed to the per-tick ``[serve] heartbeat`` lines
(emitted from the serving loop itself, so a wedged device call stops
them and gets the process killed + restarted), and ``--snapshot PATH``
persists the host-side resume state every ``--snapshot-every`` ticks —
a restarted process resumes the interrupted requests token-identically
under greedy. ``--crash-at-step N`` force-crashes for testing the loop.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro import api
from repro.data import calib_batches
from repro.models import transformer as T
from repro.serve import recovery


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=api.list_archs())
    ap.add_argument("--quantized-ckpt", default="",
                    help="NanoQuantModel artifact from launch/quantize.py; "
                         "if empty, quantizes a fresh random-init teacher")
    ap.add_argument("--fp", action="store_true",
                    help="serve the FP teacher instead (baseline)")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "wave"],
                    help="slot admission policy (wave = legacy "
                         "BatchServer schedule)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--rect", action="store_true",
                    help="serve on the legacy rectangular KV pool "
                         "instead of the paged pool (the identity "
                         "oracle; see docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV rows per page of the paged pool")
    ap.add_argument("--kv-pool-pages", type=int, default=0,
                    help="total pool pages; 0 = full capacity, smaller "
                         "overcommits (admission queues on free pages)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: serve over a "
                         "(data=1, model=N) mesh (needs >= N devices; "
                         "on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--spec-rank-frac", type=float, default=0.0,
                    help="enable self-speculative decoding: draft "
                         "through a rank-truncated view at this rank "
                         "fraction, verify full-rank (forces greedy "
                         "sampling; requires the paged pool, so "
                         "incompatible with --rect)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per speculative cycle")
    ap.add_argument("--no-prefix", action="store_true",
                    help="disable the prefix cache (shared prompt-"
                         "prefix KV pages; on by default for paged "
                         "linear-table families)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt "
                         "tokens to every request (demo of prefix-"
                         "cache page sharing)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "requests past it finish 'expired'")
    ap.add_argument("--snapshot", default="",
                    help="resume-state file: loaded on start if it "
                         "exists (crash recovery), refreshed every "
                         "--snapshot-every ticks, removed on a clean "
                         "finish")
    ap.add_argument("--snapshot-every", type=int, default=32,
                    help="ticks between snapshot refreshes")
    ap.add_argument("--heartbeat-every", type=int, default=16,
                    help="ticks between '[serve] heartbeat' lines "
                         "(what --supervise hang detection watches; "
                         "0 disables)")
    ap.add_argument("--crash-at-step", type=int, default=0,
                    help="testing: snapshot then exit(7) at this tick "
                         "(fresh runs only — a snapshot-resumed "
                         "incarnation runs to completion)")
    ap.add_argument("--supervise", action="store_true",
                    help="run under launch/supervisor.py: restart on "
                         "crash, kill+restart on missing heartbeats, "
                         "resume from --snapshot")
    ap.add_argument("--hang-timeout", type=float, default=60.0,
                    help="--supervise: seconds without a heartbeat "
                         "before the child is declared hung")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="--supervise: restart budget")
    args = ap.parse_args()

    if args.supervise:
        from repro.launch import supervisor
        if not args.snapshot:
            ap.error("--supervise needs --snapshot to resume across "
                     "restarts")
        cmd = [sys.executable, "-m", "repro.launch.serve"] \
            + [a for a in sys.argv[1:] if a != "--supervise"]
        raise SystemExit(supervisor.supervise(
            cmd, max_restarts=args.max_restarts,
            hang_timeout=args.hang_timeout,
            heartbeat_pattern=r"\[serve\] heartbeat"))

    if args.quantized_ckpt and not args.fp:
        model = api.NanoQuantModel.load(args.quantized_ckpt)
        print(f"[serve] loaded artifact {args.quantized_ckpt} "
              f"(arch={model.cfg.name}, "
              f"bpw={model.qcfg.target_bpw if model.quantized else 16})")
    else:
        cfg = api.get_smoke(args.arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        model = api.NanoQuantModel.from_fp(params, cfg)
        if not args.fp:
            calib = calib_batches(cfg, 8, 64)
            qcfg = api.QuantConfig(admm_iters=10, t_pre=5, t_post=5,
                                   t_glob=5, rank_align=32)
            model = api.NanoQuantModel.quantize(params, cfg, calib, qcfg,
                                                verbose=False)
            print("[serve] quantized random-init teacher (demo)")

    cfg = model.cfg
    spec = args.spec_rank_frac or None
    if spec and args.rect:
        ap.error("--spec-rank-frac needs the paged KV pool; drop --rect")
    scfg = api.ServeConfig(max_new_tokens=args.max_new,
                           paged=not args.rect,
                           page_size=args.page_size,
                           kv_pool_pages=args.kv_pool_pages or None,
                           greedy=bool(spec),
                           spec_rank_frac=spec,
                           spec_k=args.spec_k,
                           prefix_cache=not args.no_prefix)
    if spec:
        print(f"[serve] speculative decode: rank_frac={spec} "
              f"k<={args.spec_k} (greedy sampling forced)")
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.tp)
        print(f"[serve] tensor-parallel over {args.tp} devices "
              f"(mesh axes {mesh.axis_names}, shape {dict(mesh.shape)})")
    eng = model.engine(scfg, max_batch=args.max_batch,
                       max_len=(args.shared_prefix + args.prompt_len
                                + args.max_new),
                       admission=args.engine, mesh=mesh)
    rng = np.random.default_rng(0)
    shape = ((args.prompt_len, cfg.n_codebooks)
             if cfg.family == "audio" else (args.prompt_len,))
    sys_prompt = None
    if args.shared_prefix:
        if cfg.family == "audio":
            ap.error("--shared-prefix does not support audio prompts")
        sys_prompt = rng.integers(0, cfg.vocab_size,
                                  size=args.shared_prefix).astype(np.int32)
    t0 = time.time()
    resumed = args.snapshot and os.path.exists(args.snapshot)
    if resumed:
        snap = recovery.load_snapshot(args.snapshot)
        handles = list(recovery.restore(eng, snap).values())
        print(f"[serve] resumed {len(handles)} in-flight requests from "
              f"{args.snapshot}")
    else:
        handles = []
        for uid in range(args.requests):
            prompt = rng.integers(
                0, cfg.vocab_size, size=shape).astype(np.int32)
            if sys_prompt is not None:
                prompt = np.concatenate([sys_prompt, prompt])
            handles.append(eng.submit(api.Request(
                uid, prompt, max_new_tokens=args.max_new,
                deadline_s=args.deadline or None)))
    # manual step loop (not eng.run()): the heartbeat must come from
    # inside the serving loop — a thread would keep beating while a
    # device call is wedged, which is exactly the hang the supervisor
    # exists to catch
    while eng.in_flight:
        eng.step()
        tick = eng.stats["steps"]
        if args.heartbeat_every and tick % args.heartbeat_every == 0:
            print(f"[serve] heartbeat step={tick} "
                  f"active={int(eng.active.sum())} "
                  f"queued={len(eng.scheduler.pending)}", flush=True)
        if args.snapshot and args.snapshot_every \
                and tick % args.snapshot_every == 0 and eng.in_flight:
            recovery.save_snapshot(eng, args.snapshot)
        if args.crash_at_step and not resumed \
                and tick >= args.crash_at_step:
            if args.snapshot:
                recovery.save_snapshot(eng, args.snapshot)
            print(f"[serve] injected crash at step {tick}", flush=True)
            sys.exit(7)
    done = dict(eng.done)
    if args.snapshot and os.path.exists(args.snapshot):
        os.unlink(args.snapshot)           # clean finish: nothing to resume
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done.values())
    n_term = {s: eng.stats[s] for s in ("cancelled", "expired", "failed")
              if eng.stats[s]}
    print(f"[serve] engine={args.engine}: {len(done)} requests, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. "
          f"compile)" + (f", non-done terminals {n_term}" if n_term
                         else ""))
    lats = np.asarray(sorted(h.latency for h in handles if h.done))
    if lats.size:
        print(f"[serve] request latency: mean {lats.mean():.2f}s  "
              f"p50 {np.percentile(lats, 50):.2f}s  "
              f"p95 {np.percentile(lats, 95):.2f}s")
    print(f"[serve] decode steps {eng.stats['decode_steps']}, wasted "
          f"slot-steps {eng.stats['wasted_slot_steps']}, prefill "
          f"compilations {eng.stats['prefill_traces']}")
    _print_pool_stats(eng)
    if eng.spec is not None:
        st = eng.stats
        print(f"[serve] speculative: {st['spec_cycles']} cycles, "
              f"acceptance {eng.spec.acceptance_rate():.2f} "
              f"({st['spec_accepted_tokens']}/{st['spec_draft_tokens']} "
              f"draft tokens), {st['spec_rollback_tokens']} rolled "
              f"back ({st['spec_rollback_pages']} pages trimmed), "
              f"final k={eng.spec.k}")
    if done:
        first = min(done)
        print(f"[serve] sample output for request {first}: "
              f"{done[first].output[:16]}")


def _print_pool_stats(eng) -> None:
    """KV-pool line for either cache layout. Keys off ``eng.kv`` — the
    engine serves a rectangular layout both under ``--rect`` and for
    families with no pageable cache (pure SSM state), and neither has a
    ``PagedKVState`` to report on."""
    if eng.kv is None:
        print(f"[serve] rectangular layout (paging disabled): "
              f"max_batch x max_len KV rectangle, "
              f"{eng.kv_cache_bytes()/2**20:.2f} MiB")
        return
    print(f"[serve] paged KV pool: {eng.kv.n_pages} pages x "
          f"{eng.kv.page_size} rows ({eng.kv_cache_bytes()/2**20:.2f} "
          f"MiB), peak {eng.kv.peak_used_pages} pages in use, "
          f"{eng.stats['page_waits']} page waits, "
          f"{eng.stats['preemptions']} preemptions")
    if eng.prefix is not None:
        st = eng.stats
        rate = (st["prefix_hit_tokens"] / st["prefix_lookup_tokens"]
                if st["prefix_lookup_tokens"] else 0.0)
        print(f"[serve] prefix cache: hit rate {rate:.2f} "
              f"({st['prefix_hit_tokens']}/{st['prefix_lookup_tokens']} "
              f"prompt tokens served from shared pages), peak "
              f"{st['shared_pages']} shared pages, "
              f"{st['cow_copies']} COW copies, "
              f"{st['evicted_pages']} cached pages evicted")


if __name__ == "__main__":
    main()
