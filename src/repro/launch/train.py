"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU slice, drop --smoke and pass --mesh single|multi to train
the full config under the production mesh; on this CPU box the smoke
configs train end-to-end (examples/quickstart.py drives this module).
Fault tolerance: run under launch/supervisor.py — any crash restarts the
process and training resumes from the latest atomic checkpoint with
deterministic data skip.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import train_iterator
from repro.train import TrainConfig, Trainer, make_train_step


def build_trainer(args) -> Trainer:
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    tcfg = TrainConfig(lr=args.lr, warmup=args.warmup,
                       total_steps=args.steps,
                       grad_accum=args.grad_accum,
                       compress_grads=args.compress_grads, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    jit_step = None
    if args.mesh != "none":
        from repro.launch.cells import _ns
        from repro.launch.mesh import make_production_mesh
        from repro.sharding import rules
        from repro.configs import shapes as SH
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        params = SH.param_specs(cfg)
        pspecs = rules.param_pspecs(cfg, params, mesh)
        jit_step = jax.jit(
            make_train_step(cfg, tcfg),
            in_shardings=(_ns(mesh, pspecs), None, None, None),
            donate_argnums=(0, 1))

    # resume-aware deterministic iterator: peek the checkpoint step first
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
    it = train_iterator(cfg, batch=args.batch, seq=args.seq,
                        seed=args.seed, start_step=start)
    return Trainer(cfg, tcfg, it, mgr, ckpt_every=args.ckpt_every,
                   jit_step=jit_step, log_every=args.log_every)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    tr = build_trainer(args)
    tr.restore_or_init()
    remaining = args.steps - tr.step
    if remaining <= 0:
        print(f"[train] already at step {tr.step} >= {args.steps}")
        return
    metrics = tr.run(remaining)
    print(f"[train] done at step {tr.step}: {metrics}")


if __name__ == "__main__":
    main()
