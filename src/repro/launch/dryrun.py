import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh)
cell and record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-4b --shape train_4k --mesh both --out experiments/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` with the roofline
terms (§Roofline reads these), and the run prints a summary table. A cell
that fails to lower/compile is a bug in the distribution config — the
error is recorded and the run exits nonzero.
"""
import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import V5E, analyse_compiled, model_flops


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             quantized=None, policy=None, tag: str = "") -> dict:
    from repro.sharding import rules
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, quantized=quantized,
                      policy=policy or rules.DEFAULT)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = analyse_compiled(compiled)
    n_chips = mesh.devices.size
    cellinfo = SHAPES[shape]
    tokens = cellinfo.global_batch * (
        cellinfo.seq_len if cell.mode in ("train", "prefill") else 1)
    mflops = model_flops(cell.cfg, tokens, cell.mode)

    if cell.quantized:
        # the lowered SPMD reference path unpacks packed weights to the
        # compute dtype in HBM; the Pallas TPU kernel streams the packed
        # bits and unpacks in VMEM. Report the kernel-true memory term
        # alongside the as-lowered one (§Roofline).
        from repro.quant.surgery import quantizable_paths
        from repro.configs.shapes import param_specs
        from repro.core.bpw import rank_for_bpw
        overhead = 0.0
        for _, v in quantizable_paths(param_specs(cell.cfg), cell.cfg):
            w = v["w"]
            *lead, d_in, d_out = w.shape
            n_mat = 1
            for s in lead:
                n_mat *= s
            r = rank_for_bpw(d_out, d_in, 1.0, 32)
            overhead += n_mat * (d_in * r + r * d_out) * (2.0 - 0.125)
        # packed weights shard over the model axis only — the unpack
        # overhead per chip divides by tp, not by all chips
        tp = mesh.shape.get("model", 1)
        mem_true = max(rec["hlo_bytes"] - overhead / tp, 0.0)
        rec["unpack_overhead_bytes_per_chip"] = overhead / tp
        rec["memory_s_kernel_true"] = mem_true / V5E.hbm_bw
    rec.update({
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "mode": cell.mode, "chips": int(n_chips),
        "quantized": cell.quantized, "grad_accum": cell.grad_accum,
        "model_flops_total": mflops,
        "model_flops_per_chip": mflops / n_chips,
        "useful_flops_ratio": (mflops / n_chips) / max(rec["hlo_flops"], 1.0),
        "lower_s": t_lower, "compile_s": t_compile,
    })
    mem = rec.get("memory_analysis", {})
    if mem:
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0)
                   - mem.get("alias_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
        rec["hbm_used_bytes"] = int(per_dev)
        rec["fits_hbm"] = bool(per_dev <= V5E.hbm_bytes)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape}__{mesh_name}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _fmt(rec: dict) -> str:
    gb = rec.get("hbm_used_bytes", 0) / 1e9
    return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:16s} "
            f"flops/chip={rec['hlo_flops']:.3e} "
            f"mem={gb:6.2f}GB fit={str(rec.get('fits_hbm','?')):5s} "
            f"dom={rec['dominant']:10s} "
            f"frac={rec['roofline_fraction']:.3f} "
            f"compile={rec['compile_s']:.1f}s")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fp-serve", action="store_true",
                    help="lower serving cells with FP16 params instead of "
                         "NanoQuant-packed")
    args = ap.parse_args()

    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        shape_list = (configs.shapes_for(arch) if args.shape == "all"
                      else [args.shape])
        for shape in shape_list:
            if shape not in configs.shapes_for(arch):
                print(f"skip {arch} x {shape} (see DESIGN.md §5)")
                continue
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, args.out,
                                   quantized=(False if args.fp_serve
                                              else None),
                                   tag="__fp" if args.fp_serve else "")
                    print(_fmt(rec), flush=True)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    if failures:
        print("\nFAILED CELLS:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall cells lowered + compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
