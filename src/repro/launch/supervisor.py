"""Fault-tolerance supervisor: bounded restarts + hang (straggler)
detection for any launch command.

    PYTHONPATH=src python -m repro.launch.supervisor \
        --max-restarts 3 --hang-timeout 600 -- \
        python -m repro.launch.train --arch llama3.2-1b --smoke \
            --ckpt-dir /tmp/ckpt --steps 500

Policy (the single-controller slice of a 1000+-node control plane —
on a real cluster one supervisor runs per host, and the checkpoint dir
lives on shared storage):

- child exits 0              -> done.
- child exits nonzero        -> restart with exponential backoff, up to
                                --max-restarts; training resumes from the
                                latest atomic checkpoint (deterministic
                                data skip makes the replay exact).
- no stdout progress within --hang-timeout seconds -> the child is
  declared a straggler/hang, SIGKILLed, and restarted (same budget).

By default *any* stdout line counts as progress. For children whose
output can be chatty while the actual work loop is wedged (a serving
process logging admissions while a device call never returns), pass
``--heartbeat-regex``: only matching lines reset the hang timer.
``launch/serve.py --supervise`` wires this to its per-tick
``[serve] heartbeat`` lines, so a wedged decode step is killed and
restarted (and resumes from its ``--snapshot`` file) instead of
hanging forever.

``run_with_restarts`` is the in-process variant used by tests.
"""
from __future__ import annotations

import argparse
import os
import re
import signal
import subprocess
import sys
import threading
import time
from typing import Callable, Optional


def run_with_restarts(fn: Callable[[int], None], max_restarts: int = 3,
                      backoff_s: float = 0.0, log=print) -> int:
    """In-process restart loop: fn(attempt) is retried on exception.
    Returns the number of restarts used. Raises after budget exhaustion."""
    attempt = 0
    while True:
        try:
            fn(attempt)
            return attempt
        except Exception as e:  # noqa: BLE001
            attempt += 1
            if attempt > max_restarts:
                log(f"[supervisor] giving up after {max_restarts} restarts")
                raise
            log(f"[supervisor] attempt {attempt} failed ({e!r}); "
                f"restarting in {backoff_s * attempt:.1f}s")
            time.sleep(backoff_s * attempt)


class _Pump(threading.Thread):
    """Forward child output and timestamp progress for hang detection.
    With `heartbeat_pattern`, only matching lines count as progress —
    chatty logging from a wedged child cannot mask the hang."""

    def __init__(self, pipe, sink, heartbeat_pattern: Optional[str] = None):
        super().__init__(daemon=True)
        self.pipe, self.sink = pipe, sink
        self.pattern = (re.compile(heartbeat_pattern)
                        if heartbeat_pattern else None)
        self.last_progress = time.time()

    def run(self):
        for line in iter(self.pipe.readline, b""):
            text = line.decode(errors="replace")
            if self.pattern is None or self.pattern.search(text):
                self.last_progress = time.time()
            self.sink.write(text)
            self.sink.flush()


def supervise(cmd, max_restarts: int = 3, hang_timeout: float = 0.0,
              backoff_s: float = 2.0, log=print,
              heartbeat_pattern: Optional[str] = None) -> int:
    restarts = 0
    while True:
        log(f"[supervisor] launching (attempt {restarts + 1}): "
            f"{' '.join(cmd)}")
        child = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT)
        pump = _Pump(child.stdout, sys.stdout, heartbeat_pattern)
        pump.start()
        hung = False
        while True:
            try:
                rc = child.wait(timeout=5.0)
                break
            except subprocess.TimeoutExpired:
                if (hang_timeout
                        and time.time() - pump.last_progress > hang_timeout):
                    log(f"[supervisor] no progress for {hang_timeout}s — "
                        f"straggler/hang, killing pid {child.pid}")
                    child.kill()
                    child.wait()
                    rc, hung = -9, True
                    break
        if rc == 0:
            log("[supervisor] child finished cleanly")
            return 0
        restarts += 1
        if restarts > max_restarts:
            log(f"[supervisor] restart budget ({max_restarts}) exhausted")
            return rc if rc else 1
        wait = backoff_s * (2 ** (restarts - 1))
        log(f"[supervisor] child {'hung' if hung else f'exited rc={rc}'}; "
            f"restart {restarts}/{max_restarts} in {wait:.0f}s")
        time.sleep(wait)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--hang-timeout", type=float, default=0.0)
    ap.add_argument("--backoff", type=float, default=2.0)
    ap.add_argument("--heartbeat-regex", default=None,
                    help="only stdout lines matching this regex count "
                         "as progress for --hang-timeout (default: any "
                         "line)")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- <command to supervise>")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        ap.error("no command given after --")
    raise SystemExit(supervise(cmd, args.max_restarts, args.hang_timeout,
                               args.backoff,
                               heartbeat_pattern=args.heartbeat_regex))


if __name__ == "__main__":
    main()
