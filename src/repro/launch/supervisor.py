"""Fault-tolerance supervisor: bounded restarts + hang (straggler)
detection for any launch command.

    PYTHONPATH=src python -m repro.launch.supervisor \
        --max-restarts 3 --hang-timeout 600 -- \
        python -m repro.launch.train --arch llama3.2-1b --smoke \
            --ckpt-dir /tmp/ckpt --steps 500

Policy (the single-controller slice of a 1000+-node control plane —
on a real cluster one supervisor runs per host, and the checkpoint dir
lives on shared storage):

- child exits 0              -> done.
- child exits nonzero        -> restart with exponential backoff, up to
                                --max-restarts; training resumes from the
                                latest atomic checkpoint (deterministic
                                data skip makes the replay exact).
- no stdout progress within --hang-timeout seconds -> the child is
  declared a straggler/hang, SIGKILLed, and restarted (same budget).

``run_with_restarts`` is the in-process variant used by tests.
"""
from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import threading
import time
from typing import Callable, Optional


def run_with_restarts(fn: Callable[[int], None], max_restarts: int = 3,
                      backoff_s: float = 0.0, log=print) -> int:
    """In-process restart loop: fn(attempt) is retried on exception.
    Returns the number of restarts used. Raises after budget exhaustion."""
    attempt = 0
    while True:
        try:
            fn(attempt)
            return attempt
        except Exception as e:  # noqa: BLE001
            attempt += 1
            if attempt > max_restarts:
                log(f"[supervisor] giving up after {max_restarts} restarts")
                raise
            log(f"[supervisor] attempt {attempt} failed ({e!r}); "
                f"restarting in {backoff_s * attempt:.1f}s")
            time.sleep(backoff_s * attempt)


class _Pump(threading.Thread):
    """Forward child output and timestamp progress for hang detection."""

    def __init__(self, pipe, sink):
        super().__init__(daemon=True)
        self.pipe, self.sink = pipe, sink
        self.last_progress = time.time()

    def run(self):
        for line in iter(self.pipe.readline, b""):
            self.last_progress = time.time()
            self.sink.write(line.decode(errors="replace"))
            self.sink.flush()


def supervise(cmd, max_restarts: int = 3, hang_timeout: float = 0.0,
              backoff_s: float = 2.0, log=print) -> int:
    restarts = 0
    while True:
        log(f"[supervisor] launching (attempt {restarts + 1}): "
            f"{' '.join(cmd)}")
        child = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT)
        pump = _Pump(child.stdout, sys.stdout)
        pump.start()
        hung = False
        while True:
            try:
                rc = child.wait(timeout=5.0)
                break
            except subprocess.TimeoutExpired:
                if (hang_timeout
                        and time.time() - pump.last_progress > hang_timeout):
                    log(f"[supervisor] no progress for {hang_timeout}s — "
                        f"straggler/hang, killing pid {child.pid}")
                    child.kill()
                    child.wait()
                    rc, hung = -9, True
                    break
        if rc == 0:
            log("[supervisor] child finished cleanly")
            return 0
        restarts += 1
        if restarts > max_restarts:
            log(f"[supervisor] restart budget ({max_restarts}) exhausted")
            return rc if rc else 1
        wait = backoff_s * (2 ** (restarts - 1))
        log(f"[supervisor] child {'hung' if hung else f'exited rc={rc}'}; "
            f"restart {restarts}/{max_restarts} in {wait:.0f}s")
        time.sleep(wait)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--hang-timeout", type=float, default=0.0)
    ap.add_argument("--backoff", type=float, default=2.0)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- <command to supervise>")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        ap.error("no command given after --")
    raise SystemExit(supervise(cmd, args.max_restarts, args.hang_timeout,
                               args.backoff))


if __name__ == "__main__":
    main()
