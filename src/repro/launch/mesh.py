"""Production meshes.

TPU v5e: one pod = 256 chips. Single-pod mesh is ``(data=16, model=16)``;
multi-pod adds a leading pure-DP ``pod`` axis mapped onto DCN:
``(pod=2, data=16, model=16)`` = 512 chips. Functions, not module
constants — importing this module never touches jax device state.

For the dry-run on this CPU-only box, ``launch/dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; these builders then slice however many placeholder devices each
mesh needs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before the first jax import (dryrun.py does this)")
    try:
        kwargs = {}
        if hasattr(jax.sharding, "AxisType"):   # absent in older jax
            kwargs["axis_types"] = (
                jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, devices=devs[:need], **kwargs)
    except TypeError:  # older jax without devices/axis_types kwargs
        from jax.experimental import mesh_utils
        arr = mesh_utils.create_device_mesh(shape, devices=devs[:need])
        return jax.sharding.Mesh(arr, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(data: int, model: int, pod: Optional[int] = None):
    """Arbitrary mesh for tests / small boxes (e.g. (4, 2) on 8 CPUs)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def make_serving_mesh(model: int):
    """Tensor-parallel serving mesh for the InferenceEngine: one
    ``model`` axis of `model` devices (the data axis is size 1 — the
    engine's slot pool is one replica; scale-out across replicas is
    DP at the request-router level, not inside one engine)."""
    return _mk((1, model), ("data", "model"))
