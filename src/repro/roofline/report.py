"""Render the §Roofline table from the dry-run JSON records.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(dirname: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:7.2f}s "
    return f"{s*1e3:7.2f}ms"


def render(recs, mesh_filter="pod_16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh_filter]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = []
    hdr = (f"{'arch':24s} {'shape':12s} {'mode':7s} "
           f"{'compute':9s} {'memory':9s} {'collective':10s} "
           f"{'dominant':10s} {'MFU-frac':8s} {'useful':6s} {'HBM':7s}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in rows:
        mem = r.get("memory_s_kernel_true", r["memory_s"])
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mode']:7s} "
            f"{fmt_seconds(r['compute_s'])} {fmt_seconds(mem)} "
            f"{fmt_seconds(r['collective_s'])}  "
            f"{r['dominant']:10s} {r['roofline_fraction']:8.3f} "
            f"{r.get('useful_flops_ratio', 0):6.2f} "
            f"{r.get('hbm_used_bytes', 0)/1e9:5.1f}GB"
            f"{'' if r.get('fits_hbm', True) else ' *OVER*'}")
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        print(f"\n### mesh {mesh} ({sum(r['mesh']==mesh for r in recs)} "
              f"cells)\n")
        print(render(recs, mesh))


if __name__ == "__main__":
    main()
