"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = Σ per-op wire bytes / link_bw    (per chip)

``compiled.cost_analysis()`` on an SPMD-partitioned module reports the
*per-device* program, so every term here is per-chip directly (the
prompt's global-quantity formulas divided by `chips` — identical since
the partitioner splits work evenly). Collective bytes are not in
cost_analysis: we parse the optimized HLO and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaled by a ring-model wire factor.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link
    hbm_bytes: float = 16e9             # v5e HBM capacity


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ring-model wire factor per element of *operand* data
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,          # each shard traverses the ring once
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-category operand bytes (per-device shard sizes in post-SPMD
    HLO) + wire-model bytes. '-start' fused ops are counted once."""
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for c in _COLLECTIVES:
            # match opcode use, not variable names: "<opcode>(" or
            # "<opcode>-start("
            m = re.search(rf"\b{c}(?:-start)?\(", rhs)
            if not m:
                continue
            operands = rhs[m.end():]
            depth = 1
            for i, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        operands = operands[:i]
                        break
            nbytes = sum(_shape_bytes(d, dims)
                         for d, dims in _SHAPE_RE.findall(operands))
            out[c] += nbytes
            counts[c] += 1
            break
    wire = sum(_WIRE_FACTOR[c] * b for c, b in out.items())
    return {"per_op_bytes": out, "counts": counts,
            "total_operand_bytes": sum(out.values()),
            "wire_bytes": wire}


def model_flops(cfg: ModelConfig, tokens: int, mode: str) -> float:
    """Analytic "useful" FLOPs: 6·N_active·D train, 2·N_active·D inference
    (N_active excludes embedding tables; MoE counts routed-active experts
    only)."""
    n_total = cfg.param_count()
    if cfg.family == "audio":
        emb = cfg.n_codebooks * cfg.vocab_size * cfg.d_model
    else:
        emb = cfg.vocab_size * cfg.d_model
    # pure-lookup embedding tables do no matmul FLOPs; a tied table *is*
    # the head matmul, so it stays counted.
    n_active = n_total - (0 if cfg.tie_embeddings else emb)
    if cfg.n_experts and cfg.n_experts_per_tok:
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        inactive = (cfg.n_experts - cfg.n_experts_per_tok) * per_expert
        n_active -= n_moe_layers * inactive
    factor = 6.0 if mode == "train" else 2.0
    return factor * n_active * tokens


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   hw: HW = V5E) -> Dict[str, float]:
    t_c = flops / hw.peak_flops
    t_m = hbm_bytes / hw.hbm_bw
    t_x = wire_bytes / hw.link_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0], "bound_s": bound,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }


def analyse_compiled(compiled, lowered_text: Optional[str] = None,
                     hw: HW = V5E) -> Dict[str, Any]:
    """Full per-chip analysis of one compiled cell.

    Primary source is the loop-aware HLO walk (roofline.hlo) — XLA's own
    cost_analysis counts while bodies once, which undercounts every
    lax.scan model by ~n_layers; the xla_* fields keep the raw numbers
    for comparison."""
    from repro.roofline.hlo import module_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # older jax returns [dict]
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = lowered_text if lowered_text is not None else compiled.as_text()
    mc = module_cost(text)
    flops = mc["flops"]
    hbm = mc["hbm_bytes"]
    coll = mc["collectives"]
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception:
        pass
    terms = roofline_terms(flops, hbm, coll["wire_bytes"], hw)
    return {
        "hlo_flops": flops,
        "hlo_bytes": hbm,
        "xla_flops_bodies_once": xla_flops,
        "xla_bytes_bodies_once": xla_bytes,
        "collectives": coll,
        "memory_analysis": mem,
        **terms,
    }
