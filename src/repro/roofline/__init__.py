from repro.roofline.analysis import (  # noqa: F401
    HW, analyse_compiled, collective_bytes, model_flops, roofline_terms)
