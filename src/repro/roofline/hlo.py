"""Structural cost model over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
model built on ``lax.scan`` (every arch here — layers, flash-attention
chunks, grad accumulation) is undercounted by ~the layer count, and the
same holds for collectives that live inside scan bodies. This module
re-derives per-device costs by walking the HLO computation graph:

- ``dot`` FLOPs: 2 x |result| x |contracted dims| (MXU convention),
  multiplied through enclosing while trip counts
  (``backend_config known_trip_count``, with a loop-condition fallback);
- HBM bytes: operands + results of top-level (fusion-boundary) ops —
  fusion internals stay in registers/VMEM and are not counted;
- collective wire bytes per category with a ring model:
  all-reduce 2x operand, all-gather/reduce-scatter (gather/scatter
  delta), all-to-all and collective-permute 1x.

Everything is *per device*: post-SPMD shapes are shard shapes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "iota", "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def nbytes(self) -> int:
        n = _DTYPE_BYTES.get(self.dtype, 0)
        for d in self.dims:
            n *= d
        return n

    @property
    def nelems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n


_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _parse_shapes(s: str) -> List[Shape]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt in _DTYPE_BYTES:
            out.append(Shape(dt, tuple(int(x) for x in dims.split(",") if x)))
    return out


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: List[Shape]
    operands: List[str]          # %names
    attrs: str                   # raw text after the operand list


@dataclasses.dataclass
class Computation:
    name: str
    shapes: Dict[str, List[Shape]]         # %name -> result shape(s)
    ops: List[Op]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*"
                       r"\[[0-9,]*\](?:\{[^}]*\})?))")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _split_opcode(rhs: str) -> Tuple[List[Shape], str, str]:
    """rhs: '<shape> opcode(operands...), attrs...'"""
    rhs = rhs.strip()
    if rhs.startswith("("):                      # tuple result shape
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape_s, rest = rhs[: i + 1], rhs[i + 1:]
    else:
        sp = rhs.index(" ")
        shape_s, rest = rhs[:sp], rhs[sp:]
    rest = rest.strip()
    m = re.match(r"([\w\-]+)\(", rest)
    opcode = m.group(1) if m else rest.split("(")[0]
    return _parse_shapes(shape_s), opcode, rest


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        stripped = line.strip()
        # computation header (column 0, contains '->' and ends with '{')
        if (not raw.startswith(" ") and "->" in line
                and stripped.endswith("{")):
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                # header params give shapes for %param names
                hdr = stripped[stripped.index("(") + 1:]
                for pname, pshape in _PARAM_RE.findall(hdr.split("->")[0]):
                    cur.shapes[pname] = _parse_shapes(pshape)
                continue
        if cur is None:
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name = m.group(1)
        rhs = stripped[m.end():]
        try:
            result, opcode, rest = _split_opcode(rhs)
        except (ValueError, IndexError):
            continue
        # operands: %refs inside the first balanced paren group after opcode
        paren = rest.find("(")
        depth, j = 0, paren
        for j in range(paren, len(rest)):
            depth += rest[j] == "("
            depth -= rest[j] == ")"
            if depth == 0:
                break
        opnd_s, attrs = rest[paren + 1: j], rest[j + 1:]
        operands = _OPND_RE.findall(opnd_s)
        op = Op(name, opcode, result, operands, attrs)
        cur.shapes[name] = result
        cur.ops.append(op)
    return comps, entry


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _trip_count(op: Op, comps) -> int:
    m = _TRIP_RE.search(op.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest literal in the loop-condition computation
    m = _COND_RE.search(op.attrs)
    if m and m.group(1) in comps:
        best = 1
        for o in comps[m.group(1)].ops:
            for c in re.findall(r"constant\((\d+)\)", o.attrs):
                best = max(best, int(c))
        # also scan the raw constant defs
        return best
    return 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for c in COLLECTIVES:
            self.coll_operand_bytes[c] += other.coll_operand_bytes[c] * mult
            self.coll_wire_bytes[c] += other.coll_wire_bytes[c] * mult
            self.coll_counts[c] += other.coll_counts[c] * mult


def _operand_bytes(op: Op, comp: Computation) -> float:
    total = 0.0
    for name in op.operands:
        for sh in comp.shapes.get(name, []):
            total += sh.nbytes
    return total


def _nth_operand_bytes(op: Op, comp: Computation, i: int) -> float:
    if i >= len(op.operands):
        return 0.0
    return sum(sh.nbytes for sh in comp.shapes.get(op.operands[i], []))


def _op_hbm_bytes(op: Op, comp: Computation) -> float:
    """HBM traffic of one top-level op: only the *touched* region counts.
    Slicing ops read/write their result-sized window, not the whole
    buffer (a KV cache updated in place moves O(token) bytes per step,
    not O(cache))."""
    oc = op.opcode
    res = sum(s.nbytes for s in op.result)
    if oc in ("dynamic-slice", "slice", "gather", "pad", "broadcast",
              "reshape", "reverse"):
        return res
    if oc == "dynamic-update-slice":
        return 2.0 * _nth_operand_bytes(op, comp, 1)   # read+write window
    if oc == "scatter":
        return 2.0 * _nth_operand_bytes(op, comp, 2) \
            + _nth_operand_bytes(op, comp, 1)
    if oc in ("copy", "transpose", "convert"):
        return 2.0 * res
    return _operand_bytes(op, comp) + res


def _fusion_hbm_bytes(op: Op, comp: Computation,
                      comps: Dict[str, Computation]) -> float:
    """Fused-kernel traffic: each fusion parameter is charged its
    *accessed window* (a body dynamic-slice/gather of a parameter only
    reads the slice; an in-place DUS root only writes the update
    window), everything else is read/written once."""
    m = _CALLS_RE.search(op.attrs)
    body = comps.get(m.group(1)) if m else None
    res = sum(s.nbytes for s in op.result)
    if body is None:
        return _operand_bytes(op, comp) + res
    # default charge: full size per parameter
    charge: Dict[str, float] = {}
    by_name = {o.name: o for o in body.ops}
    for pname in body.shapes:
        o = by_name.get(pname)
        if (o is not None and o.opcode == "parameter") \
                or pname.startswith("param"):
            charge[pname] = sum(s.nbytes for s in body.shapes[pname])

    def resolve(name: str) -> str:
        """Follow convert/bitcast/copy chains to the producing source
        (XLA-CPU bf16 emulation wraps loop carries in f32 round-trips
        that have no TPU analogue)."""
        seen = set()
        while name in by_name and name not in seen:
            seen.add(name)
            o = by_name[name]
            if o.opcode in ("convert", "bitcast", "copy") and o.operands:
                name = o.operands[0]
            else:
                break
        return name

    root = body.ops[-1] if body.ops else None
    root_src = resolve(root.name) if root is not None else None
    out_bytes = res
    for o in body.ops:
        if o.opcode in ("dynamic-slice", "gather", "slice") and o.operands:
            tgt = resolve(o.operands[0])
            if tgt in charge:
                w = sum(s.nbytes for s in o.result)
                charge[tgt] = min(charge[tgt], w)
        if o.opcode == "dynamic-update-slice" and o.operands:
            tgt = resolve(o.operands[0])
            upd = _nth_operand_bytes(o, body, 1)
            if tgt in charge:
                charge[tgt] = min(charge[tgt], upd)
            if root_src == o.name:
                out_bytes = 2.0 * upd        # in-place windowed write
    return sum(charge.values()) + out_bytes


def _dot_flops(op: Op, comp: Computation) -> float:
    out = sum(s.nelems for s in op.result)
    m = _DIMS_RE.search(op.attrs)
    contracted = 1
    if m and op.operands:
        lhs = comp.shapes.get(op.operands[0])
        if lhs:
            for d in m.group(1).split(","):
                if d:
                    contracted *= lhs[0].dims[int(d)]
    return 2.0 * out * contracted


def _coll_base(opcode: str) -> Optional[str]:
    for c in COLLECTIVES:
        if opcode == c or opcode == c + "-start":
            return c
    return None


def _cost_of(cname: str, comps: Dict[str, Computation],
             memo: Dict[str, Cost], in_fusion: bool = False) -> Cost:
    key = cname + ("#f" if in_fusion else "")
    if key in memo:
        return memo[key]
    memo[key] = Cost()          # cycle guard
    comp = comps.get(cname)
    if comp is None:
        return memo[key]
    cost = Cost()
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            trip = _trip_count(op, comps)
            m = _BODY_RE.search(op.attrs)
            if m:
                cost.add(_cost_of(m.group(1), comps, memo), trip)
            mc = _COND_RE.search(op.attrs)
            if mc:
                cost.add(_cost_of(mc.group(1), comps, memo), trip)
            continue
        if oc in ("fusion",):
            m = _CALLS_RE.search(op.attrs)
            if m:
                inner = _cost_of(m.group(1), comps, memo, in_fusion=True)
                # fusion internals: count flops, not HBM traffic
                c2 = Cost(flops=inner.flops)
                for c in COLLECTIVES:
                    c2.coll_operand_bytes[c] = inner.coll_operand_bytes[c]
                    c2.coll_wire_bytes[c] = inner.coll_wire_bytes[c]
                    c2.coll_counts[c] = inner.coll_counts[c]
                cost.add(c2)
            if not in_fusion:
                cost.hbm_bytes += _fusion_hbm_bytes(op, comp, comps)
            continue
        if oc in ("call", "conditional", "async-start"):
            for m in re.finditer(
                    r"(?:to_apply|calls|branch_computations=\{|true_computation|"
                    r"false_computation)=?%?([\w.\-]+)", op.attrs):
                cost.add(_cost_of(m.group(1), comps, memo, in_fusion))
            continue
        base = _coll_base(oc)
        if base is not None:
            ob = _operand_bytes(op, comp)
            rb = sum(s.nbytes for s in op.result)
            g = _group_size(op.attrs)
            if base == "all-reduce":
                wire = 2.0 * ob * (g - 1) / max(g, 1)
            elif base == "all-gather":
                wire = max(rb - ob, 0.0)
            elif base == "reduce-scatter":
                wire = max(ob - rb, 0.0)
            elif base == "all-to-all":
                wire = ob * (g - 1) / max(g, 1)
            else:                      # collective-permute
                wire = ob
            cost.coll_operand_bytes[base] += ob
            cost.coll_wire_bytes[base] += wire
            cost.coll_counts[base] += 1
            if not in_fusion:
                cost.hbm_bytes += ob + rb
            continue
        if oc in ("dot", "convolution"):
            cost.flops += _dot_flops(op, comp)
            if not in_fusion:
                cost.hbm_bytes += _operand_bytes(op, comp) + sum(
                    s.nbytes for s in op.result)
            continue
        if oc in _FREE_OPS:
            continue
        # generic elementwise / slicing / copy — windowed traffic model
        if not in_fusion:
            cost.hbm_bytes += _op_hbm_bytes(op, comp)
    memo[key] = cost
    return cost


def module_cost(text: str) -> Dict:
    """Loop-aware per-device cost of an optimized HLO module."""
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0}
    memo: Dict[str, Cost] = {}
    c = _cost_of(entry, comps, memo)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collectives": {
            "per_op_bytes": c.coll_operand_bytes,
            "wire_bytes_per_op": c.coll_wire_bytes,
            "counts": c.coll_counts,
            "total_operand_bytes": sum(c.coll_operand_bytes.values()),
            "wire_bytes": sum(c.coll_wire_bytes.values()),
        },
    }


# ---------------------------------------------------------------------------
# diagnostics: where do the flops / bytes / collectives come from?
# ---------------------------------------------------------------------------


def top_contributors(text: str, k: int = 25):
    """Top-k ops by trip-multiplied flops and HBM bytes, with metadata
    op_name provenance — the profile stand-in the §Perf loop reads."""
    comps, entry = parse_module(text)
    rows = []

    def walk(cname: str, mult: float, in_fusion: bool, seen):
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return
        seen = seen | {cname}
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trip = _trip_count(op, comps)
                m = _BODY_RE.search(op.attrs)
                if m:
                    walk(m.group(1), mult * trip, in_fusion, seen)
                continue
            if oc == "fusion":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    walk(m.group(1), mult, True, seen)
                if not in_fusion:
                    b = _fusion_hbm_bytes(op, comp, comps)
                    rows.append((op, cname, mult, 0.0, b))
                continue
            if oc in ("call", "conditional", "async-start"):
                for m in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{|"
                        r"true_computation|false_computation)=?%?([\w.\-]+)",
                        op.attrs):
                    walk(m.group(1), mult, in_fusion, seen)
                continue
            fl = _dot_flops(op, comp) if oc in ("dot", "convolution") else 0.0
            b = 0.0 if (in_fusion or oc in _FREE_OPS) else \
                _op_hbm_bytes(op, comp)
            base = _coll_base(oc)
            if fl or b or base:
                rows.append((op, cname, mult, fl, b))

    walk(entry, 1.0, False, frozenset())

    def meta(op):
        m = re.search(r'op_name="([^"]+)"', op.attrs)
        return m.group(1) if m else op.name

    def fmt(op, cname, mult, fl, b):
        shape = "x".join(str(d) for s in op.result for d in s.dims) or "()"
        return {"op": op.opcode, "result": shape, "trips": mult,
                "flops": fl * mult, "bytes": b * mult,
                "where": f"{cname}", "name": meta(op)[:160]}

    by_flops = sorted(rows, key=lambda r: -(r[3] * r[2]))[:k]
    by_bytes = sorted(rows, key=lambda r: -(r[4] * r[2]))[:k]
    return ([fmt(*r) for r in by_flops if r[3] > 0],
            [fmt(*r) for r in by_bytes if r[4] > 0])
