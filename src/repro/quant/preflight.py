"""Pre-quantization validation (docs/quantization.md §Preflight).

A multi-hour quantization run should fail in the first second with a
message naming the bad input, not at block 17 with a NaN loss or an OOM
kill. ``preflight(params, cfg, calib_batches)`` checks, in order:

1. calibration batches — present, 2-D integer ``tokens`` with one
   consistent sequence length, every id inside ``[0, vocab_size)``,
   ``labels`` (when present) shaped like tokens, ``image_embeds``
   (vlm) finite;
2. teacher params — every float leaf finite, failures name the leaf
   path (a NaN teacher poisons every block downstream);
3. a per-block working-set estimate (activation streams + the largest
   block's params + ADMM factor state) against available host memory,
   so an over-sized calibration set fails fast with the knob to turn
   (``--calib-samples`` / ``--calib-seq``) instead of an OOM kill
   mid-run.

All failures raise :class:`PreflightError`.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


class PreflightError(ValueError):
    """A quantization input failed validation before any work ran."""


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _check_calib(cfg, calib_batches) -> int:
    if not calib_batches:
        raise PreflightError("no calibration batches given — the pipeline "
                             "needs at least one {'tokens', ...} batch")
    seqs = set()
    n_tokens = 0
    for i, b in enumerate(calib_batches):
        if "tokens" not in b:
            raise PreflightError(f"calibration batch {i} has no 'tokens'")
        toks = np.asarray(b["tokens"])
        if toks.ndim != 2:
            raise PreflightError(
                f"calibration batch {i}: tokens must be 2-D (batch, seq), "
                f"got shape {toks.shape}")
        if not np.issubdtype(toks.dtype, np.integer):
            raise PreflightError(
                f"calibration batch {i}: tokens dtype {toks.dtype} is not "
                f"an integer type")
        if toks.size and (toks.min() < 0 or toks.max() >= cfg.vocab_size):
            raise PreflightError(
                f"calibration batch {i}: token ids span "
                f"[{toks.min()}, {toks.max()}] but vocab_size is "
                f"{cfg.vocab_size}")
        seqs.add(toks.shape[1])
        n_tokens += toks.size
        if "labels" in b:
            lab = np.asarray(b["labels"])
            if lab.shape != toks.shape:
                raise PreflightError(
                    f"calibration batch {i}: labels shape {lab.shape} != "
                    f"tokens shape {toks.shape}")
        if cfg.family == "vlm":
            if "image_embeds" not in b:
                raise PreflightError(
                    f"calibration batch {i}: vlm family needs "
                    f"'image_embeds' in every batch")
            emb = np.asarray(b["image_embeds"])
            if not np.isfinite(emb).all():
                raise PreflightError(
                    f"calibration batch {i}: image_embeds contain "
                    f"non-finite values")
    if len(seqs) != 1:
        raise PreflightError(
            f"calibration batches mix sequence lengths {sorted(seqs)} — "
            f"the activation streams need one consistent length")
    return n_tokens


def _check_params(params) -> None:
    bad: List[str] = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.floating):
            continue
        if not bool(jnp.isfinite(arr.astype(jnp.float32)).all()):
            bad.append(_leaf_name(path))
            if len(bad) >= 5:
                break
    if bad:
        raise PreflightError(
            "teacher params contain non-finite values in: "
            + ", ".join(bad)
            + " — a NaN teacher poisons every quantized block; re-export "
              "or re-train the checkpoint before quantizing")


def _available_bytes() -> int:
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    try:
        return (os.sysconf("SC_AVPHYS_PAGES")
                * os.sysconf("SC_PAGE_SIZE"))
    except (ValueError, OSError):
        return 0


def estimate_block_bytes(cfg, calib_batches) -> int:
    """Rough per-block working set: the three activation streams
    (X_q, X_fp, Y) in f32, the largest block's params twice (FP + the
    tuned copy), and ADMM factor state (~3x the largest linear)."""
    n_rows = sum(np.asarray(b["tokens"]).shape[0] for b in calib_batches)
    seq = np.asarray(calib_batches[0]["tokens"]).shape[1]
    acts = 3 * n_rows * seq * cfg.d_model * 4
    # largest linear in any block: d_model x max(d_ff, d_model-ish)
    widest = max(getattr(cfg, "d_ff", cfg.d_model), cfg.d_model)
    block_params = 4 * cfg.d_model * widest * 4        # a few big linears
    admm_state = 3 * cfg.d_model * widest * 4
    return acts + 2 * block_params + admm_state


def preflight(params, cfg, calib_batches) -> Dict[str, Any]:
    """Validate quantization inputs; raises :class:`PreflightError` on
    the first failure, returns a small summary dict on success."""
    n_tokens = _check_calib(cfg, calib_batches)
    _check_params(params)
    need = estimate_block_bytes(cfg, calib_batches)
    avail = _available_bytes()
    if avail and need > avail:
        raise PreflightError(
            f"estimated per-block working set "
            f"{need / 2**20:.0f} MiB exceeds available memory "
            f"{avail / 2**20:.0f} MiB — shrink the calibration set "
            f"(--calib-samples / --calib-seq) or free host memory")
    return {"n_batches": len(calib_batches), "n_calib_tokens": n_tokens,
            "est_block_bytes": need, "available_bytes": avail}
