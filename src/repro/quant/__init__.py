"""Quantized-model surgery: abstract (ShapeDtypeStruct) packed trees
for dry-runs and storage accounting, serving-side merged projection
groups, and mesh placement of packed params / KV caches — see
:mod:`repro.quant.surgery` and docs/architecture.md (the concrete
weight transformation itself lives in ``core.pipeline``).
"""
from repro.quant.faults import (  # noqa: F401
    InjectedPipelineCrash, QuantFault, QuantFaultPlan)
from repro.quant.preflight import PreflightError, preflight  # noqa: F401
from repro.quant.surgery import (  # noqa: F401
    abstract_quantized_params, merge_projection_groups, packed_model_bytes,
    place_cache_on_mesh, place_on_mesh, quantizable_paths)

__all__ = [
    "abstract_quantized_params", "merge_projection_groups",
    "packed_model_bytes", "place_on_mesh", "place_cache_on_mesh",
    "quantizable_paths",
    "preflight", "PreflightError",
    "QuantFault", "QuantFaultPlan", "InjectedPipelineCrash",
]
