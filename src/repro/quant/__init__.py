from repro.quant.surgery import (  # noqa: F401
    abstract_quantized_params, packed_model_bytes, quantizable_paths)
