"""Quantized-model surgery: map an FP parameter tree to its NanoQuant
packed form — abstractly (ShapeDtypeStructs, for the serving dry-run and
storage accounting) or concretely (delegated to core.pipeline).

The selection rule mirrors ``core.pipeline.linear_paths``: every linear
param dict ``{"w": (d_in, d_out)}`` (or stacked experts
``(E, d_in, d_out)``) inside a transformer block whose min dim is >=
``min_dim``, excluding routers. Embeddings / lm_head / norms stay FP —
the paper quantizes transformer linears only.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.bpw import nanoquant_bits, rank_for_bpw
from repro.core.layout import BLOCK_STACKS, quantizable_linear
from repro.models.config import ModelConfig

# selection rule + FP exclusions single-sourced in core.layout (shared
# with core.pipeline's concrete walk)
_BLOCK_STACKS = BLOCK_STACKS


def quantizable_paths(params, cfg: ModelConfig, min_dim: int = 48
                      ) -> List[Tuple[Tuple[str, ...], Any]]:
    """[(path, linear-dict)] for every quantizable linear in the model."""
    out = []

    def walk(d, path):
        for k in sorted(d.keys()):
            v = d[k]
            if not isinstance(v, dict):
                continue
            if "w" in v and not isinstance(v["w"], dict):
                if quantizable_linear(k, v["w"].shape, min_dim):
                    out.append((path + (k,), v))
            else:
                walk(v, path + (k,))

    for stack in _BLOCK_STACKS:
        if stack in params and isinstance(params[stack], dict):
            walk(params[stack], (stack,))
    return out


def _packed_struct(w_shape, target_bpw: float, rank_align: int,
                   k_align: int = 32):
    """SDS dict for one packed linear; returns (struct, rank). The d_in
    dim is tile-aligned to ``k_align`` exactly as
    ``core.packing.pack_quantized`` stores it."""
    *lead, d_in, d_out = w_shape
    r = rank_for_bpw(d_out, d_in, target_bpw, rank_align)
    k_align = max(32, k_align)
    kp = -(-d_in // k_align) * k_align
    lead = tuple(lead)
    f32 = jnp.dtype(jnp.float32)
    u32 = jnp.dtype(jnp.uint32)
    return {
        "qu_t": jax.ShapeDtypeStruct(lead + (r // 32, d_out), u32),
        "qv": jax.ShapeDtypeStruct(lead + (kp // 32, r), u32),
        "s1": jax.ShapeDtypeStruct(lead + (d_out,), f32),
        "s2": jax.ShapeDtypeStruct(lead + (kp,), f32),
    }, r


def abstract_quantized_params(cfg: ModelConfig, target_bpw: float = 1.0,
                              min_dim: int = 48, rank_align: int = 32,
                              k_align: int = 32):
    """ShapeDtypeStruct tree of the NanoQuant-quantized model — the exact
    structure ``core.pipeline.nanoquant_quantize`` emits, built without
    touching a single weight (for AOT serving dry-runs)."""
    from repro.configs.shapes import param_specs
    params = param_specs(cfg)

    def q(tree, path):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict) and "w" in v and not isinstance(v["w"], dict):
                w = v["w"]
                if quantizable_linear(k, w.shape, min_dim):
                    struct, _ = _packed_struct(w.shape, target_bpw,
                                               rank_align, k_align)
                    if "b" in v:
                        struct["b"] = v["b"]
                    out[k] = struct
                    continue
            out[k] = q(v, path + (k,)) if isinstance(v, dict) else v
        return out

    new = dict(params)
    for stack in _BLOCK_STACKS:
        if stack in new and isinstance(new[stack], dict):
            new[stack] = q(new[stack], (stack,))
    return new


# ---------------------------------------------------------------------------
# merged projection groups (serving-side)
# ---------------------------------------------------------------------------

# (sibling keys sharing the block input, merged key)
MERGE_GROUPS = (
    (("wq", "wk", "wv"), "wqkv"),
    (("w_gate", "w_up"), "wgu"),
)


def _pad_to(a, targets):
    """Pad trailing dims: targets maps axis-from-end -> target size."""
    spec = [(0, 0)] * a.ndim
    for ax_fe, tgt in targets.items():
        ax = a.ndim - ax_fe
        spec[ax] = (0, tgt - a.shape[ax])
    return jnp.pad(a, spec) if any(p[1] for p in spec) else a


def _stack_group(subs):
    """Stack P packed sibling linears into one grouped operand set for
    the fused merged kernel: every projection padded to the widest rank
    R and output Nmax (padded s1 columns are 0; ``rmask`` zeros the
    padded rank columns, see kernels.binary_matmul)."""
    ranks = [int(s["qv"].shape[-1]) for s in subs]
    nouts = [int(s["qu_t"].shape[-1]) for s in subs]
    R, n_max = max(ranks), max(nouts)
    lead = subs[0]["qv"].shape[:-2]
    ax2, ax1 = len(lead), len(lead)          # new group axis position
    mp = {
        "qv": jnp.stack([_pad_to(s["qv"], {1: R}) for s in subs], ax2),
        "qu_t": jnp.stack([_pad_to(s["qu_t"], {2: R // 32, 1: n_max})
                           for s in subs], ax2),
        "s1": jnp.stack([_pad_to(s["s1"].astype(jnp.float32), {1: n_max})
                         for s in subs], ax1),
        "s2": jnp.stack([s["s2"].astype(jnp.float32) for s in subs], ax1),
    }
    rmask = jnp.stack([(jnp.arange(R) < r).astype(jnp.float32)
                       for r in ranks])
    mp["rmask"] = jnp.broadcast_to(rmask, lead + rmask.shape) + 0.0
    if any("b" in s for s in subs):
        bs = []
        for s, n in zip(subs, nouts):
            b = s["b"].astype(jnp.float32) if "b" in s else \
                jnp.zeros(lead + (n,), jnp.float32)
            bs.append(_pad_to(b, {1: n_max}))
        mp["b"] = jnp.stack(bs, ax1)
    return mp


def merge_projection_groups(params):
    """Serving-side transform: wherever a block holds packed sibling
    projections that read the same activations (attention QKV; MLP
    gate/up) with a common packed d_in, add a merged operand group
    (``wqkv`` / ``wgu``) so the model layer can issue ONE grouped kernel
    launch instead of three/two (`models.layers.dense_merged`).

    Original per-projection leaves are kept (calibration, the ref path
    and checkpointing keep reading them); the merged copies add only
    packed-width memory. FP / partially-quantized groups are skipped.
    Applied by ``serve.engine.InferenceEngine`` on its own copy of the
    params — saved artifacts are never rewritten.
    """
    def walk(d):
        out = {}
        changed = False
        for k, v in d.items():
            if isinstance(v, dict):
                nv = walk(v)
                changed = changed or (nv is not v)
                out[k] = nv
            else:
                out[k] = v
        for names, merged_key in MERGE_GROUPS:
            if merged_key in out:
                continue
            if "router" in out:
                # MoE expert stacks run through dense_expert (expert-grid
                # kernel), not ffn() — a merged copy would never be read
                continue
            subs = [out.get(nm) for nm in names]
            if not all(isinstance(s, dict) and "qu_t" in s for s in subs):
                continue
            if len({s["qv"].shape[:-1] for s in subs}) != 1:
                continue                     # packed d_in / lead mismatch
            out[merged_key] = _stack_group(subs)
            changed = True
        return out if changed else d

    return walk(params) if isinstance(params, dict) else params


# ---------------------------------------------------------------------------
# rank-truncated draft views (serve.speculative)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class EffRank:
    """Static effective-rank marker placed inside a packed linear dict
    by :func:`rank_truncated_view`. Flattens to ZERO leaves with the
    rank in the treedef's aux data, so under ``jit`` it stays a Python
    int (usable as a static slice extent / Pallas block size) instead
    of becoming a tracer. Hash/eq by value: two views at the same
    fraction share one jit cache entry."""

    def __init__(self, r: int):
        self.r = int(r)

    def tree_flatten(self):
        return (), self.r

    @classmethod
    def tree_unflatten(cls, aux, _children):
        return cls(aux)

    def __int__(self):
        return self.r

    def __eq__(self, other):
        return isinstance(other, EffRank) and other.r == self.r

    def __hash__(self):
        return hash(("EffRank", self.r))

    def __repr__(self):
        return f"EffRank({self.r})"


def truncated_rank(r: int, rank_frac: float, align: int = 32) -> int:
    """r' = frac·r rounded down to `align`, clamped to [align, r] (the
    packed rank axis is consumed in 32-row bit-words, so r' must stay
    a multiple of 32)."""
    return min(int(r), max(align, int(int(r) * rank_frac) // align * align))


def rank_truncated_view(params, rank_frac: float, align: int = 32):
    """Zero-copy draft view of a packed parameter tree: every packed
    linear dict gains a static ``eff_rank`` = :func:`truncated_rank` of
    its own rank; **every array leaf is the original object** (asserted
    by buffer identity in tests — nothing is sliced, repacked or even
    copied). The model layers thread ``eff_rank`` into the kernel
    launch, which reads only the leading r' rank columns of qv / r'//32
    packed rows of qu_t (BlockSpec sub-extents on the fused Pallas
    path, in-trace slices on the ref path) — so the truncated forward
    is *exactly* the full model with the trailing r − r' components
    zeroed, at zero extra storage.

    Applies uniformly to plain packed dicts, merged projection groups
    (``wqkv`` / ``wgu`` — truncation on the padded common rank; each
    member projection effectively min(r_p, r')) and stacked expert
    grids (rank is the last qv axis regardless of leading dims). Dicts
    whose rank already satisfies r' == r are returned as the *same*
    dict object. FP leaves (embeddings, norms, head, routers) are
    shared untouched — the draft differs from the verifier only inside
    the quantized linears."""
    if not (0.0 < rank_frac <= 1.0):
        raise ValueError(f"rank_frac must be in (0, 1], got {rank_frac}")

    def walk(d):
        out = {}
        changed = False
        for k, v in d.items():
            if isinstance(v, dict) and "qu_t" in v and "qv" in v:
                r = int(v["qv"].shape[-1])
                rp = truncated_rank(r, rank_frac, align)
                if rp == r:
                    out[k] = v
                else:
                    nv = dict(v)
                    nv["eff_rank"] = EffRank(rp)
                    out[k] = nv
                    changed = True
            elif isinstance(v, dict):
                nv = walk(v)
                changed = changed or (nv is not v)
                out[k] = nv
            else:
                out[k] = v
        return out if changed else d

    return walk(params) if isinstance(params, dict) else params


def place_on_mesh(params, cfg: ModelConfig, mesh, policy=None):
    """Place a (quantized or FP) parameter tree onto a serving mesh per
    ``sharding.rules``: packed U/s1 d_out-sharded on ``model`` for
    column-parallel projections, packed V/s2 d_in-sharded for
    row-parallel ones, everything non-divisible replicated. The default
    policy is :data:`repro.sharding.rules.SERVE` (tensor-parallel only,
    V replicated) — the layout the shard_map kernel launch in
    ``kernels.ops`` consumes shard-for-shard. Returns the placed tree;
    call on the engine's own params copy at init."""
    from repro.sharding import rules
    pspecs = rules.param_pspecs(cfg, params, mesh,
                                policy if policy is not None else rules.SERVE)
    shardings = rules.to_shardings(mesh, pspecs)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), params,
                        shardings)


def place_cache_on_mesh(cache, cfg: ModelConfig, mesh, policy=None,
                        paged: bool = False):
    """Place a pooled KV / SSM cache per ``sharding.rules.cache_pspecs``
    (kv-heads — or the sequence dim — on ``model``; slot/batch dim on
    the data axes when divisible). ``paged=True`` for a page-pool cache
    (serve.paging): the pool shards its kv-head dim only, with the
    replicated fallback when non-divisible."""
    from repro.sharding import rules
    cache = jax.tree.map(jnp.asarray, cache)   # e.g. the hybrid ring's
    # python-int `window` leaf, which cache_pspecs sizes by .shape
    cspecs = rules.cache_pspecs(cfg, cache, mesh,
                                policy if policy is not None else rules.SERVE,
                                paged=paged)
    shardings = rules.to_shardings(mesh, cspecs)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), cache, shardings)


def packed_model_bytes(cfg: ModelConfig, target_bpw: float = 1.0,
                       min_dim: int = 48, rank_align: int = 32,
                       k_align: int = 32) -> Dict[str, float]:
    """Storage accounting for the quantized checkpoint (App. F style):
    packed linears (scales counted fp16 as the paper stores them) + FP16
    residue (embeddings, norms, head, sub-min_dim linears). k_align:
    pack-time K tile alignment — padded qv rows / s2 columns are real
    bytes in the artifact and are counted."""
    from repro.configs.shapes import param_specs
    params = param_specs(cfg)
    qpaths = quantizable_paths(params, cfg, min_dim)
    qset = set()
    q_bits = 0
    k_align = max(32, k_align)
    for path, v in qpaths:
        w = v["w"]
        *lead, d_in, d_out = w.shape
        n_mat = 1
        for s in lead:
            n_mat *= s
        r = rank_for_bpw(d_out, d_in, target_bpw, rank_align)
        pad_k = -(-d_in // k_align) * k_align - d_in
        q_bits += n_mat * (nanoquant_bits(d_out, d_in, r)
                           + pad_k * r + 16 * pad_k)
        qset.add(path)

    def in_qset(kp):
        parts = []
        for p in kp:
            parts.append(getattr(p, "key", getattr(p, "idx", p)))
        # drop trailing leaf name ('w' / 'b')
        return tuple(parts[:-1]) in qset and parts[-1] == "w"

    fp_bits = 0
    qw_bits = 0
    for kp, leaf in jax.tree_util.tree_leaves_with_path(params):
        size = 1
        for s in leaf.shape:
            size *= s
        if in_qset(kp):
            qw_bits += size * 16
        else:
            fp_bits += size * 16
    return {
        "fp16_total_gb": (fp_bits + qw_bits) / 8 / 1e9,
        "quantized_gb": (q_bits + fp_bits) / 8 / 1e9,
        "linears_bpw": q_bits / max(qw_bits / 16, 1),
        "compression_x": (fp_bits + qw_bits) / max(q_bits + fp_bits, 1),
    }
