"""Quantized-model surgery: map an FP parameter tree to its NanoQuant
packed form — abstractly (ShapeDtypeStructs, for the serving dry-run and
storage accounting) or concretely (delegated to core.pipeline).

The selection rule mirrors ``core.pipeline.linear_paths``: every linear
param dict ``{"w": (d_in, d_out)}`` (or stacked experts
``(E, d_in, d_out)``) inside a transformer block whose min dim is >=
``min_dim``, excluding routers. Embeddings / lm_head / norms stay FP —
the paper quantizes transformer linears only.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.bpw import nanoquant_bits, rank_for_bpw
from repro.core.layout import BLOCK_STACKS, quantizable_linear
from repro.models.config import ModelConfig

# selection rule + FP exclusions single-sourced in core.layout (shared
# with core.pipeline's concrete walk)
_BLOCK_STACKS = BLOCK_STACKS


def quantizable_paths(params, cfg: ModelConfig, min_dim: int = 48
                      ) -> List[Tuple[Tuple[str, ...], Any]]:
    """[(path, linear-dict)] for every quantizable linear in the model."""
    out = []

    def walk(d, path):
        for k in sorted(d.keys()):
            v = d[k]
            if not isinstance(v, dict):
                continue
            if "w" in v and not isinstance(v["w"], dict):
                if quantizable_linear(k, v["w"].shape, min_dim):
                    out.append((path + (k,), v))
            else:
                walk(v, path + (k,))

    for stack in _BLOCK_STACKS:
        if stack in params and isinstance(params[stack], dict):
            walk(params[stack], (stack,))
    return out


def _packed_struct(w_shape, target_bpw: float, rank_align: int):
    """SDS dict for one packed linear; returns (struct, rank)."""
    *lead, d_in, d_out = w_shape
    r = rank_for_bpw(d_out, d_in, target_bpw, rank_align)
    lead = tuple(lead)
    f32 = jnp.dtype(jnp.float32)
    u32 = jnp.dtype(jnp.uint32)
    return {
        "qu_t": jax.ShapeDtypeStruct(lead + (r // 32, d_out), u32),
        "qv": jax.ShapeDtypeStruct(lead + (d_in // 32, r), u32),
        "s1": jax.ShapeDtypeStruct(lead + (d_out,), f32),
        "s2": jax.ShapeDtypeStruct(lead + (d_in,), f32),
    }, r


def abstract_quantized_params(cfg: ModelConfig, target_bpw: float = 1.0,
                              min_dim: int = 48, rank_align: int = 32):
    """ShapeDtypeStruct tree of the NanoQuant-quantized model — the exact
    structure ``core.pipeline.nanoquant_quantize`` emits, built without
    touching a single weight (for AOT serving dry-runs)."""
    from repro.configs.shapes import param_specs
    params = param_specs(cfg)

    def q(tree, path):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict) and "w" in v and not isinstance(v["w"], dict):
                w = v["w"]
                if quantizable_linear(k, w.shape, min_dim):
                    struct, _ = _packed_struct(w.shape, target_bpw,
                                               rank_align)
                    if "b" in v:
                        struct["b"] = v["b"]
                    out[k] = struct
                    continue
            out[k] = q(v, path + (k,)) if isinstance(v, dict) else v
        return out

    new = dict(params)
    for stack in _BLOCK_STACKS:
        if stack in new and isinstance(new[stack], dict):
            new[stack] = q(new[stack], (stack,))
    return new


def packed_model_bytes(cfg: ModelConfig, target_bpw: float = 1.0,
                       min_dim: int = 48, rank_align: int = 32
                       ) -> Dict[str, float]:
    """Storage accounting for the quantized checkpoint (App. F style):
    packed linears (scales counted fp16 as the paper stores them) + FP16
    residue (embeddings, norms, head, sub-min_dim linears)."""
    from repro.configs.shapes import param_specs
    params = param_specs(cfg)
    qpaths = quantizable_paths(params, cfg, min_dim)
    qset = set()
    q_bits = 0
    for path, v in qpaths:
        w = v["w"]
        *lead, d_in, d_out = w.shape
        n_mat = 1
        for s in lead:
            n_mat *= s
        r = rank_for_bpw(d_out, d_in, target_bpw, rank_align)
        q_bits += n_mat * nanoquant_bits(d_out, d_in, r)
        qset.add(path)

    def in_qset(kp):
        parts = []
        for p in kp:
            parts.append(getattr(p, "key", getattr(p, "idx", p)))
        # drop trailing leaf name ('w' / 'b')
        return tuple(parts[:-1]) in qset and parts[-1] == "w"

    fp_bits = 0
    qw_bits = 0
    for kp, leaf in jax.tree_util.tree_leaves_with_path(params):
        size = 1
        for s in leaf.shape:
            size *= s
        if in_qset(kp):
            qw_bits += size * 16
        else:
            fp_bits += size * 16
    return {
        "fp16_total_gb": (fp_bits + qw_bits) / 8 / 1e9,
        "quantized_gb": (q_bits + fp_bits) / 8 / 1e9,
        "linears_bpw": q_bits / max(qw_bits / 16, 1),
        "compression_x": (fp_bits + qw_bits) / max(q_bits + fp_bits, 1),
    }
