"""Deterministic fault injection for the quantization pipeline
(docs/quantization.md §Fault injection).

The quant-side sibling of ``serve.faults.FaultPlan``: a
:class:`QuantFaultPlan` is a schedule of :class:`QuantFault` records
keyed off the pipeline's own block index — no wall clock, no ambient
randomness — that ``core.pipeline.nanoquant_quantize`` consults at its
seams. A chaos run is bit-for-bit reproducible from the plan, which is
what lets ``benchmarks/quant_chaos.py`` gate kill→resume→bit-identical
artifacts and fallback-on-divergence.

Fault kinds:

- ``"crash_block"`` — raise :class:`InjectedPipelineCrash` when block
  ``block`` *starts being computed* (a resumed/skipped block does not
  crash, so a supervised restart makes progress).
- ``"crash_after_save"`` — crash after block ``block``'s packed leaves
  are checkpointed but *before* its journal entry is appended (the
  orphan-checkpoint window: resume must redo the block, bit-identical).
- ``"crash_after_journal"`` — crash after block ``block``'s journal
  entry is appended (the clean window: resume must skip the block).
- ``"nan_init"`` — overwrite block ``block``, linear ``linear``'s init
  latents with NaN, as if ADMM had diverged at iteration
  ``iteration`` — the pipeline's health guard must catch it and walk
  the init-method fallback ladder instead of packing poison.
- ``"corrupt_journal"`` — after appending block ``block``'s journal
  entry, flip a digit inside the stored line (still valid JSON, crc now
  wrong): a later resume must refuse, naming the block.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

KINDS = ("crash_block", "crash_after_save", "crash_after_journal",
         "nan_init", "corrupt_journal")


class InjectedPipelineCrash(RuntimeError):
    """Simulated hard crash (process death stand-in) raised at a
    pipeline seam; drivers/tests catch it, then resume from the
    journal."""

    def __init__(self, block: int, seam: str):
        super().__init__(f"injected pipeline crash at block {block} "
                         f"({seam})")
        self.block = block
        self.seam = seam


@dataclasses.dataclass(frozen=True)
class QuantFault:
    """One scheduled fault (each fires at most once)."""
    block: int
    kind: str
    linear: int = 0                    # nan_init: linear index in block
    iteration: int = 0                 # nan_init: reported ADMM iteration

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown quant fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class QuantFaultPlan:
    """Deterministic schedule of :class:`QuantFault` records plus the
    replay log (``plan.fired``) two identically-planned runs must agree
    on. Pass to ``nanoquant_quantize(..., faults=plan)`` (or
    ``NanoQuantModel.quantize``/``launch.quantize``)."""

    def __init__(self, faults: Sequence[QuantFault]):
        self.faults = list(faults)
        self._spent = [False] * len(self.faults)
        self.fired: List[Tuple[int, str]] = []

    def _due(self, kind: str, block: int):
        for i, f in enumerate(self.faults):
            if not self._spent[i] and f.kind == kind and f.block == block:
                yield i, f

    def _fire(self, i: int) -> None:
        self._spent[i] = True
        f = self.faults[i]
        self.fired.append((f.block, f.kind))

    # ---- pipeline seams ----------------------------------------------------

    def on_block_start(self, bi: int) -> None:
        """Block `bi` is about to be *computed* (not resumed)."""
        for i, _ in self._due("crash_block", bi):
            self._fire(i)
            raise InjectedPipelineCrash(bi, "block start")

    def poison_init(self, bi: int, li: int) -> Optional[QuantFault]:
        """Non-None => overwrite this (block, linear)'s init latents
        with NaN (returns the fault for its reported iteration)."""
        for i, f in self._due("nan_init", bi):
            if f.linear == li:
                self._fire(i)
                return f
        return None

    def after_block_save(self, bi: int) -> None:
        """Between a block's leaf checkpoint and its journal append."""
        for i, _ in self._due("crash_after_save", bi):
            self._fire(i)
            raise InjectedPipelineCrash(bi, "after block save")

    def on_journal_append(self, bi: int, journal) -> None:
        """Right after a block's journal entry is appended: corrupt it
        and/or crash."""
        for i, _ in self._due("corrupt_journal", bi):
            self._fire(i)
            _corrupt_last_line(journal.path)
        for i, _ in self._due("crash_after_journal", bi):
            self._fire(i)
            raise InjectedPipelineCrash(bi, "after journal append")

    # ---- reporting --------------------------------------------------------

    @property
    def pending_faults(self) -> int:
        return self._spent.count(False)

    def summary(self) -> dict:
        return {"scheduled": len(self.faults),
                "fired": list(self.fired),
                "unfired": [dataclasses.asdict(self.faults[i])
                            for i, s in enumerate(self._spent) if not s]}


def _corrupt_last_line(path: str) -> None:
    """Flip one digit inside the last journal line: the line stays
    complete, well-terminated JSON — only its crc32 no longer matches
    (the 'silent bitrot' class, distinct from a torn append)."""
    with open(path, "rb") as f:
        raw = f.read()
    body = raw.rstrip(b"\n")
    start = body.rfind(b"\n") + 1
    line = bytearray(raw[start:])
    for j, b in enumerate(line):
        if ord("0") <= b <= ord("9"):
            line[j] = ord("0") if b != ord("0") else ord("1")
            break
    else:
        raise RuntimeError(f"no digit to corrupt in {path!r} last line")
    with open(path, "r+b") as f:
        f.seek(start)
        f.write(bytes(line))
        f.flush()
        os.fsync(f.fileno())
