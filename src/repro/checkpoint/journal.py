"""Crash-safe progress journal for the quantization pipeline.

Layout under one journal directory::

    <dir>/journal.jsonl        # header line + one line per finished block
    <dir>/blocks/step_<bi>/    # the block's packed leaves (keyed
                               # CheckpointManager checkpoint, per-leaf crc32)

Every journal line is ``{"payload": {...}, "crc": crc32(canonical
payload)}`` and is flushed + fsynced on append. The header carries a
fingerprint of (model config, quant config, params, calibration data),
so a journal can never be resumed against a different run — resume
*refuses* a mismatch instead of silently producing a franken-artifact.

Write ordering per block is save-the-leaves-then-append-the-line: a
crash between the two leaves an orphan block checkpoint that resume
simply redoes (bit-identical, thanks to per-block RNG keying). The only
tolerated journal damage is a *torn final append* (truncated last line,
no trailing newline) — exactly what a crash mid-append produces; it is
dropped and the block redone. Any other damage (interior parse failure,
crc mismatch on a complete line, a journal entry whose block checkpoint
is missing or whose leaf crcs disagree) raises :class:`JournalError`
naming the bad block: the journal is evidence of corruption, not
something to guess around.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager, _fsync_path

JOURNAL_NAME = "journal.jsonl"
JOURNAL_VERSION = 1


class JournalError(RuntimeError):
    """Unresumable journal state (fingerprint mismatch / corruption).

    ``block`` names the offending block label when the damage is
    attributable to one block's entry or checkpoint."""

    def __init__(self, message: str, block: Optional[str] = None):
        self.block = block
        super().__init__(message)


def _canonical(payload: Dict[str, Any]) -> bytes:
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def _crc_leaves(tree) -> int:
    """One crc32 over every leaf's bytes (+shape/dtype), order-stable."""
    crc = 0
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(jax.device_get(leaf))
        crc = zlib.crc32(repr((arr.shape, arr.dtype.name)).encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), crc)
    return crc


def run_fingerprint(params, cfg, qcfg, calib_batches,
                    n_blocks: int) -> Dict[str, Any]:
    """Identity of a quantization run: resume refuses any mismatch."""
    fp = {
        "version": JOURNAL_VERSION,
        "model_config": dataclasses.asdict(cfg),
        "quant_config": dataclasses.asdict(qcfg),
        "params_crc": _crc_leaves(params),
        "calib_crc": _crc_leaves(calib_batches),
        "n_blocks": n_blocks,
    }
    # canonicalize through json so tuples/np scalars compare equal to
    # what a reloaded journal header contains
    return json.loads(_canonical(fp))


class QuantJournal:
    """Per-block progress journal + block-leaf store (see module doc)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, JOURNAL_NAME)
        self.blocks = CheckpointManager(
            os.path.join(directory, "blocks"), keep=10 ** 9)

    # ---- writing -----------------------------------------------------------

    def _append(self, payload: Dict[str, Any]) -> None:
        line = json.dumps(
            {"payload": payload,
             "crc": zlib.crc32(_canonical(payload))}) + "\n"
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(self.dir)

    def start(self, fingerprint: Dict[str, Any]) -> None:
        """Begin a fresh journal (truncates any previous one)."""
        if os.path.exists(self.path):
            os.remove(self.path)
        self._append({"kind": "header", "fingerprint": fingerprint})

    def save_block(self, bi: int, block: str, packed_bp) -> Dict[str, Any]:
        """Persist one finished block's packed leaves (atomic, keyed).
        Returns the leaf-crc list the entry must carry."""
        self.blocks.save(bi, packed_bp, keyed=True)
        return {"leaf_crcs": self.blocks.meta(bi)["checksums"]}

    def append_block(self, payload: Dict[str, Any]) -> None:
        """Record a finished block (call *after* save_block)."""
        self._append(dict(payload, kind="block"))

    def load_block(self, bi: int):
        return self.blocks.restore_keyed(bi)

    # ---- reading / resume --------------------------------------------------

    def _read_lines(self):
        """Parse journal lines; tolerates exactly one torn final append
        (truncated trailing line), truncating the file back to the
        valid prefix so new appends don't concatenate into garbage."""
        if not os.path.exists(self.path):
            return []
        with open(self.path, "rb") as f:
            raw = f.read()
        out, offset, i = [], 0, 0
        while offset < len(raw):
            nl = raw.find(b"\n", offset)
            complete = nl != -1
            line = raw[offset:nl] if complete else raw[offset:]
            if line == b"" and complete:
                offset = nl + 1
                continue
            try:
                rec = json.loads(line.decode("utf-8"))
                payload, crc = rec["payload"], rec["crc"]
            except (ValueError, KeyError, TypeError, UnicodeDecodeError):
                rec = None
            if rec is None or not complete:
                rest = raw[nl + 1:] if complete else b""
                if rest.strip() == b"":
                    # torn final append: drop it and truncate the file
                    # so the redone block appends cleanly
                    with open(self.path, "r+b") as f:
                        f.truncate(offset)
                        f.flush()
                        os.fsync(f.fileno())
                    break
                raise JournalError(
                    f"journal {self.path!r}: line {i} is corrupt and is "
                    f"not a torn final append — refusing to resume "
                    f"(delete the journal directory to start over)")
            if crc != zlib.crc32(_canonical(payload)):
                blk = payload.get("block")
                raise JournalError(
                    f"journal {self.path!r}: line {i}"
                    + (f" (block {blk!r})" if blk else "")
                    + " fails its crc32 — journal entry corrupt; "
                    "refusing to resume", block=blk)
            out.append(payload)
            offset = nl + 1
            i += 1
        return out

    def entries_for_resume(
            self, fingerprint: Dict[str, Any]) -> Optional[Dict[int, dict]]:
        """Validate the journal against `fingerprint` and every block
        entry against its block checkpoint. Returns {bi: entry} of
        completed blocks, or None when there is no journal yet (fresh
        start). Raises :class:`JournalError` on any mismatch."""
        lines = self._read_lines()
        if not lines:
            return None
        head = lines[0]
        if head.get("kind") != "header":
            raise JournalError(
                f"journal {self.path!r}: first line is not a header")
        if head.get("fingerprint") != fingerprint:
            theirs, ours = head.get("fingerprint") or {}, fingerprint
            diffs = [k for k in ours
                     if theirs.get(k) != ours[k]] or ["<structure>"]
            raise JournalError(
                f"journal {self.path!r} belongs to a different run "
                f"(mismatched: {', '.join(diffs)}) — refusing to resume "
                f"a journal against a different model/config/calibration")
        done: Dict[int, dict] = {}
        for entry in lines[1:]:
            if entry.get("kind") != "block":
                continue
            bi, blk = entry["bi"], entry.get("block")
            try:
                meta = self.blocks.meta(bi)
            except (OSError, ValueError) as e:
                raise JournalError(
                    f"journal entry for block {blk!r} (bi={bi}) has no "
                    f"readable block checkpoint: {e}", block=blk) from e
            if meta.get("checksums") != entry.get("leaf_crcs"):
                raise JournalError(
                    f"block {blk!r} (bi={bi}): journal leaf crc32s "
                    f"disagree with the block checkpoint — refusing to "
                    f"resume from a corrupt block entry", block=blk)
            done[bi] = entry
        # entries must form a contiguous prefix of the block order
        for j in range(len(done)):
            if j not in done:
                raise JournalError(
                    f"journal {self.path!r}: completed blocks are not a "
                    f"contiguous prefix (missing bi={j})")
        return done

    def n_completed_blocks(self) -> int:
        """Completed-block count without full validation (driver
        convenience, e.g. deciding whether a crash drill already ran)."""
        try:
            return sum(1 for p in self._read_lines()
                       if p.get("kind") == "block")
        except JournalError:
            return 0
