"""Atomic, resumable checkpoints with reshard-on-load.

Layout: ``<dir>/step_00000123/{arrays-<k>.npz, meta.json}``. A save is
written into ``<dir>/.tmp-<step>-<pid>`` and ``os.replace``d into place —
readers never observe a partial checkpoint, and a crash mid-save leaves
only a tmp dir that the next retention sweep removes. Checkpoints store
*logical* (global) arrays: on restore they are ``device_put`` against
whatever mesh/shardings the new job runs — this is what makes elastic
re-mesh (restart on a different topology) work.

Leaves are striped across numbered .npz shard files so very large states
don't funnel through one file, and written leaf-by-leaf (no full-state
duplication in host memory).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 et al with numpy)
import numpy as np

# npz cannot round-trip ml_dtypes extended floats (bf16, fp8): they load
# back as raw void. We store them as same-width unsigned-int bit views and
# record the true dtype in meta.json.
_UINT_OF = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync commits the
    entries themselves — rename alone is not durable across power loss)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Durable atomic file write: temp file in the same directory +
    flush + fsync + rename + parent-dir fsync. A crash at any point
    leaves either the old content or the new content, never a torn
    file (the tmp leftover is ignored by readers)."""
    d = os.path.dirname(os.path.abspath(path))
    tmp = os.path.join(d, f".tmp-{os.path.basename(path)}-{os.getpid()}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_path(d)


_NATIVE = {"float16", "float32", "float64", "int8", "int16", "int32",
           "int64", "uint8", "uint16", "uint32", "uint64", "bool"}


def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    dt = arr.dtype
    if dt.name in _NATIVE:
        return arr, dt.name
    return arr.view(_UINT_OF[dt.itemsize]), dt.name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    want = np.dtype(name)
    return arr if arr.dtype == want else arr.view(want)


def _keystr(k) -> str:
    """One path component of a jax key path as a plain string."""
    if hasattr(k, "key"):                              # DictKey
        return str(k.key)
    if hasattr(k, "idx"):                              # SequenceKey
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 shard_mb: int = 512):
        self.dir = directory
        self.keep = keep
        self.shard_bytes = shard_mb * 1024 * 1024
        os.makedirs(directory, exist_ok=True)

    # ---- paths -----------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ---- save --------------------------------------------------------------

    def save(self, step: int, state: Any, keyed: bool = False) -> str:
        """Durable atomic save. With ``keyed=True`` (dict-only trees)
        the meta also records each leaf's key path, so the checkpoint
        can be restored without a template via :meth:`restore_keyed`."""
        keypaths: Optional[list] = None
        if keyed:
            flat, _ = jax.tree_util.tree_flatten_with_path(state)
            keypaths = ["/".join(_keystr(k) for k in path)
                        for path, _ in flat]
            leaves = [leaf for _, leaf in flat]
        else:
            leaves = jax.tree.leaves(state)
        tmp = os.path.join(self.dir, f".tmp-{step}-{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        shards, cur, cur_bytes = [], {}, 0
        sizes, dtypes, checksums = [], [], []
        for i, leaf in enumerate(leaves):
            arr, dtname = _encode(np.asarray(jax.device_get(leaf)))
            sizes.append(list(arr.shape))
            dtypes.append(dtname)
            # per-leaf crc32 of the stored bits: restore verifies it, so
            # a corrupt/truncated artifact fails with the bad leaf named
            # instead of a downstream unpack shape crash (one leaf at a
            # time — no full-state duplication)
            checksums.append(
                zlib.crc32(np.ascontiguousarray(arr).tobytes()))
            cur[f"leaf_{i:06d}"] = arr
            cur_bytes += arr.nbytes
            if cur_bytes >= self.shard_bytes:
                shards.append(cur)
                cur, cur_bytes = {}, 0
        if cur:
            shards.append(cur)
        # every file is flushed + fsynced before the directory rename,
        # and the rename itself is committed with directory fsyncs — a
        # crash (or power loss) mid-save can never surface a step dir
        # whose contents are torn
        for k, shard in enumerate(shards):
            with open(os.path.join(tmp, f"arrays-{k}.npz"), "wb") as f:
                np.savez(f, **shard)
                f.flush()
                os.fsync(f.fileno())
        meta = {"step": step, "n_leaves": len(leaves),
                "n_shards": len(shards), "shapes": sizes,
                "dtypes": dtypes, "checksums": checksums}
        if keypaths is not None:
            meta["keypaths"] = keypaths
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                         # atomic commit
        _fsync_path(self.dir)
        self._retain()
        return final

    def _retain(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        for name in os.listdir(self.dir):              # crashed saves
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # ---- restore -------------------------------------------------------------

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Any:
        """Load `step` into the structure of `template`. If `shardings`
        (a matching tree of jax.sharding.Sharding) is given, leaves are
        placed sharded — reshard-on-load."""
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        flat, tdef = jax.tree.flatten(template)
        if meta["n_leaves"] != len(flat):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, template has "
                f"{len(flat)} — structure mismatch")
        arrays: dict = {}
        for k in range(meta["n_shards"]):
            shard_path = os.path.join(d, f"arrays-{k}.npz")
            if not os.path.exists(shard_path):
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: shard "
                    f"arrays-{k}.npz missing")
            with np.load(shard_path) as z:
                arrays.update({n: z[n] for n in z.files})
        stored: list = []
        checksums = meta.get("checksums")  # absent in pre-crc artifacts
        for i in range(len(flat)):
            key = f"leaf_{i:06d}"
            if key not in arrays:
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: leaf {i} "
                    f"({key}) missing from its shard")
            raw = arrays[key]
            if meta.get("shapes") is not None \
                    and tuple(raw.shape) != tuple(meta["shapes"][i]):
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: leaf {i} has "
                    f"stored shape {tuple(raw.shape)}, manifest says "
                    f"{tuple(meta['shapes'][i])}")
            if checksums is not None:
                got = zlib.crc32(np.ascontiguousarray(raw).tobytes())
                if got != checksums[i]:
                    raise ValueError(
                        f"corrupt/truncated checkpoint {d!r}: leaf {i} "
                        f"checksum mismatch (stored crc32 "
                        f"{checksums[i]:#010x}, loaded {got:#010x})")
            stored.append(raw)
        leaves = [_decode(stored[i], meta["dtypes"][i])
                  for i in range(len(flat))]
        for i, (ld, tp) in enumerate(zip(leaves, flat)):
            want = tuple(getattr(tp, "shape", np.shape(tp)))
            if tuple(ld.shape) != want:
                raise ValueError(f"leaf {i}: checkpoint shape {ld.shape} "
                                 f"!= template {want}")
        if shardings is not None:
            shard_flat = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "device_set"))
            leaves = [jax.device_put(l, s)
                      for l, s in zip(leaves, shard_flat)]
        else:
            leaves = [jax.numpy.asarray(l) for l in leaves]
        return jax.tree.unflatten(tdef, leaves)

    def meta(self, step: int) -> dict:
        """The saved meta.json of `step` (shapes, dtypes, per-leaf
        crc32s) — cheap integrity cross-checks without loading arrays."""
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f)

    def restore_keyed(self, step: int) -> Any:
        """Template-free restore of a checkpoint written with
        ``save(..., keyed=True)``: rebuilds the nested dict tree from
        the recorded key paths, with the same per-leaf crc32 / shape
        verification as :meth:`restore`."""
        d = self._step_dir(step)
        meta = self.meta(step)
        keypaths = meta.get("keypaths")
        if keypaths is None:
            raise ValueError(
                f"checkpoint {d!r} was not saved keyed "
                f"(no keypaths in meta) — use restore(template=...)")
        arrays: dict = {}
        for k in range(meta["n_shards"]):
            shard_path = os.path.join(d, f"arrays-{k}.npz")
            if not os.path.exists(shard_path):
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: shard "
                    f"arrays-{k}.npz missing")
            with np.load(shard_path) as z:
                arrays.update({n: z[n] for n in z.files})
        out: dict = {}
        checksums = meta.get("checksums")
        for i, kp in enumerate(keypaths):
            key = f"leaf_{i:06d}"
            if key not in arrays:
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: leaf {i} "
                    f"({kp}) missing from its shard")
            raw = arrays[key]
            if tuple(raw.shape) != tuple(meta["shapes"][i]):
                raise ValueError(
                    f"corrupt/truncated checkpoint {d!r}: leaf {i} "
                    f"({kp}) has stored shape {tuple(raw.shape)}, "
                    f"manifest says {tuple(meta['shapes'][i])}")
            if checksums is not None:
                got = zlib.crc32(np.ascontiguousarray(raw).tobytes())
                if got != checksums[i]:
                    raise ValueError(
                        f"corrupt/truncated checkpoint {d!r}: leaf {i} "
                        f"({kp}) checksum mismatch")
            node = out
            parts = kp.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = jax.numpy.asarray(
                _decode(raw, meta["dtypes"][i]))
        return out

    def restore_latest(self, template: Any = None,
                       shardings: Any = None
                       ) -> Optional[Tuple[int, Any]]:
        step = self.latest_step()
        if step is None or template is None:
            return None
        return step, self.restore(step, template, shardings)
