from repro.checkpoint.journal import (  # noqa: F401
    JournalError, QuantJournal, run_fingerprint)
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, atomic_write_bytes)
