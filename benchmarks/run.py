"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only tableX,...]

Prints CSV per table and writes JSON under experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig9_admm, kernel_bench, kernel_wallclock,
                        quant_chaos, serve_bench, table2_perplexity,
                        table4_efficiency, table5_init, table6_components,
                        table9_databudget, table13_storage)

TABLES = {
    "table2": table2_perplexity,
    "table4": table4_efficiency,
    "table5": table5_init,
    "table6": table6_components,
    "table9": table9_databudget,
    "table13": table13_storage,
    "fig9": fig9_admm,
    "kernels": kernel_bench,
    "kernel_wallclock": kernel_wallclock,
    "serve": serve_bench,
    "quant_chaos": quant_chaos,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of " + ",".join(TABLES))
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(TABLES)
    failures = []
    for name in names:
        t0 = time.time()
        try:
            TABLES[name].run()
            print(f"[bench] {name} done in {time.time()-t0:.1f}s",
                  flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("FAILED:", failures)
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
