"""Paper Table 6: component-wise efficacy (init / error mitigation /
factorized refinement / model reconstruction)."""
from __future__ import annotations

from benchmarks.common import calib, emit, eval_ppl, teacher
from repro import api

_BASE = dict(target_bpw=1.0, lr_pre=3e-4, lr_post=1e-4, lr_glob=1e-4, admm_iters=20, t_pre=8, t_post=12, t_glob=8,
             rank_align=32, min_dim=32)


def run():
    cfg, params, _ = teacher()
    cal = calib(cfg)
    variants = [
        ("init only", dict(skip_tune_fp=True, skip_ste=True, skip_kd=True)),
        ("init+EPM", dict(skip_ste=True, skip_kd=True)),
        ("init+refine", dict(skip_tune_fp=True, skip_kd=True)),
        ("init+EPM+refine", dict(skip_kd=True)),
        ("full pipeline", dict()),
    ]
    rows = []
    for name, kw in variants:
        model = api.NanoQuantModel.quantize(
            params, cfg, cal, api.QuantConfig(**_BASE, **kw), verbose=False)
        rows.append({"components": name, "ppl": eval_ppl(cfg, model.params)})
    emit("table6_components", rows)
    return rows


if __name__ == "__main__":
    run()
