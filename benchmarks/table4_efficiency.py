"""Paper Table 4: compression/resource efficiency (data, wall time, PPL)
— PTQ methods measured at tiny scale; full-scale storage is exact."""
from __future__ import annotations

import time

from benchmarks.common import CALIB_SEQ, calib, emit, eval_ppl, teacher
from repro import api
from repro.core.baselines import rtn_binarize, xnor_binarize


def run():
    cfg, params, teach_s = teacher()
    rows = [{"method": "Full-Precision", "bits": 16.0, "data_tokens": 0,
             "wall_s": 0.0, "ppl": eval_ppl(cfg, params)}]
    for n_samples, tag in ((8, "small-calib"), (24, "3x-calib")):
        cal = calib(cfg, n_samples=n_samples)
        t0 = time.time()
        model = api.NanoQuantModel.quantize(
            params, cfg, cal,
            api.QuantConfig(target_bpw=1.0, lr_pre=3e-4, lr_post=1e-4,
                            lr_glob=1e-4, admm_iters=20, t_pre=8, t_post=12,
                            t_glob=8, rank_align=32, min_dim=32),
            verbose=False)
        rows.append({"method": f"NanoQuant ({tag})", "bits": 1.0,
                     "data_tokens": n_samples * CALIB_SEQ,
                     "wall_s": time.time() - t0,
                     "ppl": eval_ppl(cfg, model.params)})
    emit("table4_efficiency", rows)
    return rows


if __name__ == "__main__":
    run()
