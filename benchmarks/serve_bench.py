"""Serving-scheduler benchmark: wave vs continuous batching on a
mixed-length, Poisson-ish request trace (ROADMAP serving north star;
paper §4.4 deployment claim lives in this decode loop), plus the paged
KV-cache memory-pressure race.

Both schedules run on the same ``InferenceEngine`` (same jitted prefill
/ decode steps, greedy sampling), differing only in admission policy —
so tok/s, per-request latency and wasted-slot-step deltas isolate the
scheduler. ``--tp N`` adds a tensor-parallel continuous row on a
``(data=1, model=N)`` mesh and asserts greedy token identity with the
unsharded engine (the sharded smoke gate in ``scripts/verify.sh``).
Emits ``experiments/bench/serve_bench.json``.

The paged section replays a mixed-length *memory-pressure* trace (a few
long prompts + many short ones) on three engines: the rectangular
oracle at full ``max_batch``, the paged pool overcommitted to HALF the
rectangle's KV bytes, and a rectangle shrunk to the same byte budget as
the paged pool. It asserts greedy token identity paged-vs-rectangular,
peak KV-pool bytes <= 50%, and strictly higher admitted concurrency
under the equal-byte budget; emits
``experiments/bench/BENCH_serve_paged.json``.

The speculative section (``--spec`` runs it alone) races the
non-speculative engine against the rank-truncated draft + batched
verification subsystem (serve.speculative) on two quantized teachers:
the TINY acceptance ladder over ``spec_rank_frac`` and the SMALL
long-generation amortization race (>= 1.5x decode tok/s gate on the
full run). Greedy token identity is asserted at every point, including
a ``--tp N`` chain; emits ``experiments/bench/BENCH_serve_spec.json``
(smoke: ``BENCH_serve_spec_smoke.json`` — never the full baseline).

The prefix section (``--prefix`` runs it alone) replays a shared-
system-prompt mixed-length trace (with exact page-aligned duplicate
prompts, so copy-on-write fires) on prefix-cache-on vs -off engines at
the SAME overcommitted pool byte budget. Both engines are compile-
warmed with token-shifted same-structure prompts, then the warmed
index is dropped (``prefix.clear()``) so the timed region measures
page sharing, not compile skips. Gates: greedy token identity at every
point (including ``--tp N`` and a ``spec_rank_frac`` compose row),
strictly higher admitted concurrency, and mean TTFT cut >= 2x (wall-
clock: hard on the full run, warn-only under ``--smoke``); emits
``experiments/bench/BENCH_serve_prefix[_smoke].json``.

The chaos section (``--chaos`` runs it alone) replays the scheduler
trace on a prefix+speculative engine under a seeded ``FaultPlan``
covering every fault kind (cancel at a tick / mid-prefill /
mid-spec-rollback, a deadline storm, a dry-pool borrow, a prefix
eviction inside the admission gate, a forced-preemption storm, one
injected decode-step device error, one poison request) with
``ServeConfig(debug=True)`` auditing page accounting after every tick.
Gates: every handle reaches a structured terminal status, surviving
requests' greedy outputs are token-identical to the undisturbed
engine, the fired log and outputs are bit-for-bit reproducible across
two identically-seeded runs, zero pages leak at quiesce, and a
drain -> snapshot -> restore -> complete leg is token-identical end to
end; emits ``experiments/bench/BENCH_serve_chaos[_smoke].json``.

``--seed`` (default 7) derives every section's trace seed (run=seed,
paged=seed+4, spec=seed+16, prefix=seed+30, chaos=seed+44 — the
defaults reproduce the historical 7/11/23 traces) and is recorded in
each emitted BENCH json's ``meta`` block.

Each section ends with a throughput regression gate
(:func:`benchmarks.common.check_regression`): the machine-independent
summary ratio (paged/rect decode tok/s, best pinned speculative
speedup, noprefix/prefix TTFT) must stay within 10% of the checked-in
baseline of the SAME mode (full vs ``_smoke``), read before the run
overwrites its artifact. ``NQ_BENCH_INJECT_SLOWDOWN=0.2`` proves the
gates fire.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--tp N]
        [--paged] [--spec] [--prefix] [--chaos] [--seed S]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import jax
import numpy as np

from benchmarks import common
from repro.models import transformer as T
from repro.serve import (Fault, FaultPlan, InferenceEngine, Request,
                         ServeConfig, recovery)
from repro.serve.scheduler import bucket_length

MAX_BATCH = 4
MAX_LEN = 48
PAGED_BATCH = 8          # slots in the memory-pressure race
PAGE_SIZE = 8            # small pages so the tiny trace crosses many
#                          page boundaries (production default is 64)


def build_trace(rng, n_req, vocab, max_prompt=24, max_new=16):
    """Mixed-length requests with Poisson-ish arrival gaps (in units of
    engine steps; mean gap < mean service time, so a queue forms and
    the scheduler — not arrival sparsity — decides slot occupancy).
    Returns [(arrival_step, Request)]."""
    trace, step = [], 0
    for uid in range(n_req):
        step += int(rng.poisson(0.6))
        prompt = rng.integers(0, vocab,
                              size=(int(rng.integers(4, max_prompt + 1)),)
                              ).astype(np.int32)
        budget = int(rng.integers(2, max_new + 1))
        trace.append((step, Request(uid, prompt, max_new_tokens=budget)))
    return trace


def build_pressure_trace(rng, n_long, n_short, vocab):
    """Memory-pressure mix: a few near-max_len prompts plus a burst of
    short ones, all arriving quickly — so total *sequence capacity*
    (KV rows), not arrival sparsity, limits concurrency. Returns
    [(arrival_step, Request)]."""
    trace, step, uid = [], 0, 0
    for _ in range(n_long):
        n = int(rng.integers(MAX_LEN * 3 // 5, MAX_LEN * 4 // 5))
        trace.append((step, Request(uid, rng.integers(
            0, vocab, size=(n,)).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 9)))))
        uid += 1
    for _ in range(n_short):
        step += int(rng.poisson(0.4))
        n = int(rng.integers(3, 9))
        trace.append((step, Request(uid, rng.integers(
            0, vocab, size=(n,)).astype(np.int32),
            max_new_tokens=int(rng.integers(6, 14)))))
        uid += 1
    return sorted(trace, key=lambda t: t[0])


def drive(mode, params, cfg, trace, mesh=None, scfg=None,
          max_batch=MAX_BATCH, max_len=MAX_LEN):
    """Run one admission policy over the trace; returns a metrics row."""
    eng = InferenceEngine(params, cfg, scfg or ServeConfig(greedy=True),
                          max_batch=max_batch, max_len=max_len,
                          admission=mode, mesh=mesh)
    # warm every prompt-length bucket + the decode step so the timed
    # region measures scheduling, not XLA compiles. Budget 2 (not 1):
    # a budget-1 request finishes at admission off the prefill logits
    # and would leave the decode step untraced. The warm prompt length
    # is clamped below max_len (submit rejects n >= max_len) but still
    # pads to the same bucket. Warm prompts must be DISTINCT per bucket:
    # with the prefix cache on, identical (e.g. all-zero) warm prompts
    # prefix-hit each other and compile only the shared-prefix admission
    # + suffix-prefill steps — the plain per-bucket prefill then
    # compiles inside the timed region (the ~30% "gather tax" of the
    # original paged baseline was exactly these mid-trace compiles).
    buckets = sorted({bucket_length(len(r.prompt), max_len)
                      for _, r in trace})
    vocab = cfg.vocab_size
    for i, b in enumerate(buckets):
        n = min(b, max_len - 2)
        warm = ((np.arange(n) * 7 + i * 31 + 1) % vocab).astype(np.int32)
        eng.submit(Request(-1 - i, warm, max_new_tokens=2))
    eng.run()
    assert eng.stats["decode_traces"], "warm-up must trace the decode step"
    if eng.prefix is not None:
        # drop warm-up entries: the timed region must measure page
        # sharing between trace requests, not hits on warm prompts
        eng.prefix.clear()
    eng.reset_stats()

    handles = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or eng.in_flight:
        while i < len(trace) and trace[i][0] <= eng.stats["steps"]:
            handles[trace[i][1].uid] = eng.submit(trace[i][1])
            i += 1
        eng.step()
    dt = time.perf_counter() - t0

    lats = np.asarray(sorted(h.latency for h in handles.values()))
    tokens = sum(len(eng.done[uid].output) for uid in handles)
    dts = eng.stats["decode_time_s"]
    row = {
        "engine": mode if mesh is None else f"{mode}-tp{mesh.shape['model']}",
        "requests": len(handles),
        "tokens": tokens,
        "tok_per_s": tokens / dt,
        # decode-loop throughput: tokens over wall time spent inside the
        # decode/speculative tick only (excludes prefill + admission),
        # the quantity speculative decoding accelerates
        "decode_tok_s": tokens / dts if dts else 0.0,
        "mean_latency_s": float(lats.mean()),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "decode_steps": eng.stats["decode_steps"],
        "wasted_slot_steps": eng.stats["wasted_slot_steps"],
        "kv_bytes": eng.kv_cache_bytes(),
        "peak_active": eng.stats["peak_active"],
        "preemptions": eng.stats["preemptions"],
        # recompute cost of preemption resume, in replayed token
        # positions — the same unit as spec_rollback_tokens below, so
        # rollback cost and preemption cost are directly comparable
        "preempt_recompute_tokens": eng.stats["preempt_recompute_tokens"],
        "page_waits": eng.stats["page_waits"],
    }
    if eng.spec is not None:
        row.update({
            "spec_rank_frac": eng.scfg.spec_rank_frac,
            "spec_k": eng.scfg.spec_k,
            "spec_k_final": eng.spec.k,
            "accept_rate": eng.spec.acceptance_rate(),
            "spec_cycles": eng.stats["spec_cycles"],
            "spec_rollback_tokens": eng.stats["spec_rollback_tokens"],
            "spec_rollback_pages": eng.stats["spec_rollback_pages"],
        })
    return row, {uid: eng.done[uid].output for uid in handles}


def run_paged(smoke: bool = False, seed: int = 7):
    """Paged-vs-rectangular memory-pressure race (acceptance: token
    identity, <= 50% peak KV-pool bytes, strictly higher admitted
    concurrency at the same KV-byte budget)."""
    # f32 so greedy argmax cannot flip on reduction-shape noise between
    # the gathered-pages read and the rectangle read (repo-wide identity
    # gates all run f32 for the same reason).
    cfg = dataclasses.replace(common.TINY, dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed + 4)
    n_long, n_short = (2, 8) if smoke else (4, 24)
    trace = build_pressure_trace(rng, n_long, n_short, cfg.vocab_size)

    full_pages = PAGED_BATCH * (MAX_LEN // PAGE_SIZE)
    paged_cfgs = {
        "rect-full": (ServeConfig(greedy=True, paged=False), PAGED_BATCH),
        # half the rectangle's KV bytes, same slot count: overcommitted
        "paged-half": (ServeConfig(greedy=True, page_size=PAGE_SIZE,
                                   kv_pool_pages=full_pages // 2),
                       PAGED_BATCH),
        # the rectangle shrunk to the paged pool's byte budget
        "rect-budget": (ServeConfig(greedy=True, paged=False),
                        (full_pages // 2) * PAGE_SIZE // MAX_LEN),
    }
    rows, outs = [], {}
    for name, (scfg, mb) in paged_cfgs.items():
        row, outs[name] = drive("continuous", params, cfg, trace,
                                scfg=scfg, max_batch=mb)
        row["engine"] = name
        row["max_batch"] = mb
        rows.append(row)
    # regression baseline must be read BEFORE emit: the run overwrites
    # its artifact and would then gate against itself. The gated metric
    # is the machine-independent paged/rect decode-throughput ratio
    # (the "gather tax"), not raw tok/s, and only the full trace is
    # long enough to measure it (see the smoke early-out below).
    table = "BENCH_serve_paged_smoke" if smoke else "BENCH_serve_paged"
    base_rows = common.load_baseline(table)
    # the checked-in BENCH_serve_paged.json is the full-run CPU baseline;
    # the CI smoke gate must not overwrite it with its smaller trace
    common.emit(table, rows, meta={"seed": seed + 4, "base_seed": seed,
                                   "smoke": smoke})

    by = {r["engine"]: r for r in rows}
    identical = all(np.array_equal(outs["rect-full"][u], outs["paged-half"][u])
                    for u in outs["rect-full"])
    ratio = by["paged-half"]["kv_bytes"] / by["rect-full"]["kv_bytes"]
    print(f"paged vs rectangular greedy outputs identical: {identical}")
    print(f"paged pool bytes {by['paged-half']['kv_bytes']} vs rectangular "
          f"{by['rect-full']['kv_bytes']} ({ratio:.0%}); admitted "
          f"concurrency {by['paged-half']['peak_active']} vs "
          f"{by['rect-budget']['peak_active']} at the same byte budget "
          f"({by['paged-half']['preemptions']} preemptions, "
          f"{by['paged-half']['page_waits']} page waits)")
    assert identical, "paged engine diverged from the rectangular oracle"
    assert ratio <= 0.5, f"paged pool bytes ratio {ratio:.2f} > 0.5"
    assert by["paged-half"]["peak_active"] > by["rect-budget"]["peak_active"], \
        "overcommit must admit strictly more concurrency per KV byte"

    gap = common.row_ratio(rows, "paged-half", "rect-full", "decode_tok_s")
    print(f"paged decode throughput at 50% KV bytes: {gap:.0%} of the "
          f"rectangular oracle ({by['paged-half']['decode_tok_s']:.1f} vs "
          f"{by['rect-full']['decode_tok_s']:.1f} tok/s)")
    if smoke:
        # the smoke trace finishes in ~16 decode steps — at that scale
        # the paged/rect ratio is wall-clock noise (observed swinging
        # 1.0x..1.3x run to run), so a 10% gate would flake; the ratio
        # is only gated on the full trace below
        print("[serve_paged] smoke trace too short for a stable decode "
              "ratio — regression gate runs on the full trace only")
        return
    if gap < 0.85:
        # full-run acceptance: the widened multi-page gather must hold
        # the gather tax at <= 15% of the rectangle's decode tok/s
        raise RuntimeError(f"paged decode gap {1 - gap:.0%} > 15% of the "
                           f"rectangular oracle")
    metric = "paged_vs_rect_decode_ratio"
    common.check_regression(
        common.baseline_metrics(
            base_rows,
            lambda rs: {metric: common.row_ratio(
                rs, "paged-half", "rect-full", "decode_tok_s")},
            "serve_paged"),
        {metric: gap}, rel_tol=0.10, label="serve_paged")


# the amortization race runs a SMALLER quantized model than TINY: the
# speculative win at full rank is dispatch amortization (k+1 committed
# tokens per device call), which only shows once per-call launch
# overhead rivals the forward's compute — true for SMALL on CPU, not
# for the d_model=256 TINY
SMALL = dataclasses.replace(common.TINY, name="bench-small", d_model=64,
                            d_ff=128)


@functools.lru_cache(maxsize=2)
def _quantized(cfg):
    """NanoQuant-quantize a trained f32 bench teacher once per process
    (the spec race needs *packed* params — rank truncation is defined
    on the low-rank binary factors — and a trained teacher, so draft
    acceptance measures the factorization's accuracy ladder, not argmax
    coin flips on a random-init model's near-uniform logits)."""
    from repro import api
    cfg = dataclasses.replace(cfg, dtype="float32")
    _, params, _ = common.teacher(cfg=cfg)
    qcfg = api.QuantConfig(admm_iters=10, t_pre=5, t_post=5, t_glob=5,
                           rank_align=32)
    model = api.NanoQuantModel.quantize(params, cfg, common.calib(cfg),
                                        qcfg, verbose=False)
    return model.params


def _spec_race(label, cfg, smoke, points, dynamic=None, tp=1,
               max_prompt=12, max_new=14, max_len=MAX_LEN, seed=7):
    """One model's speculative race: base engine + pinned-k spec points
    (identity asserted at every point — the verifier is full-rank, so
    outputs cannot depend on the draft). Returns (rows, best_speedup).

    k is pinned per row (spec_k_min == spec_k): the dynamic-k
    controller recompiles the fused cycle at every new k, which would
    bill XLA compiles to the timed region; `dynamic` adds one
    free-controller row with no throughput claim. Prompts are shorter
    than the scheduler race's: headroom for the drafts (the controller
    caps k at max_len-1-pos over active slots, and a cap change would
    also recompile mid-race)."""
    qparams = _quantized(cfg)
    rng = np.random.default_rng(seed + 16)
    trace = build_trace(rng, 10 if smoke else 24, cfg.vocab_size,
                        max_prompt=max_prompt, max_new=max_new)
    scfg = ServeConfig(greedy=True, page_size=PAGE_SIZE)
    base_row, base_out = drive("continuous", qparams, cfg, trace,
                               scfg=scfg, max_len=max_len)
    base_row["engine"] = f"{label}-base"
    rows = [base_row]

    def race(engine, s, mesh=None):
        row, out = drive("continuous", qparams, cfg, trace, scfg=s,
                         mesh=mesh, max_len=max_len)
        row["engine"] = engine
        assert all(np.array_equal(base_out[u], out[u])
                   for u in base_out), f"{engine} diverged from {label}-base"
        rows.append(row)

    for frac, k in points:
        race(f"{label}-spec-r{frac}-k{k}",
             dataclasses.replace(scfg, spec_rank_frac=frac, spec_k=k,
                                 spec_k_min=k))
    if dynamic is not None:
        frac, k = dynamic
        race(f"{label}-spec-dynamic-r{frac}",
             dataclasses.replace(scfg, spec_rank_frac=frac, spec_k=k,
                                 spec_k_min=1))
    if tp > 1:
        from repro.launch.mesh import make_serving_mesh
        race(f"{label}-spec-r1.0-k4-tp{tp}",
             dataclasses.replace(scfg, spec_rank_frac=1.0, spec_k=4,
                                 spec_k_min=4), mesh=make_serving_mesh(tp))

    for r in rows:
        r["model"] = f"{label}(d={cfg.d_model})"
    for r in rows[1:]:
        print(f"  {r['engine']}: accept={r.get('accept_rate', 0.0):.2f} "
              f"decode {r['decode_tok_s']:.1f} tok/s (base "
              f"{base_row['decode_tok_s']:.1f}), rollback "
              f"{r.get('spec_rollback_tokens', 0)} tokens / "
              f"{r.get('spec_rollback_pages', 0)} pages")
    pinned = rows[1:1 + len(points)]
    return rows, max(r["decode_tok_s"] / base_row["decode_tok_s"]
                     for r in pinned)


def run_spec(smoke: bool = False, tp: int = 1, seed: int = 7):
    """Self-speculative decoding races (serve.speculative), two models:

    * **ladder** (TINY, d=256): acceptance rate vs rank fraction. The
      binary factors share per-row/column scales, so every rank
      component carries similar weight — truncation degrades the argmax
      sharply, and the ladder documents that honestly.
    * **amortization** (SMALL, d=64): the throughput claim, on a
      long-generation trace (budgets up to 40 tokens amortize each
      request's final partially-wasted cycle). At spec_rank_frac=1.0
      the draft IS the full model (acceptance 1.0 by construction) and
      each fused cycle commits k+1 tokens per device call; the full
      run requires >= 1.5x decode tok/s vs the non-speculative engine
      at some pinned (frac, k) point.

    Greedy token identity is asserted at EVERY point of both races,
    including a tensor-parallel chain when tp > 1."""
    lrows, _ = _spec_race(
        "tiny", dataclasses.replace(common.TINY, dtype="float32"), smoke,
        points=([(0.5, 4)] if smoke else
                [(0.33, 4), (0.5, 4), (0.75, 4), (1.0, 4)]),
        dynamic=None if smoke else (0.75, 4),
        tp=tp, seed=seed)
    arows, best = _spec_race(
        "small", dataclasses.replace(SMALL, dtype="float32"), smoke,
        points=([(1.0, 4)] if smoke else [(1.0, 2), (1.0, 4), (1.0, 8)]),
        max_prompt=8, max_new=24 if smoke else 40, max_len=64, seed=seed)
    rows = lrows + arows
    # mode-matched baseline, read before emit (see run_paged)
    table = "BENCH_serve_spec_smoke" if smoke else "BENCH_serve_spec"
    base_rows = common.load_baseline(table)
    common.emit(table, rows, keys=list(arows[1].keys()),
                meta={"seed": seed + 16, "base_seed": seed, "smoke": smoke,
                      "tp": tp})
    print(f"speculative decode best speedup (SMALL, pinned k): "
          f"{best:.2f}x decode tok/s")
    if best < 1.5:
        # wall-clock gate: hard on the checked-in full run, warn in the
        # CI smoke (loaded boxes skew the tiny trace)
        msg = f"best speculative decode speedup {best:.2f}x < 1.5x"
        assert smoke, msg
        print(f"[serve_bench] WARNING: {msg}")
    common.check_regression(
        common.baseline_metrics(
            base_rows, lambda rs: {"spec_best_speedup_x": _spec_speedup(rs)},
            "serve_spec"),
        {"spec_best_speedup_x": best}, rel_tol=0.10, label="serve_spec")


def _spec_speedup(rows):
    """Best pinned-k SMALL speedup recomputed from artifact rows — the
    legacy baseline predates summary metrics, so the gate derives the
    ratio the same way the live race does."""
    small = [r for r in rows if str(r.get("model", "")).startswith("small")]
    base = next(r for r in small if r["engine"].endswith("-base"))
    return max(r["decode_tok_s"] / base["decode_tok_s"] for r in small
               if "-spec-" in r["engine"] and "dynamic" not in r["engine"])


def build_shared_prefix_trace(rng, n_req, vocab, sys_len, max_extra,
                              max_new):
    """Shared-system-prompt mix: every request opens with the SAME
    ``sys_len``-token system prompt (page-aligned: ``sys_len`` must be
    a multiple of PAGE_SIZE) followed by a private mixed-length tail.
    Every 4th request is an exact duplicate of the bare system prompt —
    a full-cover, page-aligned prefix hit, the case that exercises the
    admission-time copy-on-write path. Returns
    ([(arrival_step, Request)], sys_prompt)."""
    assert sys_len % PAGE_SIZE == 0
    sys_prompt = rng.integers(0, vocab, size=(sys_len,)).astype(np.int32)
    trace, step = [], 0
    for uid in range(n_req):
        step += int(rng.poisson(0.4))
        if uid % 4 == 3:
            prompt = sys_prompt.copy()
        else:
            extra = rng.integers(
                0, vocab,
                size=(int(rng.integers(1, max_extra + 1)),)).astype(np.int32)
            prompt = np.concatenate([sys_prompt, extra])
        trace.append((step, Request(uid, prompt,
                                    max_new_tokens=int(
                                        rng.integers(4, max_new + 1)))))
    return trace, sys_prompt


def drive_prefix(params, cfg, trace, scfg, mesh=None,
                 max_batch=PAGED_BATCH, max_len=MAX_LEN):
    """Prefix-race driver: like :func:`drive` but (a) compile-warms
    with token-shifted clones of the trace prompts — same lengths, so
    the same prefill buckets, suffix-prefill start offsets and the COW
    page copy all trace — then drops the warmed index
    (``prefix.clear()``), so the timed region measures page sharing,
    never compile skips; (b) reports TTFT, the latency prefix caching
    actually shrinks."""
    eng = InferenceEngine(params, cfg, scfg, max_batch=max_batch,
                          max_len=max_len, admission="continuous",
                          mesh=mesh)
    for i, (_, r) in enumerate(trace):
        warm = (r.prompt + 1) % cfg.vocab_size
        eng.submit(Request(-1 - i, warm.astype(np.int32),
                           max_new_tokens=r.max_new_tokens))
    eng.run()
    if eng.prefix is not None:
        assert eng.stats["prefix_hit_tokens"], \
            "warm-up must exercise the shared-page admission path"
        eng.prefix.clear()
    eng.reset_stats()

    handles = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or eng.in_flight:
        while i < len(trace) and trace[i][0] <= eng.stats["steps"]:
            handles[trace[i][1].uid] = eng.submit(trace[i][1])
            i += 1
        eng.step()
    dt = time.perf_counter() - t0

    ttfts = np.asarray(sorted(h.ttft for h in handles.values()))
    tokens = sum(len(eng.done[uid].output) for uid in handles)
    st = eng.stats
    row = {
        "engine": "prefix" if eng.prefix is not None else "noprefix",
        "requests": len(handles),
        "tokens": tokens,
        "tok_per_s": tokens / dt,
        "mean_ttft_s": float(ttfts.mean()),
        "p95_ttft_s": float(np.percentile(ttfts, 95)),
        "peak_active": st["peak_active"],
        "preemptions": st["preemptions"],
        "page_waits": st["page_waits"],
        "kv_bytes": eng.kv_cache_bytes(),
        "prefix_hit_tokens": st["prefix_hit_tokens"],
        "prefix_lookup_tokens": st["prefix_lookup_tokens"],
        "hit_rate": (st["prefix_hit_tokens"] / st["prefix_lookup_tokens"]
                     if st["prefix_lookup_tokens"] else 0.0),
        "shared_pages": st["shared_pages"],
        "cow_copies": st["cow_copies"],
        "evicted_pages": st["evicted_pages"],
    }
    if eng.spec is not None:
        row["spec_rank_frac"] = eng.scfg.spec_rank_frac
        row["accept_rate"] = eng.spec.acceptance_rate()
    return row, {uid: eng.done[uid].output for uid in handles}


def run_prefix(smoke: bool = False, tp: int = 1, seed: int = 7):
    """Prefix-cache race: shared-system-prompt trace on prefix-on vs
    prefix-off engines at the SAME overcommitted pool byte budget.

    Acceptance: greedy token identity at every point (including the
    ``--tp N`` chain and the speculative compose row), strictly higher
    admitted concurrency with the prefix cache, and mean TTFT cut
    >= 2x (wall-clock — hard on the full run, warn-only in the CI
    smoke, where a loaded box skews the tiny trace)."""
    # f32: the repo-wide identity-gate dtype (greedy argmax must not
    # flip between the shared-page and private-page read paths).
    cfg = dataclasses.replace(common.TINY, dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed + 30)
    n_req = 10 if smoke else 32
    sys_len = 16 if smoke else 24
    trace, _ = build_shared_prefix_trace(
        rng, n_req, cfg.vocab_size, sys_len=sys_len,
        max_extra=8, max_new=8)

    # overcommitted pool: a third of the full rectangle — tight enough
    # that no-sharing admission queues on pages, the regime where
    # shared pages buy concurrency (and so TTFT)
    pool = PAGED_BATCH * (MAX_LEN // PAGE_SIZE) // 3
    base = ServeConfig(greedy=True, page_size=PAGE_SIZE,
                       kv_pool_pages=pool)
    rows, outs = [], {}
    for name, scfg in (
            ("noprefix", dataclasses.replace(base, prefix_cache=False)),
            ("prefix", base)):
        row, outs[name] = drive_prefix(params, cfg, trace, scfg)
        rows.append(row)
    by = {r["engine"]: r for r in rows}

    def gate_identity(name, out):
        ok = all(np.array_equal(outs["noprefix"][u], out[u])
                 for u in outs["noprefix"])
        print(f"{name} greedy outputs identical to noprefix: {ok}")
        assert ok, f"{name} engine diverged from the no-sharing oracle"

    gate_identity("prefix", outs["prefix"])
    if tp > 1:
        from repro.launch.mesh import make_serving_mesh
        row, out = drive_prefix(params, cfg, trace, base,
                                mesh=make_serving_mesh(tp))
        row["engine"] = f"prefix-tp{tp}"
        rows.append(row)
        gate_identity(row["engine"], out)
    # compose: speculative decoding drafts into shared pages — the
    # reserve path COWs them first, so identity must still hold
    row, out = drive_prefix(
        params, cfg, trace,
        dataclasses.replace(base, spec_rank_frac=1.0, spec_k=4,
                            spec_k_min=4))
    row["engine"] = "prefix-spec-r1.0-k4"
    rows.append(row)
    gate_identity(row["engine"], out)

    # mode-matched baseline, read before emit (see run_paged)
    table = "BENCH_serve_prefix_smoke" if smoke else "BENCH_serve_prefix"
    base_rows = common.load_baseline(table)
    common.emit(
        table,
        rows, meta={"seed": seed + 30, "base_seed": seed, "smoke": smoke,
                    "tp": tp, "sys_len": sys_len, "pool_pages": pool})

    p, np_ = by["prefix"], by["noprefix"]
    speedup = np_["mean_ttft_s"] / p["mean_ttft_s"] \
        if p["mean_ttft_s"] else float("inf")
    print(f"prefix vs noprefix at {pool} pool pages: peak_active "
          f"{p['peak_active']} vs {np_['peak_active']}, mean TTFT "
          f"{p['mean_ttft_s']*1e3:.1f}ms vs {np_['mean_ttft_s']*1e3:.1f}ms "
          f"({speedup:.2f}x), hit rate {p['hit_rate']:.2f}, "
          f"{p['cow_copies']} COW copies, {p['evicted_pages']} evictions")
    assert p["prefix_hit_tokens"] > 0, "trace produced no prefix hits"
    assert p["peak_active"] > np_["peak_active"], \
        "prefix cache must admit strictly more concurrency per KV byte"
    if speedup < 2.0:
        msg = f"mean TTFT cut {speedup:.2f}x < 2x"
        assert smoke, msg
        print(f"[serve_bench] WARNING: {msg}")
    common.check_regression(
        common.baseline_metrics(
            base_rows,
            lambda rs: {"prefix_ttft_speedup_x": common.row_ratio(
                rs, "noprefix", "prefix", "mean_ttft_s")},
            "serve_prefix"),
        {"prefix_ttft_speedup_x": speedup}, rel_tol=0.10,
        label="serve_prefix")


def build_chaos_plan(trace):
    """One FaultPlan covering every fault kind, targeted so each fault
    is *guaranteed* to fire: mid-flight kinds (cancel_spec,
    device_error) hit the longest-budget requests — the ones that
    cannot complete before the fault arms — and queue-side kinds
    (expire, cancel) arm at step 0, firing at the first tick boundary
    after their target is submitted, before it can be admitted. The
    plan is a pure function of the (seeded) trace, so two runs from the
    same --seed replay bit-for-bit."""
    by_budget = sorted(trace, key=lambda t: (-t[1].max_new_tokens,
                                             t[1].uid))
    u = [r.uid for _, r in by_budget[:8]]
    return [
        # cancellation landing inside the speculative verify/commit
        # cycle: the longest request, mid-flight on its first cycle
        Fault(step=0, kind="cancel_spec", uid=u[0]),
        # one injected decode device error, attributed to u[1] if still
        # active (else the engine attributes the youngest active slot)
        Fault(step=4, kind="device_error", uid=u[1]),
        # cancellation landing between prefill and slot activation
        Fault(step=0, kind="cancel_prefill", uid=u[2]),
        # poison request: NaN prefill logits, isolated to this handle
        Fault(step=0, kind="poison_prefill", uid=u[3]),
        # deadline storm: three requests forced past their deadline
        Fault(step=0, kind="expire", uid=u[4]),
        Fault(step=0, kind="expire", uid=u[5]),
        Fault(step=0, kind="expire", uid=u[6]),
        # client cancellation at a tick boundary
        Fault(step=0, kind="cancel", uid=u[7]),
        # dry the pool: borrow 3 pages for 2 steps mid-trace
        Fault(step=2, kind="dry_pool", pages=3, hold=2),
        # evict cached prefix pages between the gate's match and admit
        Fault(step=3, kind="evict_prefix", pages=2),
        # forced-preemption storm: two cost-ranked victims in one step
        Fault(step=3, kind="preempt", pages=2),
    ]


def drive_chaos(params, cfg, trace, scfg, faults=None):
    """Replay the trace with arrival gating (like :func:`drive`) on a
    debug-audited engine, optionally under a FaultPlan; runs to
    quiescence and returns (engine, {uid: handle})."""
    eng = InferenceEngine(params, cfg, scfg, max_batch=MAX_BATCH,
                          max_len=MAX_LEN, admission="continuous",
                          faults=faults)
    handles, i = {}, 0
    # run past quiescence until the plan's dry-pool borrows are back
    # in the pool (empty ticks still run on_step, which returns them)
    while i < len(trace) or eng.in_flight \
            or (faults is not None and faults.borrowed_pages):
        while i < len(trace) and trace[i][0] <= eng.stats["steps"]:
            handles[trace[i][1].uid] = eng.submit(trace[i][1])
            i += 1
        eng.step()
    return eng, handles


def _chaos_row(leg, eng, handles):
    st = eng.stats
    return {"leg": leg, "requests": len(handles),
            "steps": st["steps"],
            "done": sum(h.status == "done" for h in handles.values()),
            "cancelled": st["cancelled"], "expired": st["expired"],
            "failed": st["failed"], "device_faults": st["device_faults"],
            "preemptions": st["preemptions"],
            "faults_fired": (len(eng.faults.fired)
                             if eng.faults is not None else 0),
            "leaked_pages": 0}   # asserted below before emit


def _assert_quiesced_clean(eng, leg):
    """Zero leaked pages at quiescence: every page still referenced is
    a cached prefix page, and dropping the index frees the pool."""
    eng.check_invariants()
    assert eng.kv.used_pages == eng.kv.cached_page_count, \
        f"{leg}: {eng.kv.used_pages - eng.kv.cached_page_count} " \
        f"non-cached pages leaked at quiesce"
    if eng.prefix is not None:
        eng.prefix.clear()
        assert eng.kv.used_pages == 0, \
            f"{leg}: {eng.kv.used_pages} pages leaked after prefix.clear()"


def run_chaos(smoke: bool = False, seed: int = 7):
    """Deterministic fault-injection race (acceptance: structured
    terminal statuses, surviving outputs token-identical to the
    undisturbed engine, page accounting audited after every tick, zero
    leaks at quiesce, bit-for-bit seed reproducibility, and a
    drain -> snapshot -> restore leg that completes token-identically).
    """
    # f32: the repo-wide identity-gate dtype; the chaos engine runs the
    # full serving stack (paged pool + prefix cache + pinned-k
    # speculative decode) with debug tick audits on
    cfg = dataclasses.replace(common.TINY, dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    chaos_seed = seed + 44
    rng = np.random.default_rng(chaos_seed)
    n_req = 12 if smoke else 24
    trace = build_trace(rng, n_req, cfg.vocab_size, max_new=16)
    # overcommitted pool (half the slots' rectangle) so dry_pool and
    # the preemption storm land on a pool that is already tight
    pool = MAX_BATCH * (MAX_LEN // PAGE_SIZE) // 2
    scfg = ServeConfig(greedy=True, page_size=PAGE_SIZE,
                       kv_pool_pages=pool, spec_rank_frac=1.0,
                       spec_k=4, spec_k_min=4, debug=True)

    # -- undisturbed baseline ----------------------------------------------
    eng, handles = drive_chaos(params, cfg, trace, scfg)
    assert all(h.status == "done" for h in handles.values())
    base_out = {u: eng.done[u].output for u in handles}
    rows = [_chaos_row("baseline", eng, handles)]
    _assert_quiesced_clean(eng, "baseline")

    # -- chaos run (twice: the second proves seed reproducibility) ---------
    plan_src = build_chaos_plan(trace)
    runs = []
    for rep in range(2):
        plan = FaultPlan(plan_src, seed=chaos_seed)
        eng, handles = drive_chaos(params, cfg, trace, scfg, faults=plan)
        runs.append((eng, handles, plan))
        rows.append(_chaos_row("chaos" if rep == 0 else "chaos-repeat",
                               eng, handles))
        _assert_quiesced_clean(eng, f"chaos rep {rep}")
    eng, handles, plan = runs[0]

    statuses = {u: h.status for u, h in handles.items()}
    assert all(h.finished for h in handles.values()), \
        "every handle must reach a terminal status"
    for h in handles.values():          # structured, not just a string
        if h.status != "done":
            assert h.error is not None and h.error.uid == h.uid \
                and h.error.status == h.status and h.error.reason, \
                f"request {h.uid} lacks a structured RequestError"
    fired_kinds = {k for _, k, _ in plan.fired}
    assert fired_kinds == set(f.kind for f in plan_src), \
        f"plan only fired {sorted(fired_kinds)}"
    st = eng.stats
    assert st["expired"] == 3, f"deadline storm: expired={st['expired']}"
    assert st["cancelled"] >= 2, f"cancelled={st['cancelled']}"
    assert st["failed"] == 2 and st["device_faults"] == 1, \
        f"failed={st['failed']} device_faults={st['device_faults']}"
    survivors = [u for u, s in statuses.items() if s == "done"]
    assert survivors, "chaos run must leave survivors"
    identical = all(np.array_equal(base_out[u], eng.done[u].output)
                    for u in survivors)
    print(f"chaos: {len(survivors)}/{n_req} survivors token-identical "
          f"to the undisturbed engine: {identical}; terminals "
          f"cancelled={st['cancelled']} expired={st['expired']} "
          f"failed={st['failed']}; fired={plan.fired}")
    assert identical, "a chaos survivor diverged from the baseline"

    eng2, handles2, plan2 = runs[1]
    assert plan2.fired == plan.fired, \
        f"fired logs diverged:\n{plan.fired}\nvs\n{plan2.fired}"
    assert {u: h.status for u, h in handles2.items()} == statuses
    assert all(np.array_equal(np.asarray(handles[u].tokens),
                              np.asarray(handles2[u].tokens))
               for u in handles), "replay outputs diverged"
    print(f"chaos: identically-seeded replay bit-for-bit identical "
          f"({len(plan.fired)} faults fired)")

    # -- drain -> snapshot -> restore -> complete --------------------------
    import os
    import tempfile
    eng = InferenceEngine(params, cfg, scfg, max_batch=MAX_BATCH,
                          max_len=MAX_LEN, admission="continuous")
    for _, r in trace:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    done_before = dict(eng.drain(timeout=0))
    path = os.path.join(tempfile.gettempdir(),
                        f"chaos-snap-{os.getpid()}.json")
    recovery.save_snapshot(eng, path)
    eng2 = InferenceEngine(params, cfg, scfg, max_batch=MAX_BATCH,
                           max_len=MAX_LEN, admission="continuous")
    restored = recovery.restore(eng2, recovery.load_snapshot(path))
    os.unlink(path)
    done_after = eng2.run()
    outs = {u: (done_before.get(u) or done_after[u]).output
            for u in handles}
    drain_identical = all(np.array_equal(base_out[u], outs[u])
                          for u in handles)
    row = _chaos_row("drain-restore", eng2,
                     {u: eng2.handles[u] for u in restored})
    row["requests"] = len(handles)
    rows.append(row)
    _assert_quiesced_clean(eng2, "drain-restore")
    print(f"drain -> snapshot ({len(restored)} in-flight) -> restore "
          f"-> complete token-identical: {drain_identical}")
    assert drain_identical, "snapshot/restore diverged from the baseline"

    common.emit(
        "BENCH_serve_chaos_smoke" if smoke else "BENCH_serve_chaos",
        rows, meta={"seed": chaos_seed, "base_seed": seed, "smoke": smoke,
                    "pool_pages": pool,
                    "plan": [dataclasses.asdict(f) for f in plan_src],
                    "fired": [list(f) for f in plan.fired]})


def run(smoke: bool = False, tp: int = 1, seed: int = 7):
    cfg = common.TINY
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    n_req = 12 if smoke else 32
    max_new = 6 if smoke else 16
    trace = build_trace(rng, n_req, cfg.vocab_size, max_new=max_new)

    def race():
        rows, outs = [], {}
        for mode in ("wave", "continuous"):
            row, outs[mode] = drive(mode, params, cfg, trace)
            rows.append(row)
        return rows, outs

    rows, outs = race()
    # scheduling metrics (steps, waste, outputs) are deterministic;
    # wall-clock tok/s is not — re-race on transient machine load
    # before declaring the throughput comparison lost.
    for _ in range(2):
        if rows[1]["tok_per_s"] > rows[0]["tok_per_s"]:
            break
        print("[serve_bench] tok/s inverted vs decode-step count — "
              "re-racing (transient load)")
        rows, outs = race()

    if tp > 1:
        # sharded smoke rows: the same continuous trace unsharded vs on
        # a (data=1, model=tp) mesh. Greedy outputs must be
        # token-identical (the scale-out path must not change what the
        # model says) and the decode-step counts must match exactly
        # (the mesh is invisible to the scheduler). The identity pair
        # runs in float32: a bf16 random-init model has near-tie logits
        # that partitioned-reduction ordering can flip, which would
        # gate on noise instead of on mesh correctness.
        import dataclasses
        from repro.launch.mesh import make_serving_mesh
        cfg32 = dataclasses.replace(cfg, dtype="float32")
        params32 = T.init_params(jax.random.PRNGKey(0), cfg32)
        mesh = make_serving_mesh(tp)
        row_ref, outs_ref = drive("continuous", params32, cfg32, trace)
        row_tp, outs_tp = drive("continuous", params32, cfg32, trace,
                                mesh=mesh)
        # rectangular oracle row: the default engines above run the
        # paged pool, so this also gates paged == rectangular both
        # unsharded and (by transitivity) under --tp
        row_rect, outs_rect = drive(
            "continuous", params32, cfg32, trace,
            scfg=ServeConfig(greedy=True, paged=False))
        row_ref["engine"] = "continuous-f32"
        row_rect["engine"] = "continuous-f32-rect"
        rows += [row_ref, row_tp, row_rect]
        tp_identical = all(np.array_equal(outs_ref[u], outs_tp[u])
                           for u in outs_tp)
        rect_identical = all(np.array_equal(outs_ref[u], outs_rect[u])
                             for u in outs_rect)
        print(f"sharded (tp={tp}) greedy outputs identical to unsharded: "
              f"{tp_identical}  ({row_tp['tok_per_s']:.1f} vs "
              f"{row_ref['tok_per_s']:.1f} tok/s); paged identical to "
              f"rectangular: {rect_identical}")
        assert tp_identical, "sharded engine diverged from unsharded"
        assert rect_identical, "paged engine diverged from rectangular"
        assert row_tp["decode_steps"] == row_ref["decode_steps"], \
            "mesh must not change the schedule"
    common.emit("serve_bench", rows,
                meta={"seed": seed, "base_seed": seed, "smoke": smoke,
                      "tp": tp})

    identical = all(np.array_equal(outs["wave"][u], outs["continuous"][u])
                    for u in outs["wave"])
    wave, cont = rows[0], rows[1]
    print(f"greedy outputs identical per request: {identical}")
    print(f"continuous vs wave: {cont['tok_per_s']:.1f} vs "
          f"{wave['tok_per_s']:.1f} tok/s, {cont['decode_steps']} vs "
          f"{wave['decode_steps']} decode steps, wasted slot-steps "
          f"{cont['wasted_slot_steps']} vs {wave['wasted_slot_steps']}")
    assert identical, "wave and continuous greedy outputs diverged"
    assert cont["wasted_slot_steps"] < wave["wasted_slot_steps"], \
        "continuous engine must waste strictly fewer decode slot-steps"
    assert cont["decode_steps"] < wave["decode_steps"], \
        "continuous engine must finish the trace in fewer decode steps"
    if cont["tok_per_s"] <= wave["tok_per_s"]:
        # both modes share the jitted steps, so fewer decode steps (a
        # deterministic win, asserted above) means higher tok/s on an
        # unloaded machine; in the --smoke CI gate a loaded box can
        # still invert the wall clock, so only the full run hard-fails.
        msg = ("wall-clock tok/s inverted despite the decode-step win "
               f"({cont['tok_per_s']:.1f} <= {wave['tok_per_s']:.1f}) — "
               "machine load")
        assert smoke, msg
        print(f"[serve_bench] WARNING: {msg}")

    run_paged(smoke=smoke, seed=seed)
    run_spec(smoke=smoke, tp=tp, seed=seed)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for the CI gate")
    ap.add_argument("--tp", type=int, default=1,
                    help="also run a tensor-parallel continuous row on a "
                         "(data=1, model=N) mesh and assert token "
                         "identity (needs N devices; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--paged", action="store_true",
                    help="run only the paged-vs-rectangular memory-"
                         "pressure race (BENCH_serve_paged[_smoke].json)")
    ap.add_argument("--spec", action="store_true",
                    help="run only the speculative-decode race "
                         "(BENCH_serve_spec[_smoke].json)")
    ap.add_argument("--prefix", action="store_true",
                    help="run only the prefix-cache race "
                         "(BENCH_serve_prefix[_smoke].json)")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the seeded fault-injection race "
                         "(BENCH_serve_chaos[_smoke].json)")
    ap.add_argument("--seed", type=int, default=7,
                    help="base trace seed; each section derives its own "
                         "offset from it and records it in the emitted "
                         "BENCH json metadata")
    args = ap.parse_args()
    if args.paged:
        run_paged(smoke=args.smoke, seed=args.seed)
    elif args.spec:
        run_spec(smoke=args.smoke, tp=args.tp, seed=args.seed)
    elif args.prefix:
        run_prefix(smoke=args.smoke, tp=args.tp, seed=args.seed)
    elif args.chaos:
        run_chaos(smoke=args.smoke, seed=args.seed)
    else:
        run(smoke=args.smoke, tp=args.tp, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
