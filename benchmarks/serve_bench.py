"""Serving-scheduler benchmark: wave vs continuous batching on a
mixed-length, Poisson-ish request trace (ROADMAP serving north star;
paper §4.4 deployment claim lives in this decode loop).

Both schedules run on the same ``InferenceEngine`` (same jitted prefill
/ decode steps, greedy sampling), differing only in admission policy —
so tok/s, per-request latency and wasted-slot-step deltas isolate the
scheduler. Emits ``experiments/bench/serve_bench.json``.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from benchmarks import common
from repro.models import transformer as T
from repro.serve import InferenceEngine, Request, ServeConfig
from repro.serve.scheduler import bucket_length

MAX_BATCH = 4
MAX_LEN = 48


def build_trace(rng, n_req, vocab, max_prompt=24, max_new=16):
    """Mixed-length requests with Poisson-ish arrival gaps (in units of
    engine steps; mean gap < mean service time, so a queue forms and
    the scheduler — not arrival sparsity — decides slot occupancy).
    Returns [(arrival_step, Request)]."""
    trace, step = [], 0
    for uid in range(n_req):
        step += int(rng.poisson(0.6))
        prompt = rng.integers(0, vocab,
                              size=(int(rng.integers(4, max_prompt + 1)),)
                              ).astype(np.int32)
        budget = int(rng.integers(2, max_new + 1))
        trace.append((step, Request(uid, prompt, max_new_tokens=budget)))
    return trace


def drive(mode, params, cfg, trace):
    """Run one admission policy over the trace; returns a metrics row."""
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=MAX_BATCH, max_len=MAX_LEN,
                          admission=mode)
    # warm every prompt-length bucket + the decode step so the timed
    # region measures scheduling, not XLA compiles. Budget 2 (not 1):
    # a budget-1 request finishes at admission off the prefill logits
    # and would leave the decode step untraced. The warm prompt length
    # is clamped below max_len (submit rejects n >= max_len) but still
    # pads to the same bucket.
    buckets = sorted({bucket_length(len(r.prompt), MAX_LEN)
                      for _, r in trace})
    for i, b in enumerate(buckets):
        eng.submit(Request(-1 - i,
                           np.zeros((min(b, MAX_LEN - 2),), np.int32),
                           max_new_tokens=2))
    eng.run()
    assert eng.stats["decode_traces"], "warm-up must trace the decode step"
    eng.reset_stats()

    handles = {}
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or eng.in_flight:
        while i < len(trace) and trace[i][0] <= eng.stats["steps"]:
            handles[trace[i][1].uid] = eng.submit(trace[i][1])
            i += 1
        eng.step()
    dt = time.perf_counter() - t0

    lats = np.asarray(sorted(h.latency for h in handles.values()))
    tokens = sum(len(eng.done[uid].output) for uid in handles)
    return {
        "engine": mode,
        "requests": len(handles),
        "tokens": tokens,
        "tok_per_s": tokens / dt,
        "mean_latency_s": float(lats.mean()),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "decode_steps": eng.stats["decode_steps"],
        "wasted_slot_steps": eng.stats["wasted_slot_steps"],
    }, {uid: eng.done[uid].output for uid in handles}


def run(smoke: bool = False):
    cfg = common.TINY
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    n_req = 12 if smoke else 32
    max_new = 6 if smoke else 16
    trace = build_trace(rng, n_req, cfg.vocab_size, max_new=max_new)

    def race():
        rows, outs = [], {}
        for mode in ("wave", "continuous"):
            row, outs[mode] = drive(mode, params, cfg, trace)
            rows.append(row)
        return rows, outs

    rows, outs = race()
    # scheduling metrics (steps, waste, outputs) are deterministic;
    # wall-clock tok/s is not — re-race on transient machine load
    # before declaring the throughput comparison lost.
    for _ in range(2):
        if rows[1]["tok_per_s"] > rows[0]["tok_per_s"]:
            break
        print("[serve_bench] tok/s inverted vs decode-step count — "
              "re-racing (transient load)")
        rows, outs = race()
    common.emit("serve_bench", rows)

    identical = all(np.array_equal(outs["wave"][u], outs["continuous"][u])
                    for u in outs["wave"])
    wave, cont = rows
    print(f"greedy outputs identical per request: {identical}")
    print(f"continuous vs wave: {cont['tok_per_s']:.1f} vs "
          f"{wave['tok_per_s']:.1f} tok/s, {cont['decode_steps']} vs "
          f"{wave['decode_steps']} decode steps, wasted slot-steps "
          f"{cont['wasted_slot_steps']} vs {wave['wasted_slot_steps']}")
    assert identical, "wave and continuous greedy outputs diverged"
    assert cont["wasted_slot_steps"] < wave["wasted_slot_steps"], \
        "continuous engine must waste strictly fewer decode slot-steps"
    assert cont["decode_steps"] < wave["decode_steps"], \
        "continuous engine must finish the trace in fewer decode steps"
    if cont["tok_per_s"] <= wave["tok_per_s"]:
        # both modes share the jitted steps, so fewer decode steps (a
        # deterministic win, asserted above) means higher tok/s on an
        # unloaded machine; in the --smoke CI gate a loaded box can
        # still invert the wall clock, so only the full run hard-fails.
        msg = ("wall-clock tok/s inverted despite the decode-step win "
               f"({cont['tok_per_s']:.1f} <= {wave['tok_per_s']:.1f}) — "
               "machine load")
        assert smoke, msg
        print(f"[serve_bench] WARNING: {msg}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for the CI gate")
    args = ap.parse_args()
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
