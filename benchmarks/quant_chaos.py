"""Quantization chaos gate: kill -> resume -> bit-identical artifact,
and divergence -> init-method fallback ladder (docs/quantization.md).

Five deterministic races on a tiny dense teacher, each driven by a
``quant.faults.QuantFaultPlan`` (the quant-side sibling of the serving
chaos bench):

- ``baseline``    — uninterrupted journaled run; records the artifact's
                    leaf crc32s + report every other race compares to.
- ``kill_resume`` — injected crash when block 1 starts; ``resume=True``
                    must skip block 0 and produce a bit-identical
                    artifact (leaf crc32s + report, wall_s excluded).
- ``orphan_ckpt`` — crash *between* a block's checkpoint save and its
                    journal append (the torn window); resume must redo
                    the orphan block, still bit-identical.
- ``fallback``    — NaN injected into block 0 / linear 0's init
                    latents; the run must fall back down the init
                    ladder, record the switch in the report AND the
                    journal, and the final artifact must save / load /
                    generate with finite evaluation.
- ``journal_guard`` — a journal entry is corrupted in place (valid
                    JSON, wrong crc32) then the run is killed; resume
                    must *refuse* with a :class:`JournalError` naming
                    the bad block instead of loading poison.

All five are hard asserts; the emitted ``BENCH_quant_chaos[_smoke]``
artifact carries ``races_passed`` for the regression envelope.

    PYTHONPATH=src python -m benchmarks.quant_chaos [--smoke]
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile

import jax
import numpy as np

from benchmarks.common import (check_regression, emit, load_baseline,
                               baseline_metrics)
from repro import api
from repro.checkpoint.journal import JournalError, _crc_leaves
from repro.data import SyntheticCorpus, calib_batches
from repro.models import transformer as T
from repro.models.config import ModelConfig

CHAOS_CFG = ModelConfig(name="chaos-tiny", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab_size=256, loss_chunk=0, remat=False)


def _setup(smoke: bool):
    params = T.init_params(jax.random.PRNGKey(0), CHAOS_CFG)
    seq = 32 if smoke else 48
    calib = calib_batches(CHAOS_CFG, 8, seq, batch=4,
                          corpus=SyntheticCorpus(CHAOS_CFG.vocab_size))
    qcfg = api.QuantConfig(
        target_bpw=1.0, rank_align=32, min_dim=32,
        admm_iters=6 if smoke else 10, t_pre=2 if smoke else 4,
        t_post=4 if smoke else 6, t_glob=2 if smoke else 4)
    return params, calib, qcfg


def _quantize(params, calib, qcfg, journal_dir=None, resume=False,
              faults=None):
    return api.nanoquant_quantize(params, CHAOS_CFG, calib, qcfg,
                                  verbose=False, journal_dir=journal_dir,
                                  resume=resume, faults=faults)


def _identity(report):
    """The comparable run identity: everything except wall time."""
    return json.dumps({k: v for k, v in report.items() if k != "wall_s"},
                      sort_keys=True, default=str)


def run(smoke: bool = False) -> int:
    params, calib, qcfg = _setup(smoke)
    rows = []

    def race(name, ok, detail=""):
        rows.append({"race": name, "ok": bool(ok), "detail": detail})
        print(f"[quant_chaos] {name}: {'OK' if ok else 'FAIL'} {detail}",
              flush=True)
        assert ok, f"quant_chaos race {name!r} failed: {detail}"

    work = tempfile.mkdtemp(prefix="quant_chaos_")
    try:
        # ---- baseline: uninterrupted journaled run -------------------------
        qp0, rep0 = _quantize(params, calib, qcfg,
                              journal_dir=f"{work}/j0")
        crc0, id0 = _crc_leaves(qp0), _identity(rep0)
        race("baseline", True, f"leaf_crc={crc0:#010x}")

        # ---- kill at block 1, resume, compare bit-for-bit ------------------
        plan = api.QuantFaultPlan(
            [api.QuantFault(block=1, kind="crash_block")])
        try:
            _quantize(params, calib, qcfg, journal_dir=f"{work}/j1",
                      faults=plan)
            race("kill_resume", False, "injected crash never fired")
        except api.InjectedPipelineCrash:
            qp1, rep1 = _quantize(params, calib, qcfg,
                                  journal_dir=f"{work}/j1", resume=True)
            race("kill_resume",
                 _crc_leaves(qp1) == crc0 and _identity(rep1) == id0,
                 "resumed artifact bit-identical to uninterrupted run")

        # ---- crash in the orphan-checkpoint window -------------------------
        plan = api.QuantFaultPlan(
            [api.QuantFault(block=1, kind="crash_after_save")])
        try:
            _quantize(params, calib, qcfg, journal_dir=f"{work}/j2",
                      faults=plan)
            race("orphan_ckpt", False, "injected crash never fired")
        except api.InjectedPipelineCrash:
            qp2, rep2 = _quantize(params, calib, qcfg,
                                  journal_dir=f"{work}/j2", resume=True)
            race("orphan_ckpt",
                 _crc_leaves(qp2) == crc0 and _identity(rep2) == id0,
                 "orphan block redone, artifact bit-identical")

        # ---- NaN init -> fallback ladder ----------------------------------
        plan = api.QuantFaultPlan(
            [api.QuantFault(block=0, kind="nan_init", linear=0,
                            iteration=3)])
        qp3, rep3 = _quantize(params, calib, qcfg,
                              journal_dir=f"{work}/j3", faults=plan)
        row0 = rep3["blocks"][0]
        with open(f"{work}/j3/journal.jsonl") as f:
            jrows = [json.loads(l)["payload"] for l in f if l.strip()]
        jrow0 = next(p["row"] for p in jrows if p.get("kind") == "block"
                     and p["bi"] == 0)
        ladder_ok = (row0["init_method"] != qcfg.init_method
                     and row0["fallbacks"]
                     and row0["fallbacks"][0]["method"] == qcfg.init_method
                     and jrow0["init_method"] == row0["init_method"]
                     and jrow0["fallbacks"] == row0["fallbacks"])
        model = api.NanoQuantModel(qp3, CHAOS_CFG, qcfg, rep3)
        model.save(f"{work}/artifact")
        loaded = api.NanoQuantModel.load(f"{work}/artifact")
        outs = loaded.generate(
            [np.arange(8, dtype=np.int32)], max_new_tokens=4)
        ppl = loaded.perplexity(calib)
        race("fallback",
             ladder_ok and len(outs[0]) > 0 and np.isfinite(ppl),
             f"ladder {qcfg.init_method}->{row0['init_method']}, "
             f"loaded ppl={ppl:.2f}")

        # ---- corrupted journal entry must refuse resume --------------------
        plan = api.QuantFaultPlan(
            [api.QuantFault(block=0, kind="corrupt_journal"),
             api.QuantFault(block=1, kind="crash_block")])
        try:
            _quantize(params, calib, qcfg, journal_dir=f"{work}/j4",
                      faults=plan)
            race("journal_guard", False, "injected crash never fired")
        except api.InjectedPipelineCrash:
            try:
                _quantize(params, calib, qcfg, journal_dir=f"{work}/j4",
                          resume=True)
                race("journal_guard", False,
                     "resume accepted a corrupt journal")
            except JournalError as e:
                race("journal_guard",
                     e.block == "layers[0]" or "layers[0]" in str(e),
                     f"refused, naming block: {e}")
    finally:
        shutil.rmtree(work, ignore_errors=True)

    table = "BENCH_quant_chaos" + ("_smoke" if smoke else "")
    metrics = {"races_passed": float(sum(r["ok"] for r in rows))}
    base = baseline_metrics(
        load_baseline(table),
        lambda rs: {"races_passed": float(sum(r["ok"] for r in rs))},
        "quant_chaos")
    emit(table, rows, meta={"smoke": smoke, "cfg": CHAOS_CFG.name,
                            "metrics": metrics})
    check_regression(base, metrics, rel_tol=0.0, label="quant_chaos")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller pipeline budgets; writes the _smoke "
                         "artifact, never the full baseline")
    args = ap.parse_args()
    return run(smoke=args.smoke)


if __name__ == "__main__":
    sys.exit(main())
