"""Paper Table 5: initialization ablation — LB-ADMM vs Dual-SVID
(LittleBit) vs DBF-ADMM, inside the same reconstruction pipeline."""
from __future__ import annotations

from benchmarks.common import calib, emit, eval_ppl, teacher
from repro import api


def run():
    cfg, params, _ = teacher()
    cal = calib(cfg)
    rows = []
    for method in api.list_init_methods():
        model = api.NanoQuantModel.quantize(
            params, cfg, cal,
            api.QuantConfig(target_bpw=0.8, init_method=method,
                            admm_iters=20, t_pre=6, t_post=10, t_glob=6,
                            rank_align=32, min_dim=32), verbose=False)
        rows.append({"init": method, "ppl": eval_ppl(cfg, model.params)})
    emit("table5_init", rows)
    return rows


if __name__ == "__main__":
    run()
