"""Paper Fig. 9: ADMM outer-iteration count and penalty scheduling
ablation — reconstruction error of the *binarized* factorization."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.admm import ADMMConfig, lb_admm
from repro.core.balance import magnitude_balance, reconstruct


def _recon_err(w, cfg_admm):
    res = lb_admm(w, cfg_admm)
    m, n = w.shape
    lu, lv, s1, s2 = magnitude_balance(res["p_u"], res["p_v"],
                                       jnp.ones((m,)), jnp.ones((n,)))
    return float(jnp.linalg.norm(w - reconstruct(lu, lv, s1, s2))
                 / jnp.linalg.norm(w))


def run():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 384))
    rows = []
    # (a) outer iterations
    for iters in (5, 10, 20, 40, 80):
        err = _recon_err(w, ADMMConfig(rank=96, iters=iters))
        rows.append({"ablation": "iters", "value": iters,
                     "recon_err": err})
    # (b) penalty schedule: linear ramp vs aggressive constant
    for name, (r0, rf) in (("linear_ramp", (0.01, 1.0)),
                           ("constant_low", (0.2, 0.2)),
                           ("constant_high", (1.0, 1.0)),
                           ("aggressive_ramp", (0.5, 4.0))):
        err = _recon_err(w, ADMMConfig(rank=96, iters=40, rho_init=r0,
                                       rho_final=rf))
        rows.append({"ablation": f"schedule:{name}", "value": rf,
                     "recon_err": err})
    emit("fig9_admm", rows)
    return rows


if __name__ == "__main__":
    run()
