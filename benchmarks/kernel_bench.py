"""Paper Figs. 4/5/7 + App. E: decode throughput / memory.

Two sections:

- :func:`run` — the bandwidth-roofline model the figures measure in
  practice (batch-1 decode is weight-streaming-bound): tokens/s <=
  HBM_bw / bytes-moved-per-token, for BF16 vs NanoQuant-packed weights,
  per assigned arch. Exact at published dims, no hardware needed.
- :func:`run_wallclock` — *measured* wall-clock for the kernel chain
  ``y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ`` across decode/prefill shapes,
  racing the legacy two-call execution (two kernel launches, rank-r
  intermediate materialized between them) against the fused single-pass
  kernel and the merged multi-projection launch. On TPU this times the
  Pallas kernels (the HBM round trip is real); on CPU it times the
  jitted reference oracles with a forced intermediate materialization —
  i.e. it measures the dispatch + intermediate-materialization overhead
  the fusion removes, not HBM bandwidth. Emits
  ``BENCH_kernel_wallclock.json``; registered in benchmarks/run.py as
  ``kernel_wallclock`` and wired into ``scripts/verify.sh --smoke``.

``--sweep`` times the fused kernel across block-size candidates per
shape class and writes ``kernel_block_table.json`` in the row format
``repro.kernels.tuning.load_block_table`` parses (meaningful on a real
TPU; on CPU it sweeps the interpreter and is only a wiring check).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import api
from repro.api import packed_model_bytes
from repro.kernels import binary_matmul, ref
from repro.kernels.tuning import fit_block_sizes
from repro.roofline.analysis import V5E


# ===========================================================================
# roofline section (exact, modeled)
# ===========================================================================


def _weight_stream_bytes(cfg, packed: bool):
    """Bytes of weights touched per decoded token (whole model, batch 1)."""
    rep = packed_model_bytes(cfg, 1.0)
    if packed:
        return rep["quantized_gb"] * 1e9
    return rep["fp16_total_gb"] * 1e9


def run():
    rows = []
    for arch in api.list_archs():
        cfg = api.get_config(arch)
        b_fp = _weight_stream_bytes(cfg, packed=False)
        b_q = _weight_stream_bytes(cfg, packed=True)
        tps_fp = V5E.hbm_bw / b_fp
        tps_q = V5E.hbm_bw / b_q
        rows.append({
            "arch": arch,
            "fp16_gb": b_fp / 1e9,
            "packed_gb": b_q / 1e9,
            "decode_tok_s_fp16(1chip)": tps_fp,
            "decode_tok_s_packed(1chip)": tps_q,
            "speedup_x": tps_q / tps_fp,
            "fits_8gb": b_q <= 8e9,
        })
    emit("kernel_bench", rows)
    return rows


# ===========================================================================
# measured wall-clock section
# ===========================================================================


def _mk_operands(m, k, n, r, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, ku, kv, k1, k2 = jax.random.split(key, 5)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    u = jnp.sign(jax.random.normal(ku, (n, r)))
    v = jnp.sign(jax.random.normal(kv, (k, r)))
    qv = ref.pack_signs(jnp.where(v == 0, 1.0, v))
    qu_t = ref.pack_signs(jnp.where(u == 0, 1.0, u).T)
    s1 = jnp.abs(jax.random.normal(k1, (n,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (k,))) + 0.1
    return x, qv, qu_t, s1, s2


def _time_ms(fn, *args, iters=50, warmup=5):
    """Min-of-iters wall clock (robust against scheduler noise on a
    shared CPU box; on TPU the distribution is tight anyway)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3


def _race_ms(fns, x, samples=24, calls=16, warmup=3):
    """Interleaved timing of competing variants: alternate variants
    sample-by-sample (so scheduler noise lands on all of them equally)
    and amortize per-call sync jitter over `calls` back-to-back calls
    per sample. Returns per-variant min sample time / calls, in ms."""
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn(x))
    best = [float("inf")] * len(fns)
    for _ in range(samples):
        for vi, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(calls):
                out = fn(x)
            jax.block_until_ready(out)
            best[vi] = min(best[vi], time.perf_counter() - t0)
    return [b / calls * 1e3 for b in best]


def _variants(x, qv, qu_t, s1, s2, on_tpu):
    """(two_call, fused) callables for the measured backend.

    On TPU both variants are single jits of the shipped kernel paths —
    the two-call baseline is exactly ``lowrank_binary_matmul_twocall``
    (two pallas_calls, rank intermediate through HBM). On CPU the XLA
    backend would fuse the two jnp reference stages into one program,
    erasing the boundary being measured, so the two-call stand-in runs
    the stages as separate jits with the intermediate materialized
    between them (modeling the sequential-kernel boundary; stated in
    the emitted rows via the backend field)."""
    m, k = x.shape
    n, r = qu_t.shape[1], qv.shape[1]
    if on_tpu:
        bm, bn, bk = fit_block_sizes(m, k, n, r, x.dtype)
        fused = jax.jit(lambda xx: binary_matmul.fused_lowrank_matmul(
            xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk))
        two_call = jax.jit(
            lambda xx: binary_matmul.lowrank_binary_matmul_twocall(
                xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk))
        return two_call, fused

    stage1 = jax.jit(lambda xx: ref.packed_matmul_ref(xx, qv, s_k=s2))
    stage2 = jax.jit(lambda t: ref.packed_matmul_ref(t, qu_t, s_n=s1))
    fused = jax.jit(lambda xx: ref.lowrank_binary_matmul_fused_ref(
        xx, qv, qu_t, s1, s2))

    def two_call(xx):
        t = stage1(xx)
        jax.block_until_ready(t)               # materialized intermediate
        return stage2(t)

    return two_call, fused


def _merged_variants(x, projs, on_tpu):
    """(separate, merged) callables for P projections sharing x."""
    from repro.quant.surgery import _stack_group
    mp = _stack_group([{"qv": qv, "qu_t": qu, "s1": s1, "s2": s2}
                       for (qv, qu, s1, s2) in projs])
    dims = tuple(int(qu.shape[1]) for (_, qu, _, _) in projs)
    if on_tpu:
        m, k = x.shape
        R, n_max = mp["qv"].shape[-1], mp["qu_t"].shape[-1]
        bm, bn, bk = fit_block_sizes(m, k, n_max, R, x.dtype)
        sep = [jax.jit(lambda xx, a=a: binary_matmul.fused_lowrank_matmul(
            xx, a[0], a[1], a[2], a[3], bm=bm, bn=bn, bk=bk))
            for a in projs]
        merged = jax.jit(lambda xx: binary_matmul.fused_lowrank_matmul_grouped(
            xx[None], mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], mp["rmask"],
            x_shared=True, bm=bm, bn=bn, bk=bk))
    else:
        sep = [jax.jit(lambda xx, a=a: ref.lowrank_binary_matmul_fused_ref(
            xx, a[0], a[1], a[2], a[3])) for a in projs]
        merged = jax.jit(lambda xx: jax.vmap(
            lambda qv, qu, s1, s2, rm: ref.lowrank_binary_matmul_fused_ref(
                xx, qv, qu, s1, s2, rm))(
            mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], mp["rmask"]))

    def separate(xx):
        return [f(xx) for f in sep]

    return separate, merged, dims


def run_wallclock(smoke: bool = False):
    """Measured two-call vs fused vs merged across decode/prefill shapes;
    emits BENCH_kernel_wallclock.json."""
    on_tpu = jax.default_backend() == "tpu"
    backend = jax.default_backend()
    if smoke:
        shapes = [("decode", 1, 512, 512, 128), ("decode", 8, 512, 512, 128),
                  ("prefill", 128, 512, 512, 128)]
    else:
        shapes = [("decode", 1, 512, 512, 128), ("decode", 8, 512, 512, 128),
                  ("decode", 8, 1024, 1024, 256),
                  ("decode", 8, 2816, 1024, 256),   # K misaligned to bk=512
                  ("prefill", 256, 1024, 1024, 256)]
    samples = 24 if smoke else 48
    rows = []
    for section, m, k, n, r in shapes:
        x, qv, qu_t, s1, s2 = _mk_operands(m, k, n, r)
        two_call, fused = _variants(x, qv, qu_t, s1, s2, on_tpu)
        t2, tf = _race_ms([two_call, fused], x, samples=samples)
        rows.append({
            "section": section, "M": m, "K": k, "N": n, "r": r,
            "backend": backend,
            "two_call_ms": t2, "fused_ms": tf,
            "fused_speedup_x": t2 / tf,
        })
    # merged multi-projection (QKV-shaped: one wide + two narrow)
    k = 512 if smoke else 1024
    x = jax.random.normal(jax.random.PRNGKey(7), (8, k))
    projs = [_mk_operands(8, k, n_i, r_i, seed=i)[1:]
             for i, (n_i, r_i) in enumerate(
                 [(k, k // 4), (k // 4, k // 8), (k // 4, k // 8)])]
    separate, merged, dims = _merged_variants(x, projs, on_tpu)
    ts, tm = _race_ms([separate, merged], x, samples=samples)
    rows.append({
        "section": "merged_qkv", "M": 8, "K": k,
        "N": "+".join(str(d) for d in dims), "r": "ragged",
        "backend": backend,
        "two_call_ms": ts, "fused_ms": tm,
        "fused_speedup_x": ts / tm,
    })
    emit("BENCH_kernel_wallclock", rows)
    decode = [r for r in rows if r["section"] == "decode"]
    worst = min(r["fused_speedup_x"] for r in decode)
    print(f"[kernel_wallclock] worst decode fused speedup: {worst:.2f}x "
          f"(backend={backend})")
    return rows


# ===========================================================================
# offline block-size sweep -> kernel_block_table.json
# ===========================================================================

_SWEEP_CANDS = [(8, 128, 128), (8, 256, 256), (8, 512, 512),
                (64, 128, 256), (128, 128, 512), (128, 256, 512)]


def run_sweep(smoke: bool = True):
    """Time the fused kernel across block-size candidates per shape
    class; emit the best rows as a loadable block table
    (kernels.tuning.load_block_table -> KernelPolicy(block_table=...)).
    On CPU the kernel runs in interpreter mode — use this on TPU for
    real numbers."""
    interp = jax.default_backend() != "tpu"
    shapes = ([(8, 256, 256, 64), (64, 256, 256, 64)] if smoke
              else [(1, 2048, 2048, 512), (8, 2048, 2048, 512),
                    (256, 2048, 2048, 512)])
    rows = []
    for m, k, n, r in shapes:
        x, qv, qu_t, s1, s2 = _mk_operands(m, k, n, r)
        best = None
        for bm, bn, bk in _SWEEP_CANDS:
            fn = jax.jit(lambda xx, bm=bm, bn=bn, bk=bk:
                         binary_matmul.fused_lowrank_matmul(
                             xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk,
                             interpret=interp))
            ms = _time_ms(fn, x, iters=3 if interp else 30,
                          warmup=1 if interp else 5)
            if best is None or ms < best[0]:
                best = (ms, bm, bn, bk)
        ms, bm, bn, bk = best
        rows.append({"m_hi": m, "k_hi": k, "n_hi": n, "r_hi": r,
                     "bm": bm, "bn": bn, "bk": bk, "best_ms": ms,
                     "interpreted": interp})
    emit("kernel_block_table", rows)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast wall-clock microbench (the verify.sh gate)")
    ap.add_argument("--sweep", action="store_true",
                    help="block-size sweep -> kernel_block_table.json")
    ap.add_argument("--roofline", action="store_true",
                    help="modeled roofline section only")
    args = ap.parse_args()
    if args.sweep:
        run_sweep(smoke=args.smoke or jax.default_backend() != "tpu")
        return 0
    if args.roofline:
        run()
        return 0
    rows = run_wallclock(smoke=args.smoke)
    if not args.smoke:
        run()

    def gate_ok(rs):
        return all(r["fused_speedup_x"] >= 1.0 for r in rs
                   if r["section"] == "decode")

    if not gate_ok(rows):
        # wall clock on a shared box is noisy; a regression must
        # reproduce on a second measurement before failing the gate
        print("[kernel_wallclock] decode speedup < 1.0x — re-measuring")
        rows = run_wallclock(smoke=args.smoke)
    return 0 if gate_ok(rows) else 1


if __name__ == "__main__":
    sys.exit(main())
