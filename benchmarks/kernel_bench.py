"""Paper Figs. 4/5/7 + App. E: decode throughput / memory. No TPU on
this box, so wall-clock MFU is out of reach — we report the
bandwidth-roofline model the figures measure in practice (batch-1 decode
is weight-streaming-bound): tokens/s <= HBM_bw / bytes-moved-per-token,
for BF16 vs NanoQuant-packed weights, per assigned arch. The Pallas
kernel itself is validated bit-exactly in tests/test_kernels.py."""
from __future__ import annotations

from benchmarks.common import emit
from repro import api
from repro.configs.shapes import param_specs
from repro.api import packed_model_bytes, quantizable_paths
from repro.roofline.analysis import V5E


def _weight_stream_bytes(cfg, packed: bool):
    """Bytes of weights touched per decoded token (whole model, batch 1)."""
    rep = packed_model_bytes(cfg, 1.0)
    if packed:
        return rep["quantized_gb"] * 1e9
    return rep["fp16_total_gb"] * 1e9


def run():
    rows = []
    for arch in api.list_archs():
        cfg = api.get_config(arch)
        b_fp = _weight_stream_bytes(cfg, packed=False)
        b_q = _weight_stream_bytes(cfg, packed=True)
        tps_fp = V5E.hbm_bw / b_fp
        tps_q = V5E.hbm_bw / b_q
        rows.append({
            "arch": arch,
            "fp16_gb": b_fp / 1e9,
            "packed_gb": b_q / 1e9,
            "decode_tok_s_fp16(1chip)": tps_fp,
            "decode_tok_s_packed(1chip)": tps_q,
            "speedup_x": tps_q / tps_fp,
            "fits_8gb": b_q <= 8e9,
        })
    emit("kernel_bench", rows)
    return rows


if __name__ == "__main__":
    run()
