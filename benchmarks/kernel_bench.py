"""Paper Figs. 4/5/7 + App. E: decode throughput / memory.

Two sections:

- :func:`run` — the bandwidth-roofline model the figures measure in
  practice (batch-1 decode is weight-streaming-bound): tokens/s <=
  HBM_bw / bytes-moved-per-token, for BF16 vs NanoQuant-packed weights,
  per assigned arch. Exact at published dims, no hardware needed.
- :func:`run_wallclock` — *measured* wall-clock for the kernel chain
  ``y = s1 ⊙ ((x ⊙ s2) @ V±1) @ U±1ᵀ`` across decode/prefill shapes,
  racing the legacy two-call execution (two kernel launches, rank-r
  intermediate materialized between them) against the fused single-pass
  kernel and the merged multi-projection launch. On TPU this times the
  Pallas kernels (the HBM round trip is real); on CPU it times the
  jitted reference oracles with a forced intermediate materialization —
  i.e. it measures the dispatch + intermediate-materialization overhead
  the fusion removes, not HBM bandwidth. Emits
  ``BENCH_kernel_wallclock.json``; registered in benchmarks/run.py as
  ``kernel_wallclock`` and wired into ``scripts/verify.sh --smoke``.

``--sweep`` times the fused matmul kernel across block-size candidates
AND the paged gather-attention kernel across (pages_per_step,
head_block) candidates per shape class, and writes
``kernel_block_table.json`` in the format
``repro.kernels.tuning.load_block_table`` / ``load_paged_table`` parse.
Adding ``--commit-table`` writes the committed ``{"meta", "matmul",
"paged"}`` envelope instead of the legacy bare list. On a real TPU the
committed rows are the measured winners; on CPU the kernels run in
interpreter mode, whose timings are meaningless AND noisy, so the
committed picks are the deterministic heuristic-table choices (operand
generation is seeded either way) — byte-stable output across runs, and
the measured ``best_ms`` stays in the row for provenance.

``run_wallclock`` ends with a regression gate
(:func:`benchmarks.common.check_regression`): each (section, M, K, N)
row's ``fused_speedup_x`` must stay within 10% of the checked-in
``BENCH_kernel_wallclock.json`` row (read before the run overwrites
it; rows with no baseline match are skipped).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro import api
from repro.api import packed_model_bytes
from repro.kernels import binary_matmul, ref
from repro.kernels.tuning import (fit_block_sizes, fit_paged_block_sizes,
                                  lookup_block_table)
from repro.roofline.analysis import V5E


# ===========================================================================
# roofline section (exact, modeled)
# ===========================================================================


def _weight_stream_bytes(cfg, packed: bool):
    """Bytes of weights touched per decoded token (whole model, batch 1)."""
    rep = packed_model_bytes(cfg, 1.0)
    if packed:
        return rep["quantized_gb"] * 1e9
    return rep["fp16_total_gb"] * 1e9


def run():
    rows = []
    for arch in api.list_archs():
        cfg = api.get_config(arch)
        b_fp = _weight_stream_bytes(cfg, packed=False)
        b_q = _weight_stream_bytes(cfg, packed=True)
        tps_fp = V5E.hbm_bw / b_fp
        tps_q = V5E.hbm_bw / b_q
        rows.append({
            "arch": arch,
            "fp16_gb": b_fp / 1e9,
            "packed_gb": b_q / 1e9,
            "decode_tok_s_fp16(1chip)": tps_fp,
            "decode_tok_s_packed(1chip)": tps_q,
            "speedup_x": tps_q / tps_fp,
            "fits_8gb": b_q <= 8e9,
        })
    emit("kernel_bench", rows)
    return rows


# ===========================================================================
# measured wall-clock section
# ===========================================================================


def _mk_operands(m, k, n, r, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, ku, kv, k1, k2 = jax.random.split(key, 5)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    u = jnp.sign(jax.random.normal(ku, (n, r)))
    v = jnp.sign(jax.random.normal(kv, (k, r)))
    qv = ref.pack_signs(jnp.where(v == 0, 1.0, v))
    qu_t = ref.pack_signs(jnp.where(u == 0, 1.0, u).T)
    s1 = jnp.abs(jax.random.normal(k1, (n,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (k,))) + 0.1
    return x, qv, qu_t, s1, s2


def _time_ms(fn, *args, iters=50, warmup=5):
    """Min-of-iters wall clock (robust against scheduler noise on a
    shared CPU box; on TPU the distribution is tight anyway)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e3


def _race_ms(fns, x, samples=24, calls=16, warmup=3):
    """Interleaved timing of competing variants: alternate variants
    sample-by-sample (so scheduler noise lands on all of them equally)
    and amortize per-call sync jitter over `calls` back-to-back calls
    per sample. Returns per-variant min sample time / calls, in ms."""
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn(x))
    best = [float("inf")] * len(fns)
    for _ in range(samples):
        for vi, fn in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(calls):
                out = fn(x)
            jax.block_until_ready(out)
            best[vi] = min(best[vi], time.perf_counter() - t0)
    return [b / calls * 1e3 for b in best]


def _variants(x, qv, qu_t, s1, s2, on_tpu):
    """(two_call, fused) callables for the measured backend.

    On TPU both variants are single jits of the shipped kernel paths —
    the two-call baseline is exactly ``lowrank_binary_matmul_twocall``
    (two pallas_calls, rank intermediate through HBM). On CPU the XLA
    backend would fuse the two jnp reference stages into one program,
    erasing the boundary being measured, so the two-call stand-in runs
    the stages as separate jits with the intermediate materialized
    between them (modeling the sequential-kernel boundary; stated in
    the emitted rows via the backend field)."""
    m, k = x.shape
    n, r = qu_t.shape[1], qv.shape[1]
    if on_tpu:
        bm, bn, bk = fit_block_sizes(m, k, n, r, x.dtype)
        fused = jax.jit(lambda xx: binary_matmul.fused_lowrank_matmul(
            xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk))
        two_call = jax.jit(
            lambda xx: binary_matmul.lowrank_binary_matmul_twocall(
                xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk))
        return two_call, fused

    stage1 = jax.jit(lambda xx: ref.packed_matmul_ref(xx, qv, s_k=s2))
    stage2 = jax.jit(lambda t: ref.packed_matmul_ref(t, qu_t, s_n=s1))
    fused = jax.jit(lambda xx: ref.lowrank_binary_matmul_fused_ref(
        xx, qv, qu_t, s1, s2))

    def two_call(xx):
        t = stage1(xx)
        jax.block_until_ready(t)               # materialized intermediate
        return stage2(t)

    return two_call, fused


def _merged_variants(x, projs, on_tpu):
    """(separate, merged) callables for P projections sharing x."""
    from repro.quant.surgery import _stack_group
    mp = _stack_group([{"qv": qv, "qu_t": qu, "s1": s1, "s2": s2}
                       for (qv, qu, s1, s2) in projs])
    dims = tuple(int(qu.shape[1]) for (_, qu, _, _) in projs)
    if on_tpu:
        m, k = x.shape
        R, n_max = mp["qv"].shape[-1], mp["qu_t"].shape[-1]
        bm, bn, bk = fit_block_sizes(m, k, n_max, R, x.dtype)
        sep = [jax.jit(lambda xx, a=a: binary_matmul.fused_lowrank_matmul(
            xx, a[0], a[1], a[2], a[3], bm=bm, bn=bn, bk=bk))
            for a in projs]
        merged = jax.jit(lambda xx: binary_matmul.fused_lowrank_matmul_grouped(
            xx[None], mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], mp["rmask"],
            x_shared=True, bm=bm, bn=bn, bk=bk))
    else:
        sep = [jax.jit(lambda xx, a=a: ref.lowrank_binary_matmul_fused_ref(
            xx, a[0], a[1], a[2], a[3])) for a in projs]
        merged = jax.jit(lambda xx: jax.vmap(
            lambda qv, qu, s1, s2, rm: ref.lowrank_binary_matmul_fused_ref(
                xx, qv, qu, s1, s2, rm))(
            mp["qv"], mp["qu_t"], mp["s1"], mp["s2"], mp["rmask"]))

    def separate(xx):
        return [f(xx) for f in sep]

    return separate, merged, dims


def run_wallclock(smoke: bool = False, _base=None, _retry: bool = True):
    """Measured two-call vs fused vs merged across decode/prefill shapes;
    emits BENCH_kernel_wallclock.json and gates each row's
    fused_speedup_x within 10% of the checked-in baseline (one internal
    re-measure before failing: wall clock on a shared box is noisy)."""
    on_tpu = jax.default_backend() == "tpu"
    backend = jax.default_backend()
    if smoke:
        shapes = [("decode", 1, 512, 512, 128), ("decode", 8, 512, 512, 128),
                  ("prefill", 128, 512, 512, 128)]
    else:
        shapes = [("decode", 1, 512, 512, 128), ("decode", 8, 512, 512, 128),
                  ("decode", 8, 1024, 1024, 256),
                  ("decode", 8, 2816, 1024, 256),   # K misaligned to bk=512
                  ("prefill", 256, 1024, 1024, 256)]
    samples = 24 if smoke else 48
    rows = []
    for section, m, k, n, r in shapes:
        x, qv, qu_t, s1, s2 = _mk_operands(m, k, n, r)
        two_call, fused = _variants(x, qv, qu_t, s1, s2, on_tpu)
        t2, tf = _race_ms([two_call, fused], x, samples=samples)
        rows.append({
            "section": section, "M": m, "K": k, "N": n, "r": r,
            "backend": backend,
            "two_call_ms": t2, "fused_ms": tf,
            "fused_speedup_x": t2 / tf,
        })
    # merged multi-projection (QKV-shaped: one wide + two narrow)
    k = 512 if smoke else 1024
    x = jax.random.normal(jax.random.PRNGKey(7), (8, k))
    projs = [_mk_operands(8, k, n_i, r_i, seed=i)[1:]
             for i, (n_i, r_i) in enumerate(
                 [(k, k // 4), (k // 4, k // 8), (k // 4, k // 8)])]
    separate, merged, dims = _merged_variants(x, projs, on_tpu)
    ts, tm = _race_ms([separate, merged], x, samples=samples)
    rows.append({
        "section": "merged_qkv", "M": 8, "K": k,
        "N": "+".join(str(d) for d in dims), "r": "ragged",
        "backend": backend,
        "two_call_ms": ts, "fused_ms": tm,
        "fused_speedup_x": ts / tm,
    })
    if _base is None:
        # read BEFORE emit overwrites the artifact; () = "no baseline",
        # threaded through the retry so the re-measure does not gate
        # against its own first emit
        _base = common.load_baseline("BENCH_kernel_wallclock") or ()
    emit("BENCH_kernel_wallclock", rows)
    decode = [r for r in rows if r["section"] == "decode"]
    worst = min(r["fused_speedup_x"] for r in decode)
    print(f"[kernel_wallclock] worst decode fused speedup: {worst:.2f}x "
          f"(backend={backend})")

    def keyed(rs):
        return {f"{r['section']}:M{r['M']}:K{r['K']}:N{r['N']}":
                r["fused_speedup_x"] for r in rs}

    cur = keyed(rows)
    # only rows both runs measured: --smoke and the full run sweep
    # different shape sets, and a shape is not a regression of a
    # different shape
    base = ({k: v for k, v in keyed(_base).items() if k in cur}
            if _base else None)
    try:
        common.check_regression(base, cur, rel_tol=0.10,
                                label="kernel_wallclock")
    except RuntimeError:
        if not _retry:
            raise
        print("[kernel_wallclock] speedup regression — re-measuring "
              "(wall clock noise on a shared box)")
        return run_wallclock(smoke=smoke, _base=_base, _retry=False)
    return rows


# ===========================================================================
# offline block-size sweep -> kernel_block_table.json
# ===========================================================================

_SWEEP_CANDS = [(8, 128, 128), (8, 256, 256), (8, 512, 512),
                (64, 128, 256), (128, 128, 512), (128, 256, 512)]

# (pages_per_step, head_block) candidates for the paged gather kernel;
# head_block candidates not dividing a shape's Hkv are skipped.
_PAGED_CANDS = [(1, 0), (2, 0), (4, 0), (8, 0), (4, 2), (4, 4), (8, 4)]


def _sweep_paged(smoke: bool, interp: bool, seed: int = 3):
    """Time the paged gather-attention kernel across (pages_per_step,
    head_block) candidates per (B, Hkv, D, pages) shape class; rows in
    the ``tuning.load_paged_table`` format. On an interpreted backend
    the committed knobs are the deterministic heuristic picks (timing
    the interpreter is noise); ``best_ms`` keeps the measured winner
    for provenance either way."""
    from repro.kernels.paged_attention import paged_decode_attention
    shapes = ([(4, 2, 16, 4), (8, 2, 16, 8)] if smoke
              else [(8, 8, 128, 16), (32, 8, 128, 64)])
    rows = []
    for B, Hkv, D, pages in shapes:
        G = 2
        NP, PS = B * pages + 1, 8
        key = jax.random.PRNGKey(seed + B + pages)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (B, 1, Hkv * G, D), jnp.float32)
        kp = jax.random.normal(kk, (NP, PS, Hkv, D), jnp.float32)
        vp = jax.random.normal(kv, (NP, PS, Hkv, D), jnp.float32)
        bt = jnp.arange(1, 1 + B * pages, dtype=jnp.int32).reshape(B, pages)
        qpos = jnp.full((B,), pages * PS - 2, jnp.int32)
        best = None
        for ppb, hb in _PAGED_CANDS:
            if ppb > pages or (hb and Hkv % hb):
                continue
            fn = jax.jit(lambda qq, ppb=ppb, hb=hb: paged_decode_attention(
                qq, kp, vp, bt, qpos, qpos, scale=D ** -0.5,
                pages_per_step=ppb, head_block=hb, interpret=interp))
            ms = _time_ms(fn, q, iters=2 if interp else 30,
                          warmup=1 if interp else 5)
            if best is None or ms < best[0]:
                best = (ms, ppb, hb)
        ms, ppb, hb = best
        if interp:
            ppb, hb = fit_paged_block_sizes(B, Hkv, D, pages)
        rows.append({"b_hi": B, "hkv_hi": Hkv, "d_hi": D, "pages_hi": pages,
                     "pages_per_step": ppb, "head_block": hb,
                     "best_ms": ms, "interpreted": interp})
    return rows


def run_sweep(smoke: bool = True, commit: bool = False, seed: int = 0):
    """Time the fused matmul kernel across block-size candidates and the
    paged kernel across gather knobs; emit the winners as a loadable
    block table (kernels.tuning.load_block_table ->
    KernelPolicy(block_table=...), load_paged_table ->
    KernelPolicy(paged_block_table=...)).

    With ``commit``, write the ``{"meta", "matmul", "paged"}`` envelope.
    On CPU the kernels run in interpreter mode, so the committed picks
    are the deterministic heuristic-table choices (seeded operands,
    byte-stable file across runs) — use a real TPU for measured
    numbers."""
    interp = jax.default_backend() != "tpu"
    shapes = ([(8, 256, 256, 64), (64, 256, 256, 64)] if smoke
              else [(1, 2048, 2048, 512), (8, 2048, 2048, 512),
                    (256, 2048, 2048, 512)])
    rows = []
    for m, k, n, r in shapes:
        x, qv, qu_t, s1, s2 = _mk_operands(m, k, n, r, seed=seed)
        best = None
        for bm, bn, bk in _SWEEP_CANDS:
            fn = jax.jit(lambda xx, bm=bm, bn=bn, bk=bk:
                         binary_matmul.fused_lowrank_matmul(
                             xx, qv, qu_t, s1, s2, bm=bm, bn=bn, bk=bk,
                             interpret=interp))
            ms = _time_ms(fn, x, iters=3 if interp else 30,
                          warmup=1 if interp else 5)
            if best is None or ms < best[0]:
                best = (ms, bm, bn, bk)
        ms, bm, bn, bk = best
        if interp:
            bm, bn, bk = lookup_block_table(m, k, n, r)
        rows.append({"m_hi": m, "k_hi": k, "n_hi": n, "r_hi": r,
                     "bm": bm, "bn": bn, "bk": bk, "best_ms": ms,
                     "interpreted": interp})
    paged_rows = _sweep_paged(smoke, interp, seed=seed + 3)
    if commit:
        # the committed table is pure configuration: measured timings
        # vary run to run, so dropping them keeps the file byte-stable
        # (re-running --commit-table on an unchanged tree is a no-op
        # diff — the property the checked-in artifact's review relies
        # on); timings live in the non-commit emits.
        strip = lambda rs: [{k: v for k, v in r.items() if k != "best_ms"}
                            for r in rs]
        doc = {"meta": {"seed": seed, "smoke": smoke,
                        "backend": jax.default_backend(),
                        "interpreted": interp},
               "matmul": strip(rows), "paged": strip(paged_rows)}
        os.makedirs(common.OUT_DIR, exist_ok=True)
        path = os.path.join(common.OUT_DIR, "kernel_block_table.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"[kernel_bench] committed swept table -> {path} "
              f"({len(rows)} matmul + {len(paged_rows)} paged rows, "
              f"{'heuristic picks (interpreted)' if interp else 'measured'})")
    else:
        emit("kernel_block_table", rows)
        emit("kernel_paged_table", paged_rows)
    return rows, paged_rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast wall-clock microbench (the verify.sh gate)")
    ap.add_argument("--sweep", action="store_true",
                    help="block-size + paged-knob sweep -> "
                         "kernel_block_table.json")
    ap.add_argument("--commit-table", action="store_true",
                    help="with --sweep: write the committed "
                         '{"meta","matmul","paged"} envelope '
                         "(deterministic on CPU: heuristic picks)")
    ap.add_argument("--roofline", action="store_true",
                    help="modeled roofline section only")
    args = ap.parse_args()
    if args.sweep:
        run_sweep(smoke=args.smoke or jax.default_backend() != "tpu",
                  commit=args.commit_table)
        return 0
    if args.roofline:
        run()
        return 0
    rows = run_wallclock(smoke=args.smoke)
    if not args.smoke:
        run()

    def gate_ok(rs):
        return all(r["fused_speedup_x"] >= 1.0 for r in rs
                   if r["section"] == "decode")

    if not gate_ok(rows):
        # wall clock on a shared box is noisy; a regression must
        # reproduce on a second measurement before failing the gate
        print("[kernel_wallclock] decode speedup < 1.0x — re-measuring")
        rows = run_wallclock(smoke=args.smoke)
    return 0 if gate_ok(rows) else 1


if __name__ == "__main__":
    sys.exit(main())
