"""Paper Table 2 (+ Fig. 1): WikiText-2 perplexity of 1-bit / sub-1-bit
PTQ — tiny-scale reproduction on the synthetic corpus.

Expected orderings (validated): FP < NanoQuant@1.0 < @0.8 < @0.55 <<
XNOR/RTN (catastrophic, the paper's e4–e22 rows)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import calib, emit, eval_ppl, teacher
from repro import api
from repro.core.baselines import rtn_binarize, xnor_binarize

_Q = dict(lr_pre=3e-4, lr_post=1e-4, lr_glob=1e-4, admm_iters=20, t_pre=8, t_post=12, t_glob=8, rank_align=32,
          min_dim=32)


def _binarize_all(params, fn):
    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                if "w" in v and not isinstance(v["w"], dict):
                    out[k] = dict(v, w=fn(v["w"]).astype(v["w"].dtype))
                else:
                    out[k] = walk(v)
            else:
                out[k] = v
        return out
    new = dict(params)
    new["layers"] = walk(params["layers"])
    return new


def run():
    cfg, params, _ = teacher()
    cal = calib(cfg)
    rows = [{"method": "FP16", "w_bits": 16.0, "ppl": eval_ppl(cfg, params)}]
    rows.append({"method": "RTN", "w_bits": 1.0,
                 "ppl": eval_ppl(cfg, _binarize_all(params, rtn_binarize))})
    rows.append({"method": "XNOR", "w_bits": 1.0,
                 "ppl": eval_ppl(cfg, _binarize_all(params, xnor_binarize))})
    for bpw in (1.0, 0.8, 0.55):
        t0 = time.time()
        model = api.NanoQuantModel.quantize(
            params, cfg, cal, api.QuantConfig(target_bpw=bpw, **_Q),
            verbose=False)
        rows.append({"method": f"NanoQuant@{bpw}", "w_bits": bpw,
                     "ppl": eval_ppl(cfg, model.params),
                     "wall_s": time.time() - t0})
    emit("table2_perplexity", rows)
    return rows


if __name__ == "__main__":
    run()
