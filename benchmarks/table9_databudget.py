"""Paper Table 9: calibration-data budgets for block vs model
reconstruction (more block-recon data -> better PPL)."""
from __future__ import annotations

from benchmarks.common import calib, emit, eval_ppl, teacher
from repro import api


def run():
    cfg, params, _ = teacher()
    rows = []
    for block_samples in (8, 24):
        for model_samples in (8, 24):
            cal_block = calib(cfg, n_samples=block_samples)
            cal_model = calib(cfg, n_samples=model_samples, seed=77)
            qcfg = api.QuantConfig(target_bpw=1.0, lr_pre=3e-4,
                                   lr_post=1e-4, lr_glob=1e-4,
                                   admm_iters=16, t_pre=6, t_post=10,
                                   t_glob=0, rank_align=32, min_dim=32)
            qp = api.NanoQuantModel.quantize(params, cfg, cal_block, qcfg,
                                             verbose=False).params
            # model reconstruction with its own budget
            import dataclasses
            qcfg2 = dataclasses.replace(qcfg, t_glob=8)
            qp, _ = api.tune_scales_kd(params, qp, cfg, cal_model, qcfg2)
            rows.append({"block_samples": block_samples,
                         "model_samples": model_samples,
                         "ppl": eval_ppl(cfg, qp)})
    emit("table9_databudget", rows)
    return rows


if __name__ == "__main__":
    run()
