"""Shared benchmark substrate.

Paper-claim validation runs at tiny scale (CPU-only box; repro tier 4):
one FP teacher per model family is trained on the synthetic corpus and
reused across tables. Full-scale numbers that are *exact* (storage
formulas, roofline-modeled throughput) are computed at the published
dims.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List

import jax

from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.data.synthetic import eval_perplexity
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import TrainConfig, Trainer

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# d_model 256 so the three BPW targets (1.0 / 0.8 / 0.55) resolve to
# distinct ranks (96 / 64 / 32) instead of all clamping to r_min.
TINY = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                   d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                   vocab_size=256, loss_chunk=0, remat=False)

CALIB_SEQ = 64


@functools.lru_cache(maxsize=4)
def teacher(steps: int = 300, cfg: ModelConfig = TINY):
    """Train (once per process) a small FP teacher to sub-uniform PPL."""
    tcfg = TrainConfig(lr=3e-3, warmup=20, total_steps=steps)
    tr = Trainer(cfg, tcfg, train_iterator(cfg, batch=16, seq=CALIB_SEQ),
                 log_every=10**9)
    tr.restore_or_init()
    t0 = time.time()
    tr.run(steps)
    params = tr.state[0]
    return cfg, params, time.time() - t0


def calib(cfg, n_samples=16, seed=7):
    return calib_batches(cfg, n_samples, CALIB_SEQ, batch=4, seed=seed,
                         corpus=SyntheticCorpus(cfg.vocab_size))


def eval_ppl(cfg, params, seed=9999):
    evalb = calib_batches(cfg, 12, CALIB_SEQ, batch=4, seed=seed,
                          corpus=SyntheticCorpus(cfg.vocab_size))
    return eval_perplexity(T.loss_fn, params, cfg, evalb)


def emit(table: str, rows: List[Dict], keys=None, meta: Dict = None):
    """Print CSV + persist JSON. With ``meta`` (run provenance: trace
    seed, flags) the file is ``{"meta": ..., "rows": [...]}``; without,
    the legacy bare row list — existing baselines stay readable."""
    if not rows:
        return
    keys = keys or list(rows[0].keys())
    print(f"\n== {table} ==")
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r.get(k, '')}" if not isinstance(r.get(k), float)
                       else f"{r[k]:.4g}" for k in keys))
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{table}.json"), "w") as f:
        json.dump(rows if meta is None else {"meta": meta, "rows": rows},
                  f, indent=1, default=str)
