"""Shared benchmark substrate.

Paper-claim validation runs at tiny scale (CPU-only box; repro tier 4):
one FP teacher per model family is trained on the synthetic corpus and
reused across tables. Full-scale numbers that are *exact* (storage
formulas, roofline-modeled throughput) are computed at the published
dims.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List

import jax

from repro.data import SyntheticCorpus, calib_batches, train_iterator
from repro.data.synthetic import eval_perplexity
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import TrainConfig, Trainer

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# d_model 256 so the three BPW targets (1.0 / 0.8 / 0.55) resolve to
# distinct ranks (96 / 64 / 32) instead of all clamping to r_min.
TINY = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                   d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                   vocab_size=256, loss_chunk=0, remat=False)

CALIB_SEQ = 64


@functools.lru_cache(maxsize=4)
def teacher(steps: int = 300, cfg: ModelConfig = TINY):
    """Train (once per process) a small FP teacher to sub-uniform PPL."""
    tcfg = TrainConfig(lr=3e-3, warmup=20, total_steps=steps)
    tr = Trainer(cfg, tcfg, train_iterator(cfg, batch=16, seq=CALIB_SEQ),
                 log_every=10**9)
    tr.restore_or_init()
    t0 = time.time()
    tr.run(steps)
    params = tr.state[0]
    return cfg, params, time.time() - t0


def calib(cfg, n_samples=16, seed=7):
    return calib_batches(cfg, n_samples, CALIB_SEQ, batch=4, seed=seed,
                         corpus=SyntheticCorpus(cfg.vocab_size))


def eval_ppl(cfg, params, seed=9999):
    evalb = calib_batches(cfg, 12, CALIB_SEQ, batch=4, seed=seed,
                          corpus=SyntheticCorpus(cfg.vocab_size))
    return eval_perplexity(T.loss_fn, params, cfg, evalb)


def emit(table: str, rows: List[Dict], keys=None, meta: Dict = None):
    """Print CSV + persist JSON. With ``meta`` (run provenance: trace
    seed, flags) the file is ``{"meta": ..., "rows": [...]}``; without,
    the legacy bare row list — existing baselines stay readable."""
    if not rows:
        return
    keys = keys or list(rows[0].keys())
    print(f"\n== {table} ==")
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r.get(k, '')}" if not isinstance(r.get(k), float)
                       else f"{r[k]:.4g}" for k in keys))
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{table}.json"), "w") as f:
        json.dump(rows if meta is None else {"meta": meta, "rows": rows},
                  f, indent=1, default=str)


def load_baseline(table: str):
    """Rows of a checked-in benchmark artifact. Handles both the
    ``{"meta": ..., "rows": [...]}`` format and the legacy bare row
    list; returns None when the file does not exist (fresh checkout,
    custom BENCH_OUT) so callers can skip their gate with a notice
    instead of crashing."""
    path = os.path.join(OUT_DIR, f"{table}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        doc = json.load(f)
    return doc["rows"] if isinstance(doc, dict) else doc


def row_ratio(rows: List[Dict], num_engine: str, den_engine: str,
              key: str) -> float:
    """``rows[num][key] / rows[den][key]`` looked up by engine name —
    the machine-independent summary a regression gate compares (raw
    tok/s depends on the box; the paged/rect or spec/base *ratio* does
    not)."""
    by = {r["engine"]: r for r in rows}
    return float(by[num_engine][key]) / float(by[den_engine][key])


def baseline_metrics(rows, build, label: str):
    """Build a gated-metric dict from checked-in baseline rows via
    ``build(rows)``, or None when there is no baseline or it predates
    the gated metric (legacy artifacts carry different row keys /
    engine names — an old file must not crash the run that is about to
    replace it)."""
    if rows is None:
        return None
    try:
        return build(rows)
    except (KeyError, StopIteration, ValueError, ZeroDivisionError):
        print(f"[{label}] checked-in baseline predates the gated metric "
              f"— gate skipped (this run rewrites the artifact)")
        return None


def check_regression(baseline: Dict[str, float], current: Dict[str, float],
                     rel_tol: float = 0.10, label: str = "bench"):
    """Enforce higher-is-better metric floors against a checked-in
    baseline: every metric must satisfy ``current >= baseline *
    (1 - rel_tol)`` or the run fails loudly with a RuntimeError listing
    each regressed metric.

    ``baseline`` is None when the artifact is missing (fresh checkout)
    — the gate prints a notice and passes, so first runs can create the
    baselines the next run will be held to. The
    ``NQ_BENCH_INJECT_SLOWDOWN`` env var (a fraction, e.g. ``0.2``)
    scales every *current* metric down before the comparison — the
    end-to-end negative test that proves the gate actually fires."""
    if baseline is None:
        print(f"[{label}] no checked-in baseline — regression gate "
              f"skipped (run the full benchmark to create one)")
        return
    inject = float(os.environ.get("NQ_BENCH_INJECT_SLOWDOWN", "0") or 0.0)
    failures = []
    for k, base in baseline.items():
        base = float(base)
        if k not in current:
            failures.append(f"{k}: metric missing from current run")
            continue
        cur = float(current[k]) * (1.0 - inject)
        floor = base * (1.0 - rel_tol)
        ok = cur >= floor
        print(f"[{label}] {k}: {cur:.3f} vs baseline {base:.3f} "
              f"(floor {floor:.3f}) {'OK' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(f"{k}: {cur:.3f} < floor {floor:.3f} "
                            f"(baseline {base:.3f}, rel_tol {rel_tol:.0%})")
    if failures:
        raise RuntimeError(
            f"[{label}] benchmark regression vs checked-in baseline:\n  "
            + "\n  ".join(failures))
