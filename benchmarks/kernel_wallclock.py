"""Registered entry point for the measured kernel wall-clock section
(two-call vs fused vs merged-projection; see benchmarks/kernel_bench.py,
which also hosts the CLI: ``python -m benchmarks.kernel_bench --smoke``).
Emits BENCH_kernel_wallclock.json."""
from benchmarks.kernel_bench import run_wallclock


def run():
    return run_wallclock()
