"""Paper Tables 13–14 (App. F): exact storage / BPW bounds — reproduced
for the paper's Llama-2-7B and extended to all 10 assigned archs."""
from __future__ import annotations

from benchmarks.common import emit
from repro import api
from repro.core import bpw
from repro.api import packed_model_bytes, quantizable_paths
from repro.configs.shapes import param_specs

_METHODS = ("nanoquant", "billm", "stbllm_4:8", "stbllm_6:8", "stbllm_8:8",
            "arbllm_rc", "hbllm_row", "hbllm_col")


def _l27_shapes():
    per = [(4096, 4096)] * 4 + [(11008, 4096)] * 2 + [(4096, 11008)]
    return per * 32


def run():
    rows = []
    # --- paper row: Llama-2-7B --------------------------------------------
    shapes = _l27_shapes()
    row = {"model": "llama-2-7b (paper)"}
    for m in _METHODS:
        kw = {"bpw": 1.0} if m == "nanoquant" else {}
        row[m] = bpw.model_bpw(shapes, m, **kw)
    rows.append(row)

    # --- assigned archs ------------------------------------------------------
    for arch in api.list_archs():
        cfg = api.get_config(arch)
        qp = quantizable_paths(param_specs(cfg), cfg)
        shapes = []
        for _, v in qp:
            w = v["w"]
            *lead, d_in, d_out = w.shape
            n_mat = 1
            for s in lead:
                n_mat *= s
            shapes += [(d_out, d_in)] * n_mat
        row = {"model": arch}
        for m in _METHODS:
            kw = {"bpw": 1.0} if m == "nanoquant" else {}
            row[m] = bpw.model_bpw(shapes, m, **kw)
        rep = packed_model_bytes(cfg, 1.0)
        row["nq_model_gb"] = rep["quantized_gb"]
        row["fp16_gb"] = rep["fp16_total_gb"]
        row["compression_x"] = rep["compression_x"]
        rows.append(row)
    emit("table13_storage", rows)
    return rows


if __name__ == "__main__":
    run()
