"""Roofline HLO cost model: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import HW, model_flops, roofline_terms
from repro.roofline.hlo import Shape, module_cost, parse_module


def test_matmul_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    comp = jax.jit(f).lower(a, b).compile()
    mc = module_cost(comp.as_text())
    assert mc["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=1e-6)


def test_scan_trip_multiplication():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(x, ws).compile()
    mc = module_cost(comp.as_text())
    assert mc["flops"] == pytest.approx(7 * 2 * 32 * 64 * 64, rel=1e-6)
    # XLA's own analysis counts the body once — ours must be 7x larger
    xla = comp.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    assert mc["flops"] > 5 * float(xla.get("flops", 0))


def test_grad_flops_counts_both_matmuls():
    def f(a, b):
        return jnp.sum(a @ b)
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    comp = jax.jit(jax.grad(f, argnums=(0, 1))).lower(a, b).compile()
    mc = module_cost(comp.as_text())
    assert mc["flops"] == pytest.approx(2 * 2 * 64 * 128 * 32, rel=1e-6)


def test_shape_bytes():
    assert Shape("bf16", (4, 8)).nbytes == 64
    assert Shape("f32", ()).nbytes == 4
    assert Shape("u32", (32,)).nbytes == 128


def test_parse_module_finds_entry():
    txt = """HloModule m

%helper (p: f32[4]) -> f32[4] {
  ROOT %t = f32[4]{0} tanh(%p)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  ROOT %c = f32[4]{0} call(%a), to_apply=%helper
}
"""
    comps, entry = parse_module(txt)
    assert entry == "main"
    assert "helper" in comps


def test_roofline_terms_dominance():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, link_bw=1.0)
    t = roofline_terms(flops=1000.0, hbm_bytes=10.0, wire_bytes=0.0, hw=hw)
    assert t["dominant"] == "compute"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t2 = roofline_terms(flops=100.0, hbm_bytes=1000.0, wire_bytes=0.0, hw=hw)
    assert t2["dominant"] == "memory"
    assert t2["roofline_fraction"] == pytest.approx(0.01)


def test_model_flops_moe_counts_active_only():
    from repro import configs
    cfg_moe = configs.get_config("qwen3-moe-235b-a22b")
    f = model_flops(cfg_moe, tokens=1000, mode="train")
    n_active_expected = 22e9          # a22b
    got_n = f / 6 / 1000
    assert 0.6 * n_active_expected < got_n < 1.4 * n_active_expected


def test_model_flops_train_vs_decode_factor():
    from repro import configs
    cfg = configs.get_config("llama3.2-1b")
    tr = model_flops(cfg, tokens=100, mode="train")
    de = model_flops(cfg, tokens=100, mode="decode")
    assert tr == pytest.approx(3 * de)
