import os
import subprocess
import sys
import textwrap

# tests run single-device (the dry-run alone forces 512 placeholder
# devices; see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_multidevice(code: str, devices: int = 8, timeout: int = 1500) -> str:
    """Run `code` in a subprocess with `devices` forced host devices
    (the launch/dryrun.py trick) — the shared harness for multi-device
    SPMD tests, so the main test process stays single-device. Raises
    AssertionError with captured output on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                       loss_chunk=0, remat=False)


@pytest.fixture(scope="session")
def tiny_params(tiny_dense_cfg):
    from repro.models import transformer as T
    return T.init_params(jax.random.PRNGKey(0), tiny_dense_cfg)
