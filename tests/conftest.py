import os
import sys

# tests run single-device (the dry-run alone forces 512 placeholder
# devices; see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                       loss_chunk=0, remat=False)


@pytest.fixture(scope="session")
def tiny_params(tiny_dense_cfg):
    from repro.models import transformer as T
    return T.init_params(jax.random.PRNGKey(0), tiny_dense_cfg)
