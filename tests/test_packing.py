"""Bit-packing (paper Fig. 2c) tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.balance import reconstruct
from repro.core.packing import pack_quantized, pack_signs, unpack_signs
from repro.kernels.ref import lowrank_binary_matmul_ref


@settings(max_examples=20, deadline=None)
@given(k32=st.integers(1, 4), n=st.integers(1, 40), seed=st.integers(0, 99))
def test_pack_unpack_roundtrip(k32, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jnp.sign(jax.random.normal(key, (32 * k32, n)))
    a = jnp.where(a == 0, 1.0, a)
    packed = pack_signs(a)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (k32, n)
    np.testing.assert_array_equal(np.asarray(unpack_signs(packed)),
                                  np.asarray(a))


def test_pack_convention_minus1_is_0():
    a = -jnp.ones((32, 3))
    assert int(pack_signs(a).sum()) == 0
    b = jnp.ones((32, 2))
    assert (np.asarray(pack_signs(b)) == np.uint32(0xFFFFFFFF)).all()


def test_pack_quantized_matches_reconstruct(tiny_dense_cfg):
    """Packed forward == dense reconstruct(Ŵ) forward (paper Eq. 1)."""
    key = jax.random.PRNGKey(1)
    m, n, r = 64, 96, 32
    ku, kv, k1, k2, kx = jax.random.split(key, 5)
    lu = jax.random.normal(ku, (m, r))          # (d_out, r)
    lv = jax.random.normal(kv, (n, r))          # (d_in, r)
    s1 = jnp.abs(jax.random.normal(k1, (m,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (n,))) + 0.1
    q = pack_quantized(lu, lv, s1, s2)
    x = jax.random.normal(kx, (5, n))
    y_packed = lowrank_binary_matmul_ref(x, q["qv"], q["qu_t"], q["s1"],
                                         q["s2"])
    w_hat = reconstruct(lu, lv, s1, s2)         # (m, n) = (d_out, d_in)
    y_dense = x @ w_hat.T
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_dense),
                               rtol=1e-3, atol=1e-3)
