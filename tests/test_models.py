"""Per-arch smoke tests (deliverable f): reduced same-family configs run
one forward + one train step on CPU; output shapes + no NaNs. Full
configs are only exercised via the AOT dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.train import TrainConfig, init_train_state, make_train_step

ARCHS = configs.list_archs()

# published-parameter-count lock (DESIGN.md §5); values in billions
_PARAM_B = {
    "musicgen-medium": 1.84, "zamba2-1.2b": 1.17, "qwen3-4b": 4.41,
    "qwen1.5-110b": 111.21, "qwen1.5-0.5b": 0.62, "llama3.2-1b": 1.24,
    "qwen3-moe-235b-a22b": 235.09, "deepseek-v2-lite-16b": 15.71,
    "llama-3.2-vision-90b": 87.67, "mamba2-370m": 0.37,
}


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    shape = (b, s, cfg.n_codebooks) if cfg.family == "audio" else (b, s)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    out = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        out["image_embeds"] = 0.1 * jnp.ones(
            (b, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    n = configs.get_config(arch).param_count() / 1e9
    assert abs(n - _PARAM_B[arch]) / _PARAM_B[arch] < 0.02, n


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    batch = _batch(cfg)
    tcfg = TrainConfig(lr=1e-3, total_steps=3)
    params, opt_state, eff = init_train_state(cfg, tcfg)
    logits = T.forward(params, cfg, batch["tokens"],
                       batch.get("image_embeds"))
    if cfg.family == "audio":
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    step = jax.jit(make_train_step(cfg, tcfg))
    l0 = None
    for _ in range(2):
        params, opt_state, eff, m = step(params, opt_state, eff, batch)
        assert np.isfinite(float(m["loss"]))
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) <= l0 + 0.5          # not diverging


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-1.2b",
                                  "mamba2-370m", "deepseek-v2-lite-16b"])
def test_prefill_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # dropless
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    full = T.forward(params, cfg, toks)
    cache = T.init_cache(cfg, 2, 24)
    logits, cache = T.prefill(params, cfg, toks[:, :16], cache)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, 15]), rtol=2e-3, atol=2e-3)
    for i in range(16, 20):
        logits, cache = T.decode_step(params, cfg, toks[:, i:i + 1], cache,
                                      jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]), rtol=5e-3,
                                   atol=5e-3)


def test_moe_dispatch_matches_dense_oracle():
    from repro.models import layers as L
    cfg = dataclasses.replace(configs.get_smoke("qwen3-moe-235b-a22b"),
                              dtype="float32", capacity_factor=8.0)
    p = L.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, cfg.d_model))
    got = L.moe(p, cfg, x)
    want = L.moe_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor some tokens overflow and fall back to
    the residual path (output contribution zero) — dispatch must not
    corrupt other tokens."""
    from repro.models import layers as L
    cfg = dataclasses.replace(configs.get_smoke("qwen3-moe-235b-a22b"),
                              dtype="float32", capacity_factor=8.0)
    p = L.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    full = L.moe(p, cfg, x, capacity=64)
    tight = L.moe(p, cfg, x, capacity=8)
    assert np.isfinite(np.asarray(tight)).all()
    # tight-capacity output differs (tokens dropped) but stays bounded
    assert float(jnp.abs(tight).max()) <= float(jnp.abs(full).max()) * 4


def test_sliding_window_masks_past():
    from repro.models import layers as L
    q_pos = jnp.arange(10)
    m = L._mask(q_pos, q_pos, window=3)
    assert bool(m[5, 5]) and bool(m[5, 3])
    assert not bool(m[5, 2]) and not bool(m[5, 6])


def test_decode_mask_ring_buffer_wrap():
    """After the hybrid sliding-window cache wraps, the decode mask
    reconstructs each row's absolute position from the wrapped write
    offset — the old absolute-vs-row-index mask went all-False there."""
    from repro.models import layers as L
    win = 64
    # pos 70 wrapped to row 6: every ring row holds one of the last 64
    # positions, so the whole window is attendable
    m = L._decode_mask(jnp.asarray([70]), jnp.asarray(70 % win), win, win)
    assert m.shape == (1, win) and bool(m.all())
    # pre-wrap (pos 3): only rows 0..3 written
    m = L._decode_mask(jnp.asarray([3]), jnp.asarray(3), win, win)
    assert m.sum() == 4 and bool(m[0, :4].all())
    # linear (non-ring) cache: reduces to the causal prefix mask
    m = L._decode_mask(jnp.asarray([9]), jnp.asarray(9), 32, 0)
    assert m.sum() == 10 and bool(m[0, :10].all())
    # per-slot vector offsets
    m = L._decode_mask(jnp.asarray([[70], [3]]),
                       jnp.asarray([70 % win, 3]), win, win)
    assert m.shape == (2, 1, win)
    assert bool(m[0].all()) and m[1].sum() == 4


def test_hybrid_decode_survives_window_wrap():
    """Hybrid decode past the sliding window attends over the full ring
    (the pre-fix mask had zero valid rows there -> uniform softmax over
    garbage), and the ring is independent of the cache max_len: the
    shared-attn buffer is allocated at exactly `window` rows."""
    cfg = dataclasses.replace(configs.get_smoke("zamba2-1.2b"),
                              dtype="float32", sliding_window=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                              cfg.vocab_size)

    def drive(max_len):
        cache = T.init_cache(cfg, 1, max_len)
        assert cache["window"] == 8
        assert cache["shared_attn"]["k"].shape[2] == 8   # ring == window
        logits, cache = T.prefill(params, cfg, toks, cache)
        outs = []
        for i in range(4, 20):              # crosses the wrap at pos 8
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            logits, cache = T.decode_step(params, cfg, tok, cache,
                                          jnp.asarray(i))
            assert np.isfinite(np.asarray(logits)).all()
            # post-wrap logits must stay sharp, not collapse toward the
            # uniform average an all-masked softmax produces
            probs = jax.nn.softmax(logits[0, 0].astype(jnp.float32))
            assert float(probs.max()) > 2.0 / cfg.vocab_size
            outs.append(np.asarray(logits[0, 0]))
        return np.stack(outs)

    np.testing.assert_array_equal(drive(8), drive(32))


def test_mask_per_slot_positions():
    """(B,Sq) q_pos gives each batch row its own causal frontier."""
    from repro.models import layers as L
    q_pos = jnp.asarray([[3], [7]])
    m = L._mask(q_pos, jnp.arange(10), window=0)
    assert m.shape == (2, 1, 10)
    assert bool(m[0, 0, 3]) and not bool(m[0, 0, 4])
    assert bool(m[1, 0, 7]) and not bool(m[1, 0, 8])


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-lite-16b",
                                  "zamba2-1.2b"])
def test_decode_step_vector_pos_matches_scalar(arch):
    """decode_step with a (B,) per-slot position vector reproduces the
    scalar-pos path exactly when all slots sit at the same position."""
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # dropless
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    cache = T.init_cache(cfg, 2, 16)
    _, cache = T.prefill(params, cfg, toks[:, :8], cache)
    tok = toks[:, 8:9]
    l_s, c_s = T.decode_step(params, cfg, tok, cache, jnp.asarray(8))
    l_v, c_v = T.decode_step(params, cfg, tok, cache,
                             jnp.asarray([8, 8], jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-lite-16b"])
def test_decode_step_staggered_slots(arch):
    """Slots at *different* positions each match their own scalar-pos
    decode: per-slot RoPE phases, cache writes and causal masks keep
    batch rows fully independent (attention families)."""
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # dropless
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    cache = T.init_cache(cfg, 2, 16)
    _, cache = T.prefill(params, cfg, toks[:, :8], cache)
    l8, c9 = T.decode_step(params, cfg, toks[:, 8:9], cache, jnp.asarray(8))
    _, c10 = T.decode_step(params, cfg, toks[:, 9:10], c9, jnp.asarray(9))
    l10, _ = T.decode_step(params, cfg, toks[:, 10:11], c10,
                           jnp.asarray(10))
    # row 0 replays pos 8 (its mask hides the newer cache rows; the
    # write at row 8 re-stores identical k/v), row 1 decodes pos 10.
    l_mix, _ = T.decode_step(params, cfg,
                             jnp.stack([toks[0, 8:9], toks[1, 10:11]]),
                             c10, jnp.asarray([8, 10], jnp.int32))
    np.testing.assert_allclose(np.asarray(l_mix[0, 0]),
                               np.asarray(l8[0, 0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_mix[1, 0]),
                               np.asarray(l10[1, 0]), rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_naive_recurrence():
    """Chunked SSD (arXiv:2405.21060) vs step-by-step recurrence."""
    from repro.models import layers as L
    B, S, H, P, N = 2, 12, 3, 4, 5
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[0], (B, S, 1, N))
    y, final = L.ssd_chunked(xh, dt, A, Bm, Cm, chunk=4)

    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                      # (B,H)
        st = st * dA[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t, 0], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t, 0], st))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(st), rtol=2e-3,
                               atol=2e-3)


def test_ssd_chunk_padding_exact():
    from repro.models import layers as L
    B, S, H, P, N = 1, 10, 2, 3, 4
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, 1, N))
    Cm = jax.random.normal(ks[0], (B, S, 1, N))
    y1, f1 = L.ssd_chunked(xh, dt, A, Bm, Cm, chunk=4)   # pads 10 -> 12
    y2, f2 = L.ssd_chunked(xh, dt, A, Bm, Cm, chunk=5)   # exact fit
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-3,
                               atol=2e-3)
