"""Differential kernel fuzz harness: fused pallas kernels == unfused
chains == pure-jnp oracles over randomized shapes.

Every property draws ONE integer ``seed`` (via the hypothesis shim —
real hypothesis when installed) and derives the whole case from
``np.random.default_rng(seed)``; the seed is embedded in the assertion
message, so any reported failure replays bit-for-bit with
``_case(seed)``. Example counts scale with the ``NQ_FUZZ_EXAMPLES``
env var (the full profile in ``kernel_bench``/CI docs runs >= 200
generated cases across the suite; the tier-1 default stays small so
the interpreter-mode kernels don't dominate the test wall clock).

Covered differentials:

- fused single-pass matmul vs legacy two-call pallas chain vs
  ``ref.lowrank_binary_matmul_fused_ref`` (dtype in {f32, bf16},
  eff_rank truncation, off-block K like 704 that the divisor-fitted
  tiles must launch pad-free);
- merged multi-projection launch (ragged true ranks via rmask) vs the
  per-projection oracle;
- paged gather attention vs ``ref.paged_attention_ref`` across
  page_size, n_pages, ragged last page, sliding-window ring wrap,
  pages_per_step / head_block knobs, and S in {1..k+1} multi-token
  verify reads;
- decode-step megakernel vs the unfused chain (merged QKV -> RoPE ->
  paged cache write -> paged attention -> wo, each stage the shipped
  pallas op) vs ``ref.decode_step_ref`` — including engine-level
  greedy token identity with the megakernel genuinely engaged, and the
  tensor-parallel fallback (non-qualifying launches return None and
  the chain takes over).
"""
from __future__ import annotations

import dataclasses
import os
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref, tuning  # noqa: E402
from repro.kernels.megakernel import decode_step_megakernel_raw  # noqa: E402
from repro.models.layers import apply_rope, paged_cache_write  # noqa: E402

PALLAS = kops.KernelPolicy(mode="pallas", interpret=True)
REF = kops.KernelPolicy(mode="ref")
BIG = 10 ** 6
SEEDS = st.integers(min_value=0, max_value=2 ** 31 - 1)


def _examples(default: int) -> int:
    return int(os.environ.get("NQ_FUZZ_EXAMPLES", default))


def _tol(dtype) -> float:
    return 1e-5 if dtype == jnp.float32 else 3e-2


def _close(name, a, b, tol, seed, **case):
    """Relative max-abs comparison; the failure message carries the
    replay seed and the drawn case."""
    a = np.asarray(jnp.asarray(a, jnp.float32))
    b = np.asarray(jnp.asarray(b, jnp.float32))
    assert a.shape == b.shape, (name, a.shape, b.shape, seed, case)
    scale = max(1.0, float(np.max(np.abs(a)))) if a.size else 1.0
    err = float(np.max(np.abs(a - b))) / scale if a.size else 0.0
    assert err <= tol, (f"{name}: rel err {err:.3e} > {tol} "
                        f"[replay seed={seed} case={case}]")


def _pack(rng, k, r):
    """Packed random ±1 matrix (k, r) -> (k//32, r) uint32."""
    signs = (rng.standard_normal((k, r)) > 0).astype(np.float32) * 2 - 1
    return ref.pack_signs(jnp.asarray(signs))


def _operands(rng, m, k, n, r, dtype):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32).astype(dtype)
    qv = _pack(rng, k, r)
    qu_t = _pack(rng, r, n)
    s1 = jnp.asarray(rng.standard_normal(n), jnp.float32)
    s2 = jnp.asarray(rng.standard_normal(k), jnp.float32)
    return x, qv, qu_t, s1, s2


# ===========================================================================
# packed matmul: fused vs two-call vs oracle
# ===========================================================================


@settings(max_examples=_examples(20))
@given(seed=SEEDS)
def test_matmul_fused_vs_twocall_vs_oracle(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.choice([1, 3, 8]))
    k = 32 * int(rng.integers(1, 5))
    n = 8 * int(rng.integers(1, 17))
    r = 32 * int(rng.integers(1, 4))
    dtype = jnp.float32 if rng.integers(2) else jnp.bfloat16
    case = dict(m=m, k=k, n=n, r=r, dtype=str(dtype.__name__))
    x, qv, qu_t, s1, s2 = _operands(rng, m, k, n, r, dtype)

    fused = kops.lowrank_binary_matmul(x, qv, qu_t, s1, s2, policy=PALLAS)
    two = kops.lowrank_binary_matmul(
        x, qv, qu_t, s1, s2,
        policy=dataclasses.replace(PALLAS, fused=False))
    oracle = ref.lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2)
    _close("fused-vs-oracle", oracle, fused, _tol(dtype), seed, **case)
    _close("twocall-vs-oracle", oracle, two, _tol(dtype), seed, **case)


@settings(max_examples=_examples(15))
@given(seed=SEEDS)
def test_matmul_eff_rank_truncation(seed):
    """Rank-truncated launches (the speculative draft forward) read only
    the leading eff_rank components — equal to the sliced oracle."""
    rng = np.random.default_rng(seed)
    m = int(rng.choice([1, 8]))
    k = 32 * int(rng.integers(1, 4))
    n = 8 * int(rng.integers(2, 9))
    r = 32 * int(rng.integers(2, 5))
    er = 32 * int(rng.integers(1, r // 32 + 1))
    case = dict(m=m, k=k, n=n, r=r, eff_rank=er)
    x, qv, qu_t, s1, s2 = _operands(rng, m, k, n, r, jnp.float32)

    got = kops.lowrank_binary_matmul(x, qv, qu_t, s1, s2, policy=PALLAS,
                                     eff_rank=er)
    want = ref.lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2,
                                               eff_rank=er)
    _close("effrank", want, got, 1e-5, seed, **case)


@settings(max_examples=_examples(15))
@given(seed=SEEDS)
def test_matmul_offblock_shapes(seed):
    """K values the preferred bk=512 tile does NOT divide (the
    d_ff=2816 / K=704 family): the divisor-fitted tiles must stay
    exact, launching without padding the packed operands."""
    rng = np.random.default_rng(seed)
    k = int(rng.choice([160, 224, 704]))   # 5, 7, 22 packed words
    m = int(rng.choice([1, 8]))
    n = 8 * int(rng.choice([5, 7, 25]))
    r = 32 * int(rng.integers(1, 3))
    case = dict(m=m, k=k, n=n, r=r)
    x, qv, qu_t, s1, s2 = _operands(rng, m, k, n, r, jnp.float32)
    got = kops.lowrank_binary_matmul(x, qv, qu_t, s1, s2, policy=PALLAS)
    want = ref.lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2)
    _close("offblock", want, got, 1e-5, seed, **case)


@settings(max_examples=_examples(15))
@given(seed=SEEDS)
def test_merged_rmask_vs_oracle(seed):
    """Grouped QKV-style launch with ragged true ranks (rmask) equals
    the per-projection fused oracle on every group's true output dim."""
    rng = np.random.default_rng(seed)
    m = int(rng.choice([1, 4]))
    k = 32 * int(rng.integers(1, 4))
    R = 32 * int(rng.integers(1, 3))
    P = int(rng.integers(2, 4))
    dims = [8 * int(rng.integers(1, 9)) for _ in range(P)]
    ranks = [32 * int(rng.integers(1, R // 32 + 1)) for _ in range(P)]
    n_max = max(dims)
    case = dict(m=m, k=k, R=R, dims=dims, ranks=ranks)

    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    qv = jnp.stack([_pack(rng, k, R) for _ in range(P)])
    qu_t = jnp.stack([_pack(rng, R, n_max) for _ in range(P)])
    s1 = jnp.asarray(rng.standard_normal((P, n_max)), jnp.float32)
    s2 = jnp.asarray(rng.standard_normal((P, k)), jnp.float32)
    rmask = jnp.asarray(np.stack(
        [(np.arange(R) < r).astype(np.float32) for r in ranks]))
    mp = {"qv": qv, "qu_t": qu_t, "s1": s1, "s2": s2, "rmask": rmask}

    got = kops.lowrank_binary_matmul_merged(x, mp, dims, policy=PALLAS)
    for i, n in enumerate(dims):
        want = ref.lowrank_binary_matmul_fused_ref(
            x, qv[i], qu_t[i], s1[i], s2[i], rmask[i])[:, :n]
        _close(f"group{i}", want, got[i], 1e-5, seed, **case)


# ===========================================================================
# paged gather attention
# ===========================================================================


def _paged_case(rng, s_max=1):
    hkv = int(rng.choice([1, 2, 3]))
    G = int(rng.choice([1, 2, 4]))
    D = int(rng.choice([8, 16]))
    PS = int(rng.choice([2, 4, 8]))
    pages = int(rng.integers(1, 7))
    B = int(rng.integers(1, 4))
    NP = B * pages + 2
    rows = pages * PS
    # an S-token span must fit in the pool's rows (and keep the ring
    # draw range non-empty for tiny pools: qpos in [rows, 3*rows - S))
    S = int(rng.integers(1, min(s_max, rows) + 1)) if s_max > 1 else 1
    window = int(rng.choice([0, rng.integers(2, rows + 1)]))
    ring = bool(rng.integers(2)) and window > 0
    dtype = jnp.float32 if rng.integers(2) else jnp.bfloat16

    q = jnp.asarray(rng.standard_normal((B, S, hkv * G, D)),
                    jnp.float32).astype(dtype)
    kp = jnp.asarray(rng.standard_normal((NP, PS, hkv, D)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(rng.standard_normal((NP, PS, hkv, D)),
                     jnp.float32).astype(dtype)
    # writable pages exclusive per slot (see decode_step_ref contract)
    flat = rng.choice(np.arange(1, NP), B * pages, replace=False)
    bt = jnp.asarray(flat.reshape(B, pages), jnp.int32)
    # q_pos of the FIRST query token; ragged last page almost surely
    # (positions drawn mid-page), S tokens must fit below the rectangle
    hi = max(rows - S, 1)
    if ring:
        qpos = jnp.asarray(rng.integers(rows, 3 * rows - S, B), jnp.int32)
        cpos = qpos % rows
    else:
        qpos = jnp.asarray(rng.integers(0, hi, B), jnp.int32)
        cpos = qpos
    knobs = (int(rng.integers(1, 5)),                       # pages_per_step
             int(rng.choice([0, 1, 2, 3])))                 # head_block
    case = dict(B=B, S=S, hkv=hkv, G=G, D=D, PS=PS, pages=pages,
                window=window, ring=ring, knobs=knobs,
                dtype=str(np.dtype(dtype).name))
    return q, kp, vp, bt, qpos, cpos, window, knobs, dtype, case


def _paged_policy(ppb, hb):
    return dataclasses.replace(
        PALLAS, paged_block_table=((BIG, BIG, BIG, BIG, ppb, hb),))


@settings(max_examples=_examples(15))
@given(seed=SEEDS)
def test_paged_attention_vs_oracle(seed):
    rng = np.random.default_rng(seed)
    q, kp, vp, bt, qpos, cpos, window, (ppb, hb), dtype, case = \
        _paged_case(rng, s_max=1)
    scale = 1.0 / np.sqrt(q.shape[-1])
    got = kops.paged_attention(q, kp, vp, bt, qpos, cpos, window=window,
                               scale=scale, policy=_paged_policy(ppb, hb))
    want = ref.paged_attention_ref(q, kp, vp, bt, qpos, cpos,
                                   window=window, scale=scale)
    _close("paged", want, got, _tol(dtype), seed, **case)


@settings(max_examples=_examples(15))
@given(seed=SEEDS)
def test_paged_attention_multitoken_vs_oracle(seed):
    """S in {1..5} multi-token verify reads (all S rows pre-written,
    per-query causal masking), including page-boundary-straddling
    spans and ring wrap."""
    rng = np.random.default_rng(seed)
    q, kp, vp, bt, qpos, cpos, window, (ppb, hb), dtype, case = \
        _paged_case(rng, s_max=5)
    scale = 1.0 / np.sqrt(q.shape[-1])
    got = kops.paged_attention(q, kp, vp, bt, qpos, cpos, window=window,
                               scale=scale, policy=_paged_policy(ppb, hb))
    want = ref.paged_attention_ref(q, kp, vp, bt, qpos, cpos,
                                   window=window, scale=scale)
    _close("paged-multitoken", want, got, _tol(dtype), seed, **case)


# ===========================================================================
# decode-step megakernel: one pallas pass vs unfused chain vs oracle
# ===========================================================================


def _mega_case(rng):
    D = int(rng.choice([8, 16]))
    hkv = int(rng.choice([2, 3]))
    G = int(rng.choice([1, 2]))
    hq = hkv * G
    nq, nkv = hq * D, hkv * D
    K = 32 * int(rng.choice([2, 3]))
    R = 32 * int(rng.choice([1, 2]))
    n_max = max(nq, nkv)
    ranks = [32 * int(rng.integers(1, R // 32 + 1)) for _ in range(3)]
    eff = 32 * int(rng.integers(1, R // 32 + 1)) if rng.integers(2) else None
    B = int(rng.integers(1, 3))
    pages, PS = int(rng.integers(2, 5)), 4
    NP = B * pages + 2
    rows = pages * PS
    window = int(rng.choice([0, rng.integers(3, rows)]))
    ring = bool(rng.integers(2)) and window > 0
    ppb = int(rng.integers(1, 4))
    dtype = jnp.float32 if rng.integers(2) else jnp.bfloat16

    mqkv = {
        "qv": jnp.stack([_pack(rng, K, R) for _ in range(3)]),
        "qu_t": jnp.stack([_pack(rng, R, n_max) for _ in range(3)]),
        "s1": jnp.asarray(rng.standard_normal((3, n_max)), jnp.float32),
        "s2": jnp.asarray(rng.standard_normal((3, K)), jnp.float32),
        "rmask": jnp.asarray(np.stack(
            [(np.arange(R) < r).astype(np.float32) for r in ranks])),
    }
    Ko = -(-nq // 32) * 32      # wo packed K is pack-aligned past nq
    s2o = rng.standard_normal(Ko).astype(np.float32)
    s2o[nq:] = 0.0
    wo = {
        "qv": _pack(rng, Ko, R),
        "qu_t": _pack(rng, R, K),
        "s1": jnp.asarray(rng.standard_normal(K), jnp.float32),
        "s2": jnp.asarray(s2o),
    }
    eff_o = 32 * int(rng.integers(1, R // 32 + 1)) if rng.integers(2) \
        else None

    x = jnp.asarray(rng.standard_normal((B, K)), jnp.float32).astype(dtype)
    kp = jnp.asarray(rng.standard_normal((NP, PS, hkv, D)),
                     jnp.float32).astype(dtype)
    vp = jnp.asarray(rng.standard_normal((NP, PS, hkv, D)),
                     jnp.float32).astype(dtype)
    flat = rng.choice(np.arange(1, NP), B * pages, replace=False)
    bt = jnp.asarray(flat.reshape(B, pages), jnp.int32)
    if ring:
        qpos = jnp.asarray(rng.integers(rows, 3 * rows, B), jnp.int32)
        cpos = qpos % rows
    else:
        qpos = jnp.asarray(rng.integers(1, rows, B), jnp.int32)
        cpos = qpos
    kw = dict(dims=(nq, nkv), head_dim=D, theta=10000.0,
              scale=1.0 / np.sqrt(D), window=window,
              eff_rank=eff, eff_rank_o=eff_o)
    case = dict(B=B, K=K, D=D, hq=hq, hkv=hkv, R=R, ranks=ranks,
                pages=pages, window=window, ring=ring, ppb=ppb,
                eff=eff, eff_o=eff_o, dtype=str(np.dtype(dtype).name))
    return x, mqkv, wo, kp, vp, bt, qpos, cpos, ppb, kw, dtype, case


def _unfused_chain(x, mqkv, wo, kp, vp, bt, qpos, cpos, ppb, *, dims,
                   head_dim, theta, scale, window, eff_rank, eff_rank_o):
    """The decode step as the engine runs it when the megakernel does
    not qualify: every stage the shipped pallas op (interpret mode)."""
    nq, nkv = dims
    B = x.shape[0]
    pol = _paged_policy(ppb, 0)
    q, k, v = kops.lowrank_binary_matmul_merged(
        x, mqkv, (nq, nkv, nkv), policy=pol, eff_rank=eff_rank)
    q = apply_rope(q.reshape(B, 1, nq // head_dim, head_dim),
                   qpos[:, None], theta)
    k = apply_rope(k.reshape(B, 1, nkv // head_dim, head_dim),
                   qpos[:, None], theta)
    v = v.reshape(B, 1, nkv // head_dim, head_dim)
    kp = paged_cache_write(kp, k.astype(kp.dtype), bt, cpos)
    vp = paged_cache_write(vp, v.astype(vp.dtype), bt, cpos)
    o = kops.paged_attention(q, kp, vp, bt, qpos, cpos, window=window,
                             scale=scale, policy=pol)
    y = kops.lowrank_binary_matmul(
        o.reshape(B, nq).astype(x.dtype), wo["qv"], wo["qu_t"], wo["s1"],
        wo["s2"], policy=pol, eff_rank=eff_rank_o)
    return y, k[:, 0], v[:, 0]


@settings(max_examples=_examples(8))
@given(seed=SEEDS)
def test_megakernel_vs_unfused_chain_vs_oracle(seed):
    rng = np.random.default_rng(seed)
    x, mqkv, wo, kp, vp, bt, qpos, cpos, ppb, kw, dtype, case = \
        _mega_case(rng)
    y_m, k_m, v_m = decode_step_megakernel_raw(
        x, mqkv, wo, kp, vp, bt, qpos, cpos, pages_per_step=ppb,
        bk=32, bn=32, interpret=True, **kw)
    y_r, k_r, v_r = ref.decode_step_ref(x, mqkv, wo, kp, vp, bt, qpos,
                                        cpos, **kw)
    y_c, k_c, v_c = _unfused_chain(x, mqkv, wo, kp, vp, bt, qpos, cpos,
                                   ppb, **kw)
    tol = _tol(dtype)
    for nm, a, b in (("y", y_r, y_m), ("k_new", k_r, k_m),
                     ("v_new", v_r, v_m)):
        _close(f"mega-vs-oracle:{nm}", a, b, tol, seed, **case)
    for nm, a, b in (("y", y_r, y_c),
                     ("k_new", k_r, k_c.astype(k_r.dtype)),
                     ("v_new", v_r, v_c.astype(v_r.dtype))):
        _close(f"chain-vs-oracle:{nm}", a, b, tol, seed, **case)


def test_megakernel_gating_returns_none_for_nonqualifying():
    """Non-qualifying launches must fall back to the unfused chain
    (return None), never mis-launch: ref/unfused/unmerged policies,
    megakernel=False, off-32 eff_rank, oversized ranks."""
    rng = np.random.default_rng(0)
    x, mqkv, wo, kp, vp, bt, qpos, cpos, ppb, kw, _, _ = _mega_case(rng)
    call = lambda pol, **ov: kops.decode_step_megakernel(
        x, mqkv, wo, kp, vp, bt, qpos, cpos, policy=pol, **{**kw, **ov})
    assert call(REF) is None
    assert call(dataclasses.replace(PALLAS, fused=False)) is None
    assert call(dataclasses.replace(PALLAS, merge_projections=False)) is None
    assert call(dataclasses.replace(PALLAS, megakernel=False)) is None
    assert call(PALLAS, eff_rank=33) is None          # not a 32-multiple
    assert call(PALLAS, eff_rank=mqkv["qv"].shape[-1] + 32) is None
    out = call(PALLAS)                                # qualifying launch
    assert out is not None and len(out) == 3


# ===========================================================================
# engine-level identity: megakernel on == off (greedy), genuinely engaged
# ===========================================================================


def _mega_engine_outputs(monkeypatch):
    from repro.quant.surgery import abstract_quantized_params
    from repro.serve import InferenceEngine, Request, ServeConfig
    from repro.models.config import ModelConfig
    from repro.kernels import megakernel as mk

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      loss_chunk=0, remat=False)
    # min_dim=32: the 64->32 kv projections must quantize, else the
    # attention group never merges and the megakernel silently never
    # engages (the launch counter below guards against exactly that)
    tpl = abstract_quantized_params(cfg, target_bpw=2.0, min_dim=32)
    rng = np.random.default_rng(11)

    def fill(path, s):
        last = getattr(path[-1], "key", str(path[-1]))
        if s.dtype == jnp.uint32:
            return jnp.asarray(rng.integers(
                0, 2 ** 32, size=s.shape, dtype=np.uint64).astype(np.uint32))
        if last in ("s1", "s2"):
            return jnp.ones(s.shape, s.dtype)
        return jnp.asarray(rng.normal(0, 0.05, s.shape).astype(s.dtype))

    params = jax.tree_util.tree_map_with_path(fill, tpl)
    prompts = [list((np.arange(n) * 7 + 3) % cfg.vocab_size)
               for n in (6, 11, 4)]
    budgets = [10, 8, 12]

    launches = [0]
    raw = mk.decode_step_megakernel_raw

    def counting_raw(*a, **k):
        launches[0] += 1
        return raw(*a, **k)

    monkeypatch.setattr(mk, "decode_step_megakernel_raw", counting_raw)

    def serve(scfg):
        eng = InferenceEngine(params, cfg, scfg, max_batch=2, max_len=48)
        for uid, (p, b) in enumerate(zip(prompts, budgets)):
            eng.submit(Request(uid, p, max_new_tokens=b))
        return {u: r.output for u, r in eng.run().items()}

    base = ServeConfig(greedy=True, page_size=8)
    out = {}
    with kops.kernel_policy(PALLAS):
        out["off"] = serve(dataclasses.replace(base, megakernel=False))
        traced_off = launches[0]
        out["on"] = serve(dataclasses.replace(base, megakernel=True))
        assert launches[0] > traced_off, \
            "megakernel=True never launched the megakernel"
        out["spec_off"] = serve(dataclasses.replace(
            base, megakernel=False, spec_rank_frac=0.5, spec_k=4))
        out["spec_on"] = serve(dataclasses.replace(
            base, megakernel=True, spec_rank_frac=0.5, spec_k=4))
    return out


@pytest.mark.slow
def test_megakernel_engine_token_identity(monkeypatch):
    """Greedy outputs token-identical with the megakernel on vs off, on
    the paged engine and composed with speculative decoding (k=4)."""
    out = _mega_engine_outputs(monkeypatch)
    for u in out["off"]:
        np.testing.assert_array_equal(out["off"][u], out["on"][u])
        np.testing.assert_array_equal(out["spec_off"][u], out["spec_on"][u])
        np.testing.assert_array_equal(out["off"][u], out["spec_on"][u])


@pytest.mark.slow
def test_megakernel_tp_fallback_identity():
    """Under a (model=2) tensor-parallel mesh the megakernel launch does
    not qualify (merged padded-Nmax layout is not head-aligned): the
    gate must return None and the engine must stay token-identical to
    the unsharded megakernel=True engine via the unfused-chain
    fallback."""
    from conftest import run_multidevice
    out = run_multidevice("""
        import jax, numpy as np
        from repro.core.pipeline import QuantConfig, nanoquant_quantize
        from repro.data import calib_batches
        from repro.kernels import ops as kops
        from repro.kernels import megakernel as mk
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as T
        from repro.models.config import ModelConfig
        from repro.serve.engine import InferenceEngine, ServeConfig
        from repro.serve.scheduler import Request

        # f32 + TP-divisible dims, same recipe as
        # test_engine.test_sharded_engine_token_identity: greedy argmax
        # must not flip on partitioned-reduction reordering noise
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=256, loss_chunk=0, remat=False,
                          dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        calib = calib_batches(cfg, 2, 32, batch=2)
        qcfg = QuantConfig(admm_iters=2, t_pre=0, t_post=0, t_glob=0,
                           rank_align=32, min_dim=32)
        qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)

        prompts = [np.arange(1, 7, dtype=np.int32),
                   np.arange(3, 12, dtype=np.int32),
                   np.arange(2, 10, dtype=np.int32)]
        budgets = [6, 3, 5]

        launches = [0]
        raw = mk.decode_step_megakernel_raw
        def counting_raw(*a, **k):
            launches[0] += 1
            return raw(*a, **k)
        mk.decode_step_megakernel_raw = counting_raw

        def run(mesh):
            scfg = ServeConfig(greedy=True, page_size=8, megakernel=True)
            eng = InferenceEngine(qp, cfg, scfg, max_batch=2,
                                  max_len=32, mesh=mesh)
            for uid, (p, b) in enumerate(zip(prompts, budgets)):
                eng.submit(Request(uid, p, max_new_tokens=b))
            return {u: r.output for u, r in eng.run().items()}

        pol = kops.KernelPolicy(mode="pallas", interpret=True)
        with kops.kernel_policy(pol):
            ref_out = run(None)
        assert launches[0] > 0, "megakernel never engaged unsharded"
        traced = launches[0]
        with kops.kernel_policy(pol):
            tp_out = run(make_serving_mesh(2))
        # the TP engine must have taken the unfused-chain fallback:
        # no new megakernel launches under the mesh
        assert launches[0] == traced, "megakernel launched under TP"
        for u in ref_out:
            np.testing.assert_array_equal(ref_out[u], tp_out[u])
        print("tp-fallback-identity-ok")
    """, devices=2)
    assert "tp-fallback-identity-ok" in out


# ===========================================================================
# tuning-table behavior
# ===========================================================================


@pytest.mark.sweep
def test_no_pad_in_decode_jaxpr_for_swept_shapes():
    """Divisor-fitted tiles must launch the swept decode shapes (and
    the K=704 off-block GEMV family) without tracing a single pad op
    into the jitted step — padding the packed weights per call was the
    original table-miss regression. M is kept sublane-aligned (8) so
    the one *intended* pad (rounding a tiny activation batch up to the
    sublane) can't mask a weight pad; any ``pad[`` left in the jaxpr is
    a weight pad."""
    shapes = [(8, 704, 512, 64), (8, 512, 512, 128)]
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "bench", "kernel_block_table.json")
    if os.path.exists(path):
        shapes += [(max(m, 8), k, n, r) for (m, k, n, r, *_)
                   in tuning.load_block_table(path)]
    for m, k, n, r in shapes:
        rng = np.random.default_rng(1)
        x, qv, qu_t, s1, s2 = _operands(rng, m, k, n, r, jnp.float32)
        jaxpr = str(jax.make_jaxpr(
            lambda xx: kops.lowrank_binary_matmul(
                xx, qv, qu_t, s1, s2, policy=PALLAS))(x))
        assert "pad[" not in jaxpr, \
            f"shape (M={m},K={k},N={n},r={r}) traced a pad"


def test_tuning_miss_warns_once():
    tuning._MISS_WARNED.clear()
    huge = (2 * BIG, 2 * BIG, 2 * BIG, 2 * BIG)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        tuning.lookup_block_table(*huge)
        tuning.lookup_block_table(*huge)
    msgs = [x for x in w if "no block-table row" in str(x.message)]
    assert len(msgs) == 1, "table miss must warn exactly once per class"


def test_fit_paged_block_sizes_units():
    # ppb clamps to the page count; hb snaps down to a divisor of Hkv
    table = ((BIG, BIG, BIG, BIG, 8, 3),)
    assert tuning.fit_paged_block_sizes(1, 4, 8, 2, table) == (2, 2)
    ppb, hb = tuning.fit_paged_block_sizes(1, 4, 8, 64, table)
    assert ppb == 8 and 4 % max(hb, 1) == 0
    # hb >= Hkv or <= 1 disables head tiling
    assert tuning.fit_paged_block_sizes(1, 2, 8, 64, ((BIG,) * 4 + (4, 2),)
                                        )[1] == 0


@pytest.mark.sweep
def test_committed_block_table_roundtrip():
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "bench", "kernel_block_table.json")
    if not os.path.exists(path):
        pytest.skip("no committed kernel_block_table.json")
    mm = tuning.load_block_table(path)
    assert mm and all(len(r) == 7 for r in mm)
    pg = tuning.load_paged_table(path)
    assert pg and all(len(r) == 6 for r in pg)
    # the loaded rows drive the policy fit without error
    pol = kops.KernelPolicy(mode="pallas", interpret=True,
                            block_table=mm, paged_block_table=pg)
    assert len(pol.block_sizes(1, 256, 256, 64)) == 3
    assert len(pol.paged_block_sizes(4, 2, 16, 4)) == 2


# ===========================================================================
# benchmark regression gates (benchmarks/common.py)
# ===========================================================================


def test_check_regression_gate(monkeypatch):
    """The gate passes inside tolerance, fails loudly past it, fails on
    an injected 20% slowdown (the end-to-end negative test hook), and
    skips cleanly with no checked-in baseline."""
    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, root)
    try:
        from benchmarks import common
    finally:
        sys.path.remove(root)
    base = {"decode_ratio": 1.0}
    common.check_regression(base, {"decode_ratio": 1.2})
    common.check_regression(base, {"decode_ratio": 0.95})   # within 10%
    with pytest.raises(RuntimeError, match="decode_ratio"):
        common.check_regression(base, {"decode_ratio": 0.85})
    with pytest.raises(RuntimeError, match="missing"):
        common.check_regression(base, {})
    monkeypatch.setenv("NQ_BENCH_INJECT_SLOWDOWN", "0.2")
    with pytest.raises(RuntimeError):
        common.check_regression(base, {"decode_ratio": 1.0})
    monkeypatch.delenv("NQ_BENCH_INJECT_SLOWDOWN")
    common.check_regression(None, {"decode_ratio": 0.0})    # no baseline
