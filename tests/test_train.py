"""Training loop + gradient compression tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import make_batch, SyntheticCorpus, train_iterator
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.grad_compress import (
    CompressConfig, compress_leaf, compress_with_error_feedback,
    compression_ratio, decompress_leaf)


def test_loss_decreases(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    tcfg = TrainConfig(lr=2e-3, warmup=5, total_steps=40)
    state = init_train_state(cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    it = train_iterator(cfg, batch=8, seq=32)
    params, opt, eff = state
    losses = []
    for _ in range(30):
        params, opt, eff, m = step(params, opt, eff, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::6]


def test_grad_accum_equivalence(tiny_dense_cfg):
    """accum=2 over a pre-split batch == accum=1 over the flat batch."""
    cfg = tiny_dense_cfg
    t1 = TrainConfig(lr=1e-3, warmup=0, total_steps=10, grad_accum=1)
    t2 = dataclasses.replace(t1, grad_accum=2)
    s1 = init_train_state(cfg, t1, key=jax.random.PRNGKey(4))
    s2 = jax.tree.map(lambda x: x, s1)
    corpus = SyntheticCorpus(cfg.vocab_size)
    flat = make_batch(cfg, corpus, 0, 0, batch=8, seq=32)
    split = jax.tree.map(
        lambda x: x.reshape(2, 4, *x.shape[1:]), flat)
    step1 = jax.jit(make_train_step(cfg, t1))
    step2 = jax.jit(make_train_step(cfg, t2))
    p1, o1, e1, m1 = step1(*s1, flat)
    p2, o2, e2, m2 = step2(*s2, split)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-3)
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)


def test_compress_decompress_error_shrinks_with_rank():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 96))
    errs = []
    for r in (1, 2, 4, 8):
        c = compress_leaf(g, CompressConfig(rank=r, power_iters=8))
        errs.append(float(jnp.linalg.norm(g - decompress_leaf(c))))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < errs[0]


def test_error_feedback_is_unbiased_over_time():
    """EF invariant: Σ applied_t = Σ g_t − e_T (nothing lost forever)."""
    key = jax.random.PRNGKey(1)
    cfg = CompressConfig(rank=1, min_size=0, power_iters=6)
    g_sum = jnp.zeros((32, 48))
    applied_sum = jnp.zeros((32, 48))
    err = None
    grads = {"w": jnp.zeros((32, 48))}
    for t in range(6):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (32, 48))
        out, err = compress_with_error_feedback({"w": g}, err, cfg)
        g_sum = g_sum + g
        applied_sum = applied_sum + out["w"]
    resid = g_sum - applied_sum
    np.testing.assert_allclose(np.asarray(resid), np.asarray(err["w"]),
                               rtol=1e-3, atol=1e-3)


def test_training_with_compression_converges(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    tcfg = TrainConfig(lr=2e-3, warmup=5, total_steps=40,
                       compress_grads=True, compress_rank=2)
    params, opt, eff = init_train_state(cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    it = train_iterator(cfg, batch=8, seq=32)
    losses = []
    for _ in range(30):
        params, opt, eff, m = step(params, opt, eff, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15


def test_compression_ratio_wire_accounting():
    r = compression_ratio((1024, 1024), CompressConfig(rank=4))
    # rank-4: 4*(nm/8 + 4(n+m)) vs 4nm  ->  ~1/8 + eps
    assert 0.10 < r < 0.16
    assert compression_ratio((128,), CompressConfig(rank=4)) == 1.0
