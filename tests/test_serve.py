"""Serving sampling / generate-loop tests + the deprecated BatchServer
shim (wave admission over InferenceEngine). Scheduler invariants and
continuous-batching coverage live in test_engine.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import BatchServer, Request, ServeConfig
from repro.serve.engine import generate, sample_token


@pytest.fixture(scope="module")
def served_model():
    cfg = configs.get_smoke("llama3.2-1b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_greedy_sampling_is_argmax():
    logits = jnp.asarray([[[0.1, 3.0, -1.0, 0.0]]])
    tok = sample_token(logits, jax.random.PRNGKey(0),
                       ServeConfig(greedy=True))
    assert int(tok[0, 0]) == 1


def test_topk_restricts_support():
    logits = jnp.asarray([[[10.0, 9.0, -50.0, -50.0]]])
    scfg = ServeConfig(top_k=2, temperature=1.0)
    for seed in range(20):
        tok = sample_token(logits, jax.random.PRNGKey(seed), scfg)
        assert int(tok[0, 0]) in (0, 1)


def test_generate_shapes(served_model):
    cfg, params = served_model
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    gen, logits = generate(params, cfg, toks,
                           ServeConfig(max_new_tokens=5))
    assert gen.shape == (2, 5)
    assert int(gen.max()) < cfg.vocab_size and int(gen.min()) >= 0


def test_batch_server_completes_all(served_model):
    cfg, params = served_model
    srv = BatchServer(params, cfg, ServeConfig(max_new_tokens=6),
                      max_batch=3, max_len=32)
    rng = np.random.default_rng(0)
    for uid in range(7):
        srv.submit(Request(uid, rng.integers(
            0, cfg.vocab_size, size=(5 + uid % 3,)).astype(np.int32),
            max_new_tokens=4 + uid % 3))
    done = srv.run()
    assert sorted(done) == list(range(7))
    for uid, r in done.items():
        assert r.output is not None
        assert 1 <= len(r.output) <= r.max_new_tokens


def test_batch_server_eos_truncation(served_model):
    cfg, params = served_model
    srv = BatchServer(params, cfg, ServeConfig(max_new_tokens=8, greedy=True),
                      max_batch=1, max_len=32)
    prompt = np.arange(4, dtype=np.int32)
    srv.submit(Request(0, prompt, max_new_tokens=8, eos_id=None))
    r = srv.run()[0]
    # determine the greedy second token and use it as eos for a new req
    eos = int(r.output[1]) if len(r.output) > 1 else None
    if eos is not None and eos != int(r.output[0]):
        srv2 = BatchServer(params, cfg,
                           ServeConfig(max_new_tokens=8, greedy=True),
                           max_batch=1, max_len=32)
        srv2.submit(Request(1, prompt, max_new_tokens=8, eos_id=eos))
        r2 = srv2.run()[1]
        assert len(r2.output) == 2
        assert int(r2.output[-1]) == eos


def test_quantized_model_serves(served_model):
    """Packed model is a drop-in for the server (paper's deployment)."""
    from repro.core.pipeline import QuantConfig, nanoquant_quantize
    from repro.data import calib_batches
    cfg, params = served_model
    calib = calib_batches(cfg, 4, 32, batch=2)
    qcfg = QuantConfig(admm_iters=4, t_pre=0, t_post=2, t_glob=0,
                       rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    srv = BatchServer(qp, cfg, ServeConfig(max_new_tokens=4), max_batch=2,
                      max_len=16)
    srv.submit(Request(0, np.arange(6, dtype=np.int32)))
    srv.submit(Request(1, np.arange(4, dtype=np.int32)))
    done = srv.run()
    assert len(done) == 2
    for r in done.values():
        assert np.isfinite(r.output).all()
