"""Paged KV cache: allocator invariants, paged-vs-rectangular greedy
token identity (incl. page-boundary edge cases, the hybrid
sliding-window ring and the MLA compressed cache), overcommit admission
(queue, never crash), decode-time preemption, page-leak regression on
uid reuse, the wave shim on a paged engine, and CPU-interpreter parity
of the Pallas gather-attention kernel against the pure-jax oracle."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_multidevice

from repro import configs
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import transformer as T
from repro.serve import (BatchServer, InferenceEngine, PagedKVState,
                         Request, ServeConfig)
from repro.serve.engine import generate
from repro.serve.paging import (cache_page_kinds, init_paged_cache,
                                kv_cache_bytes, page_kind)


@pytest.fixture(scope="module")
def served_model():
    # f32 so greedy argmax is identical across cache layouts
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-1b"),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _run(params, cfg, prompts, budgets, scfg, max_batch=2, max_len=32,
         eos=None):
    eng = InferenceEngine(params, cfg, scfg, max_batch=max_batch,
                          max_len=max_len)
    for uid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(uid, p, max_new_tokens=b,
                           eos_id=eos.get(uid) if eos else None))
    done = eng.run()
    return {u: r.output for u, r in done.items()}, eng


def _assert_paged_matches_rect(params, cfg, prompts, budgets, paged_scfg,
                               **kw):
    rect, _ = _run(params, cfg, prompts, budgets,
                   ServeConfig(greedy=True, paged=False), **kw)
    paged, eng = _run(params, cfg, prompts, budgets, paged_scfg, **kw)
    assert eng.paged
    for u in rect:
        np.testing.assert_array_equal(rect[u], paged[u])
    # drained: no slot maps anything; only the prefix index (when
    # enabled) may still hold refcount-zero cached pages
    assert not eng.kv.ref.any(), "drained engine must hold no mappings"
    assert eng.kv.used_pages == eng.kv.cached_page_count, \
        "drained engine holds non-index pages"
    return eng


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_allocator_invariants(served_model):
    cfg, _ = served_model
    kv = PagedKVState(cfg, max_batch=2, max_len=32, page_size=8,
                      n_pages=9)
    assert kv.free_pages == 8 and kv.lin_pages == 4
    ids = kv.admit(0, 9)                       # 2 pages
    assert list(ids) == ["linear"] and ids["linear"].shape == (4,)
    assert (ids["linear"][:2] > 0).all() and (ids["linear"][2:] == 0).all()
    assert 0 not in kv._slot_pages[0], "null page must never be handed out"
    assert kv.ensure(0, 15) and kv.used_pages == 2      # row 15: page 1
    assert kv.ensure(0, 16) and kv.used_pages == 3      # crosses into page 2
    ids1 = kv.admit(1, 32)                     # 4 pages
    assert kv.free_pages == 1
    assert not kv.can_admit(9)                 # 2 pages > 1 free
    assert set(ids1["linear"]).isdisjoint(set(kv.tables["linear"][0]) - {0})
    kv.release(0)
    assert (kv.tables["linear"][0] == 0).all()
    assert kv.free_pages == 4 and kv.can_admit(9)
    kv.release(1)
    assert kv.used_pages == 0 and kv.peak_used_pages == 7


def test_pool_must_fit_one_slot(served_model):
    cfg, _ = served_model
    with pytest.raises(ValueError, match="worst case"):
        PagedKVState(cfg, max_batch=2, max_len=32, page_size=8, n_pages=4)


def test_submit_rejects_unadmittable_watermark(served_model):
    """A prompt that can never clear the admission watermark is rejected
    at submit instead of stalling the queue forever."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg,
                          ServeConfig(greedy=True, page_size=8,
                                      kv_pool_pages=5, page_watermark=2),
                          max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(Request(0, np.arange(1, 25, dtype=np.int32),
                           max_new_tokens=2))
    h = eng.submit(Request(1, np.arange(1, 9, dtype=np.int32),
                           max_new_tokens=2))
    assert len(h.result()) == 2


def test_watermark_does_not_livelock_resumes(served_model):
    """Regression: a preempted resume's grown prompt may need more
    pages than submit() validated; the admission watermark must not
    gate it (only fresh work), or the engine livelocks with the whole
    pool free and nothing active."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg,
                          ServeConfig(greedy=True, page_size=8,
                                      kv_pool_pages=7, page_watermark=4),
                          max_batch=2, max_len=48)
    prompts = _prompts(cfg, [8, 8], seed=10)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=20))
    done = eng.run()
    assert eng.stats["preemptions"] >= 1
    for uid, p in enumerate(prompts):
        gen, _ = generate(params, cfg, p[None],
                          ServeConfig(max_new_tokens=20, greedy=True))
        np.testing.assert_array_equal(done[uid].output, np.asarray(gen[0]))


def test_page_kind_classification():
    assert page_kind("layers/k") == "linear"
    assert page_kind("self_layers/v") == "linear"
    assert page_kind("layers/c_kv") == "linear"
    assert page_kind("shared_attn/k") == "ring"
    assert page_kind("cross_kv/k") is None
    assert page_kind("layers/ssm") is None
    hyb = configs.get_smoke("zamba2-1.2b")
    assert cache_page_kinds(hyb, 32) == {"ring"}
    assert cache_page_kinds(configs.get_smoke("mamba2-370m"), 32) == set()


def test_pool_shapes_and_bytes(served_model):
    cfg, _ = served_model
    pool = init_paged_cache(cfg, 4, 32, n_pages=9, page_size=8)
    k = pool["layers"]["k"]
    assert k.shape[1:3] == (9, 8)
    rect = T.init_cache(cfg, 4, 32)
    assert kv_cache_bytes(pool) < kv_cache_bytes(rect)


# ---------------------------------------------------------------------------
# engine identity + page-boundary edge cases
# ---------------------------------------------------------------------------


def test_paged_identity_and_boundaries(served_model):
    """Prompt exactly k*page_size (first decode write opens a fresh
    page), decode across page boundaries, and odd lengths — all
    token-identical to the rectangular engine and the solo generate."""
    cfg, params = served_model
    lens = [8, 16, 5, 9, 12]                  # 8, 16: exactly k*page_size
    budgets = [12, 10, 6, 9, 3]               # 12 from row 8: crosses 16
    prompts = _prompts(cfg, lens)
    eng = _assert_paged_matches_rect(
        params, cfg, prompts, budgets,
        ServeConfig(greedy=True, page_size=8))
    for u, (p, b) in enumerate(zip(prompts, budgets)):
        gen, _ = generate(params, cfg, p[None],
                          ServeConfig(max_new_tokens=b, greedy=True))
        np.testing.assert_array_equal(np.asarray(gen[0]),
                                      eng.done[u].output)


def test_paged_identity_default_page_size(served_model):
    """The production default (page_size=64, clamped to max_len) is a
    drop-in: no overcommit, no behavior change."""
    cfg, params = served_model
    prompts = _prompts(cfg, [5, 9, 12, 6], seed=2)
    eng = _assert_paged_matches_rect(params, cfg, prompts, [6, 3, 8, 5],
                                     ServeConfig(greedy=True))
    assert eng.kv.page_size == 32 and eng.kv.lin_pages == 1
    assert eng.stats["preemptions"] == 0 and eng.stats["page_waits"] == 0


def test_paged_eos_and_streaming(served_model):
    cfg, params = served_model
    prompts = _prompts(cfg, [6, 8], seed=3)
    ref_out, _ = _run(params, cfg, prompts, [8, 8],
                      ServeConfig(greedy=True, paged=False))
    eos = int(ref_out[0][2])
    if eos in (int(ref_out[0][0]), int(ref_out[0][1])):
        pytest.skip("greedy output repeats; eos would hit earlier")
    paged_out, _ = _run(params, cfg, prompts, [8, 8],
                        ServeConfig(greedy=True, page_size=8),
                        eos={0: eos})
    np.testing.assert_array_equal(paged_out[0], ref_out[0][:3])
    np.testing.assert_array_equal(paged_out[1], ref_out[1])


def test_hybrid_ring_wrap_in_paged_pool():
    """Sliding-window ring (window < max_len so decode wraps the ring)
    paged: token-identical to the rectangular ring."""
    cfg = dataclasses.replace(configs.get_smoke("zamba2-1.2b"),
                              dtype="float32", sliding_window=16)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, [5, 7], seed=4)
    # pos reaches 5+26=31 >= virtual ring 16 -> wraps several times
    eng = _assert_paged_matches_rect(
        params, cfg, prompts, [26, 20],
        ServeConfig(greedy=True, page_size=8))
    assert eng.kv.has_ring and not eng.kv.has_linear
    assert eng.kv.ring_pages == 2


def test_mla_paged_identity():
    cfg = dataclasses.replace(configs.get_smoke("deepseek-v2-lite-16b"),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, [5, 9, 12], seed=5)
    _assert_paged_matches_rect(params, cfg, prompts, [6, 4, 8],
                               ServeConfig(greedy=True, page_size=8))


def test_ssm_family_falls_back_rectangular():
    cfg = dataclasses.replace(configs.get_smoke("mamba2-370m"),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=2, max_len=16)
    assert not eng.paged and eng.kv is None
    eng.submit(Request(0, np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=3))
    assert len(eng.run()[0].output) == 3


# ---------------------------------------------------------------------------
# overcommit: admission queueing, preemption, leak regression
# ---------------------------------------------------------------------------


def test_pool_exhaustion_queues_without_crash(served_model):
    """A pool half the rectangle: admission gates on free pages (FIFO
    head-of-line), everything still completes token-identically."""
    cfg, params = served_model
    lens = [6, 9, 5, 7, 11, 4]
    budgets = [20, 18, 15, 12, 10, 16]
    prompts = _prompts(cfg, lens, seed=6)
    eng = _assert_paged_matches_rect(
        params, cfg, prompts, budgets,
        ServeConfig(greedy=True, page_size=4, kv_pool_pages=12),
        max_batch=3)
    assert eng.stats["page_waits"] > 0, "the pool never gated admission"
    assert eng.kv.peak_used_pages <= eng.kv.n_pages - 1


def test_decode_exhaustion_preempts_youngest(served_model):
    """Two slots admitted cheap, then both grow: the pool runs dry
    mid-decode, a victim is preempted (requeued, re-prefilled) and
    every output still matches the solo generate loop. With equal
    recompute costs (identical prompt lengths and lockstep positions,
    prefix cache off) the cost-aware policy degenerates to
    youngest-first — the tie-break scheduler.pick_preemption_victim
    guarantees."""
    cfg, params = served_model
    prompts = _prompts(cfg, [4, 4], seed=7)
    out, eng = _run(params, cfg, prompts, [24, 24],
                    ServeConfig(greedy=True, page_size=4,
                                kv_pool_pages=9, prefix_cache=False),
                    max_len=32)
    assert eng.stats["preemptions"] >= 1
    # youngest-first: the first-admitted request is never evicted (its
    # admission step never moves), the younger one is re-admitted later
    assert eng.admission_step[0] == 0
    assert eng.admission_step[1] > 0
    for u, p in enumerate(prompts):
        gen, _ = generate(params, cfg, p[None],
                          ServeConfig(max_new_tokens=24, greedy=True))
        np.testing.assert_array_equal(out[u], np.asarray(gen[0]))
    assert eng.kv.used_pages == 0


def test_uid_reuse_cannot_leak_pages_or_read_stale_tables(served_model):
    """Regression (satellite): completion frees the slot's pages and
    zeroes its block-table rows; reusing the uid after clear_finished()
    allocates fresh pages and reproduces the fresh-engine output."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg,
                          ServeConfig(greedy=True, page_size=4,
                                      prefix_cache=False),
                          max_batch=1, max_len=32)
    p = _prompts(cfg, [9], seed=8)[0]
    first = eng.submit(Request(0, p, max_new_tokens=6)).result()
    assert eng.kv.used_pages == 0, "completion must free pages"
    assert all((t == 0).all() for t in eng.kv.tables.values()), \
        "stale block-table rows survived completion"
    eng.clear_finished()
    assert not eng.done and eng.kv.used_pages == 0
    again = eng.submit(Request(0, p, max_new_tokens=6)).result()
    np.testing.assert_array_equal(first, again)
    # prompt 9 rows + 6 generated = 15 rows -> never more than 4 pages
    assert eng.kv.used_pages == 0 and eng.kv.peak_used_pages == 4


def test_wave_shim_runs_on_paged_engine(served_model):
    """Satellite: the deprecated BatchServer drives whichever cache
    layout the engine was built with — paged (default) and rectangular
    waves produce identical greedy outputs."""
    cfg, params = served_model
    prompts = _prompts(cfg, [4, 11, 7, 9], seed=9)
    budgets = [5, 2, 7, 4]
    outs = {}
    for name, scfg in (("paged", ServeConfig(greedy=True, page_size=8)),
                       ("rect", ServeConfig(greedy=True, paged=False))):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            srv = BatchServer(params, cfg, scfg, max_batch=2, max_len=32)
        for uid, (p, b) in enumerate(zip(prompts, budgets)):
            srv.submit(Request(uid, p, max_new_tokens=b))
        outs[name] = srv.run()
    assert srv.engine.paged is False
    for uid in range(len(prompts)):
        np.testing.assert_array_equal(outs["paged"][uid].output,
                                      outs["rect"][uid].output)


# ---------------------------------------------------------------------------
# Pallas gather kernel: CPU-interpreter parity vs the pure-jax oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,D,NP,PS,pages,window,ring", [
    (3, 4, 2, 16, 9, 8, 4, 0, False),        # GQA, linear
    (2, 8, 8, 16, 17, 4, 6, 0, False),       # MHA, many small pages
    (2, 4, 2, 16, 9, 8, 2, 6, True),         # sliding-window ring wrap
    (1, 4, 4, 32, 5, 16, 3, 10, False),      # windowed linear
])
def test_paged_kernel_matches_ref(B, Hq, Hkv, D, NP, PS, pages, window,
                                  ring):
    rng = np.random.default_rng(B * 100 + pages)
    kp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, NP, size=(B, pages)), jnp.int32)
    rows = pages * PS
    q_pos = jnp.asarray(rng.integers(1, rows + 20, size=(B,)), jnp.int32)
    cache_pos = q_pos % rows if ring else jnp.minimum(q_pos, rows - 1)
    want = ref.paged_attention_ref(q, kp, vp, bt, q_pos, cache_pos,
                                   window=window, scale=0.125)
    got = paged_decode_attention(q, kp, vp, bt, q_pos, cache_pos,
                                 window=window, scale=0.125,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_ref_matches_rectangular_sdpa():
    """The gather oracle == attention over the equivalent rectangle."""
    from repro.models import layers as L
    rng = np.random.default_rng(3)
    B, Hq, Hkv, D, PS, pages = 2, 4, 2, 8, 4, 3
    rows = pages * PS
    # build a rectangle, then scatter it into pages per a block table
    k_rect = jnp.asarray(rng.standard_normal((B, rows, Hkv, D)), jnp.float32)
    v_rect = jnp.asarray(rng.standard_normal((B, rows, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    NP = B * pages + 1
    bt = np.zeros((B, pages), np.int32)
    kp = np.zeros((NP, PS, Hkv, D), np.float32)
    vp = np.zeros((NP, PS, Hkv, D), np.float32)
    page = 1
    for b in range(B):
        for j in range(pages):
            bt[b, j] = page
            kp[page] = np.asarray(k_rect[b, j * PS:(j + 1) * PS])
            vp[page] = np.asarray(v_rect[b, j * PS:(j + 1) * PS])
            page += 1
    q_pos = jnp.asarray([5, rows - 1], jnp.int32)
    msk = L._decode_mask(q_pos[:, None], q_pos, rows, 0)
    want = L.sdpa(q, k_rect, v_rect, msk, 0.3)
    got = ref.paged_attention_ref(q, jnp.asarray(kp), jnp.asarray(vp),
                                  jnp.asarray(bt), q_pos, q_pos,
                                  window=0, scale=0.3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# S > 1 verify reads (the speculative k+1 forward) on the paged kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,window,ring", [
    (2, 0, False),                           # shortest multi-token span
    (3, 6, True),                            # windowed, span wraps the ring
    (4, 10, True),
    (5, 0, False),                           # k=4 verify (k+1 queries)
])
def test_paged_kernel_multitoken_matches_ref(S, window, ring):
    """S>1 spans through the shipped S>1 dispatch (ops.paged_attention:
    S shifted single-token launches) against the oracle's joint
    reconstruction, including sliding-window ring wrap under S>1."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(20 + S)
    B, Hq, Hkv, D, PS, pages = 2, 4, 2, 16, 4, 3
    NP = B * pages + 1
    rows = pages * PS
    kp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    # writable pages exclusive per slot (kernels.ref.decode_step_ref)
    bt = jnp.asarray(np.arange(1, NP).reshape(B, pages), jnp.int32)
    if ring:
        q_pos = jnp.asarray(rng.integers(rows, 2 * rows - S, B), jnp.int32)
        cache_pos = q_pos % rows
    else:
        q_pos = jnp.asarray(rng.integers(0, rows - S, B), jnp.int32)
        cache_pos = q_pos
    pol = kops.KernelPolicy(mode="pallas", interpret=True)
    got = kops.paged_attention(q, kp, vp, bt, q_pos, cache_pos,
                               window=window, scale=0.125, policy=pol)
    want = ref.paged_attention_ref(q, kp, vp, bt, q_pos, cache_pos,
                                   window=window, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_multitoken_exact_page_boundary():
    """Exact page-boundary spans for pos+k verify reads: slot 0's
    4-token span is exactly one full page (rows 4..7 of page 1), slot
    1's starts on the last row of page 0 and crosses into page 1."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(7)
    B, S, Hq, Hkv, D, PS, pages = 2, 4, 4, 2, 16, 4, 3
    NP = B * pages + 1
    kp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, PS, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    bt = jnp.asarray(np.arange(1, NP).reshape(B, pages), jnp.int32)
    q_pos = jnp.asarray([PS, PS - 1], jnp.int32)
    pol = kops.KernelPolicy(mode="pallas", interpret=True)
    got = kops.paged_attention(q, kp, vp, bt, q_pos, q_pos,
                               scale=0.25, policy=pol)
    want = ref.paged_attention_ref(q, kp, vp, bt, q_pos, q_pos, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # ... and per-token sequential equivalence at the same positions
    for j in range(S):
        want_j = ref.paged_attention_ref(q[:, j:j + 1], kp, vp, bt,
                                         q_pos + j, q_pos + j, scale=0.25)
        np.testing.assert_allclose(np.asarray(got[:, j:j + 1]),
                                   np.asarray(want_j),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tensor-parallel paged engine (forced host devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_paged_tp_engine_token_identity():
    """Satellite: paged pool + 2-way tensor parallelism (pool kv-head
    dim sharded per sharding.rules, cache_pspecs(paged=True)) is greedy
    token-identical to the *rectangular unsharded* engine — mirroring
    test_engine.py::test_sharded_engine_token_identity but crossing
    both the layout and the mesh axis at once."""
    out = run_multidevice("""
        import dataclasses, jax, numpy as np
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as T
        from repro.models.config import ModelConfig
        from repro.serve.engine import InferenceEngine, ServeConfig
        from repro.serve.scheduler import Request

        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=256, loss_chunk=0, remat=False,
                          dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [np.arange(1, 7, dtype=np.int32),
                   np.arange(3, 12, dtype=np.int32),
                   np.arange(2, 10, dtype=np.int32)]
        budgets = [6, 3, 5]

        def run(scfg, mesh):
            eng = InferenceEngine(params, cfg, scfg, max_batch=2,
                                  max_len=32, mesh=mesh)
            for uid, (p, b) in enumerate(zip(prompts, budgets)):
                eng.submit(Request(uid, p, max_new_tokens=b))
            return {u: r.output for u, r in eng.run().items()}, eng

        ref, _ = run(ServeConfig(greedy=True, paged=False), None)
        got, eng = run(ServeConfig(greedy=True, page_size=8),
                       make_serving_mesh(2))
        assert eng.paged and eng.mesh is not None
        # the page pool really is kv-head-sharded on the model axis
        # (trailing None may be trimmed from the spec)
        spec = tuple(eng.cache["layers"]["k"].sharding.spec)
        assert spec[:4] == (None, None, None, "model"), spec
        for u in ref:
            np.testing.assert_array_equal(ref[u], got[u])
        print("paged TP token-identity OK")
    """, devices=2)
    assert "OK" in out
