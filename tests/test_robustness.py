"""Serving-tier robustness: deadlines, cancellation, terminal
statuses, failure isolation (poison requests, injected device errors),
graceful drain + snapshot/restore, page-accounting audits, and the
deterministic fault-injection harness (docs/serving.md §Failure
handling)."""
import dataclasses
import functools
import os

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serve import (Fault, FaultPlan, InferenceEngine,
                         PageAccountingError, Request, RequestError,
                         ServeConfig, TERMINAL_STATUSES, recovery)


@functools.lru_cache(maxsize=1)
def _model():
    # f32 so greedy argmax is identical across batch compositions —
    # the survivor-identity assertions compare against a fault-free run
    cfg = ModelConfig(name="tiny", family="dense", d_model=64,
                      n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, loss_chunk=0, remat=False,
                      dtype="float32")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _engine(cfg, params, faults=None, clock=None, max_batch=2,
            max_len=32, **scfg_kw):
    scfg = ServeConfig(greedy=True, page_size=4, debug=True, **scfg_kw)
    return InferenceEngine(params, cfg, scfg, max_batch=max_batch,
                           max_len=max_len, faults=faults, clock=clock)


def _assert_no_leaks(eng):
    eng.check_invariants()
    assert eng.kv.used_pages == eng.kv.cached_page_count
    if eng.prefix is not None:
        eng.prefix.clear()
        assert eng.kv.used_pages == 0


# ---- satellite: handle lifecycle (statuses, cancel, iter, result) -------


def test_cancel_while_queued():
    cfg, params = _model()
    eng = _engine(cfg, params, max_batch=1)
    p1, p2 = _prompts(cfg, [6, 6])
    h1 = eng.submit(Request(0, p1, max_new_tokens=4))
    h2 = eng.submit(Request(1, p2, max_new_tokens=4))
    h2.cancel()
    eng.run()
    assert h1.status == "done" and h1.done and h1.finished
    assert h2.status == "cancelled" and not h2.done and h2.finished
    assert h2.tokens == []                   # never admitted
    with pytest.raises(RequestError, match="request 1 cancelled"):
        h2.result()
    assert h2.error.uid == 1 and h2.error.status == "cancelled"
    assert eng.stats["cancelled"] == 1
    _assert_no_leaks(eng)


def test_cancel_active_keeps_partial_output():
    cfg, params = _model()
    eng = _engine(cfg, params, max_batch=1)
    [p] = _prompts(cfg, [6])
    h = eng.submit(Request(0, p, max_new_tokens=16))
    for _ in range(4):
        eng.step()
    assert h.status == "running" and len(h.tokens) >= 2
    h.cancel("user closed the stream")
    eng.run()
    assert h.status == "cancelled"
    # partial output stays readable on the handle and the request
    assert len(h.tokens) >= 2
    assert np.array_equal(h.request.output, np.asarray(h.tokens))
    with pytest.raises(RequestError, match="user closed the stream"):
        h.result()
    _assert_no_leaks(eng)


def test_handle_reiteration_replays():
    cfg, params = _model()
    eng = _engine(cfg, params, max_batch=1)
    [p] = _prompts(cfg, [5])
    h = eng.submit(Request(0, p, max_new_tokens=5))
    first = list(h)
    again = list(h)                          # restarts from token 0
    assert first == again == list(h.result())
    assert len(first) == 5


def test_iterating_failed_handle_raises_at_exhaustion():
    cfg, params = _model()
    eng = _engine(cfg, params, max_batch=1)
    p1, p2 = _prompts(cfg, [6, 6])
    h1 = eng.submit(Request(0, p1, max_new_tokens=3))
    h2 = eng.submit(Request(1, p2, max_new_tokens=3))
    h2.cancel()
    eng.run()
    assert list(h1) == list(h1.result())
    it = iter(h2)
    with pytest.raises(RequestError, match="cancelled"):
        list(it)


def test_deadline_expiry_with_injected_clock():
    cfg, params = _model()
    t = [0.0]
    eng = _engine(cfg, params, clock=lambda: t[0], max_batch=1)
    [p] = _prompts(cfg, [6])
    h = eng.submit(Request(0, p, max_new_tokens=32, deadline_s=10.0))
    for _ in range(3):
        eng.step()
    assert h.status == "running"
    t[0] = 11.0                              # past the deadline
    eng.step()                               # reaped at the tick boundary
    assert h.status == "expired"
    assert len(h.tokens) >= 2                # partial output survives
    with pytest.raises(RequestError, match="deadline 10.0s exceeded"):
        h.result()
    assert eng.stats["expired"] == 1
    assert not eng.in_flight
    _assert_no_leaks(eng)


def test_submit_validation():
    cfg, params = _model()
    eng = _engine(cfg, params)
    [p] = _prompts(cfg, [4])
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(Request(0, p, max_new_tokens=2, deadline_s=-1.0))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(1, np.asarray([0, cfg.vocab_size], np.int32),
                           max_new_tokens=2))
    h = eng.submit(Request(2, p, max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate request uid"):
        eng.submit(Request(2, p, max_new_tokens=2))
    h.cancel()
    eng.run()
    # uid reuse is fine once the old request reached a terminal status
    h2 = eng.submit(Request(2, p, max_new_tokens=2))
    eng.run()
    assert h2.status == "done"


# ---- tentpole: failure isolation ----------------------------------------


def test_poison_request_is_isolated():
    cfg, params = _model()
    prompts = _prompts(cfg, [6, 7, 8])
    base = _engine(cfg, params)
    for uid, p in enumerate(prompts):
        base.submit(Request(uid, p, max_new_tokens=6))
    base_out = {u: r.output for u, r in base.run().items()}

    plan = FaultPlan([Fault(step=0, kind="poison_prefill", uid=1)])
    eng = _engine(cfg, params, faults=plan)
    hs = [eng.submit(Request(uid, p, max_new_tokens=6))
          for uid, p in enumerate(prompts)]
    eng.run()
    assert hs[1].status == "failed"
    assert "non-finite" in hs[1].error.reason
    assert hs[0].status == hs[2].status == "done"
    for u in (0, 2):                         # neighbours undisturbed
        assert np.array_equal(base_out[u], eng.done[u].output)
    assert eng.stats["failed"] == 1
    _assert_no_leaks(eng)


def test_device_error_recovery():
    cfg, params = _model()
    prompts = _prompts(cfg, [6, 7, 8])
    base = _engine(cfg, params)
    for uid, p in enumerate(prompts):
        base.submit(Request(uid, p, max_new_tokens=8))
    base_out = {u: r.output for u, r in base.run().items()}

    plan = FaultPlan([Fault(step=2, kind="device_error", uid=0)])
    eng = _engine(cfg, params, faults=plan)
    hs = [eng.submit(Request(uid, p, max_new_tokens=8))
          for uid, p in enumerate(prompts)]
    eng.run()
    assert eng.stats["device_faults"] == 1
    assert hs[0].status == "failed"
    assert "device error" in hs[0].error.reason
    # the other slots were preempted and resumed token-identically
    for u in (1, 2):
        assert hs[u].status == "done"
        assert np.array_equal(base_out[u], eng.done[u].output)
    _assert_no_leaks(eng)


def test_page_accounting_error_is_engine_fatal():
    cfg, params = _model()
    eng = _engine(cfg, params)
    [p] = _prompts(cfg, [6])
    eng.submit(Request(0, p, max_new_tokens=4))
    eng.step()
    # corrupt the pool deliberately: a page owned by a live table also
    # pushed onto the free list must trip the audit, not be isolated
    owned = next(pages for pages in eng.kv._slot_pages if pages)
    eng.kv._free.append(owned[0])
    with pytest.raises(PageAccountingError):
        eng.check_invariants()


# ---- tentpole: graceful drain + snapshot/restore ------------------------


def test_drain_completes_active_and_closes_admission():
    cfg, params = _model()
    eng = _engine(cfg, params, max_batch=2)
    prompts = _prompts(cfg, [6, 7, 8])
    hs = [eng.submit(Request(uid, p, max_new_tokens=4))
          for uid, p in enumerate(prompts)]
    eng.step()                               # admit the first two
    done = eng.drain()                       # no timeout: finish active
    assert hs[0].status == hs[1].status == "done"
    assert hs[2].status == "pending"         # queued, never admitted
    assert set(done) == {0, 1}
    eng.resume_admission()
    eng.run()
    assert hs[2].status == "done"


def test_drain_snapshot_restore_token_identity(tmp_path):
    cfg, params = _model()
    prompts = _prompts(cfg, [6, 7, 8, 9])
    base = _engine(cfg, params)
    for uid, p in enumerate(prompts):
        base.submit(Request(uid, p, max_new_tokens=8))
    base_out = {u: r.output for u, r in base.run().items()}

    t = [0.0]
    eng = _engine(cfg, params, clock=lambda: t[0])
    hs = [eng.submit(Request(uid, p, max_new_tokens=8,
                             deadline_s=100.0 if uid == 0 else None))
          for uid, p in enumerate(prompts)]
    for _ in range(3):
        eng.step()
    t[0] = 40.0
    done_before = eng.drain(timeout=0)       # preempt whatever is live
    snap = recovery.snapshot(eng)
    # remaining deadline budget carries over, not the absolute deadline
    rec0 = next(it for it in snap["items"] if it["uid"] == 0)
    assert rec0["deadline_left_s"] == pytest.approx(60.0)
    path = os.path.join(str(tmp_path), "snap.json")
    recovery.save_snapshot(eng, path)
    assert recovery.load_snapshot(path)["items"] == snap["items"]

    eng2 = _engine(cfg, params, clock=lambda: t[0])
    restored = recovery.restore(eng2, snap)
    assert set(restored) == {u for u, h in enumerate(hs)
                             if not h.finished}
    done_after = eng2.run()
    for u in range(len(prompts)):
        out = (done_before.get(u) or done_after[u]).output
        assert np.array_equal(base_out[u], out), f"request {u} diverged"
    _assert_no_leaks(eng2)


def test_restore_rejects_wrong_geometry(tmp_path):
    cfg, params = _model()
    eng = _engine(cfg, params, max_len=32)
    eng.submit(Request(0, _prompts(cfg, [6])[0], max_new_tokens=8))
    eng.drain(timeout=0)
    snap = recovery.snapshot(eng)
    small = _engine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        recovery.restore(small, snap)


# ---- tentpole: deterministic fault injection ----------------------------


def test_fault_plan_validates_and_is_deterministic():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(step=0, kind="meteor_strike")
    a = FaultPlan.random(seed=5, uids=range(8), n_steps=20)
    b = FaultPlan.random(seed=5, uids=range(8), n_steps=20)
    assert a.faults == b.faults
    assert a.faults != FaultPlan.random(seed=6, uids=range(8),
                                        n_steps=20).faults


def test_fault_replay_is_bit_for_bit():
    cfg, params = _model()
    prompts = _prompts(cfg, [5, 6, 7, 8], seed=3)

    def chaos():
        plan = FaultPlan([Fault(step=0, kind="cancel", uid=2),
                          Fault(step=1, kind="dry_pool", pages=2, hold=2),
                          Fault(step=2, kind="preempt", pages=1)], seed=5)
        eng = _engine(cfg, params, faults=plan, kv_pool_pages=10)
        hs = [eng.submit(Request(uid, p, max_new_tokens=6))
              for uid, p in enumerate(prompts)]
        while eng.in_flight or plan.borrowed_pages:
            eng.step()
        return plan.fired, [h.status for h in hs], \
            [list(h.tokens) for h in hs]

    assert chaos() == chaos()


# ---- satellite: randomized lifecycle property trace ---------------------


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_randomized_lifecycle_trace(seed):
    """Random {submit, cancel, deadline-expire, preempt, drain} trace:
    page accounting and slot alignment hold after every event, every
    handle reaches a terminal status, and zero pages leak at quiesce."""
    cfg, params = _model()
    t = [0.0]
    eng = _engine(cfg, params, clock=lambda: t[0], max_batch=2,
                  max_len=24, kv_pool_pages=10)
    rng = np.random.default_rng(seed)
    handles, next_uid = {}, 0
    for _ in range(30):
        act = int(rng.integers(0, 6))
        if act <= 1 and next_uid < 8:        # submit (weighted)
            n = int(rng.integers(1, 12))
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=(n,)).astype(np.int32)
            deadline = (float(rng.integers(1, 40))
                        if rng.integers(0, 2) else None)
            handles[next_uid] = eng.submit(
                Request(next_uid, prompt,
                        max_new_tokens=int(rng.integers(1, 10)),
                        deadline_s=deadline))
            next_uid += 1
        elif act == 2 and handles:           # cancel a random request
            handles[int(rng.choice(list(handles)))].cancel()
        elif act == 3:                       # advance the deadline clock
            t[0] += float(rng.integers(0, 25))
        elif act == 4 and eng.active.any():  # forced preemption
            eng._preempt(eng._select_victim())
        elif act == 5:                       # drain burst, then reopen
            eng.drain(timeout=0)
            eng.resume_admission()
        eng.step()
        eng.check_invariants()               # audited after every event
    eng.run()
    assert all(h.finished for h in handles.values())
    assert all(h.status in TERMINAL_STATUSES for h in handles.values())
    done = sum(h.status == "done" for h in handles.values())
    assert (done + eng.stats["cancelled"] + eng.stats["expired"]
            + eng.stats["failed"] == len(handles))
    _assert_no_leaks(eng)


# ---- chaos storms (the heavier seeded runs) -----------------------------


@pytest.mark.chaos
def test_random_fault_storm_quiesces_clean():
    """A dense seeded FaultPlan.random storm over every fault kind:
    the engine must keep accounting exact (debug tick audits), land
    every handle on a terminal status and leak nothing."""
    cfg, params = _model()
    rng = np.random.default_rng(17)
    prompts = _prompts(cfg, list(rng.integers(4, 12, size=10)), seed=17)
    plan = FaultPlan.random(seed=17, uids=range(len(prompts)),
                            n_steps=12, n_faults=16)
    eng = _engine(cfg, params, faults=plan, max_batch=3, max_len=24)
    hs = [eng.submit(Request(uid, p, max_new_tokens=8))
          for uid, p in enumerate(prompts)]
    while eng.in_flight or plan.borrowed_pages:
        eng.step()
    assert all(h.finished for h in hs)
    for h in hs:
        if h.status != "done":
            assert isinstance(h.error, RequestError)
    _assert_no_leaks(eng)


@pytest.mark.chaos
def test_preemption_storm_token_identity():
    """A preemption fault every step must never change greedy outputs
    — resume is re-prefill of prompt+emitted, token-exact."""
    cfg, params = _model()
    prompts = _prompts(cfg, [6, 7, 8, 9], seed=23)
    base = _engine(cfg, params)
    for uid, p in enumerate(prompts):
        base.submit(Request(uid, p, max_new_tokens=8))
    base_out = {u: r.output for u, r in base.run().items()}

    plan = FaultPlan([Fault(step=s, kind="preempt", pages=1)
                      for s in range(1, 30, 2)])
    eng = _engine(cfg, params, faults=plan)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid, p, max_new_tokens=8))
    done = eng.run()
    assert eng.stats["preemptions"] >= 4
    for u, r in done.items():
        assert np.array_equal(base_out[u], r.output)
    _assert_no_leaks(eng)
