"""Synthetic data pipeline: determinism + family shapes."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.data import SyntheticCorpus, calib_batches, make_batch, \
    train_iterator


def test_batches_deterministic_in_seed_step():
    cfg = configs.get_smoke("llama3.2-1b")
    corpus = SyntheticCorpus(cfg.vocab_size)
    a = make_batch(cfg, corpus, seed=5, step=17, batch=4, seq=16)
    b = make_batch(cfg, corpus, seed=5, step=17, batch=4, seq=16)
    c = make_batch(cfg, corpus, seed=5, step=18, batch=4, seq=16)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_resume_skip_equivalence():
    """Iterator restarted at step k produces the same stream — the
    deterministic data skip behind checkpoint/restart."""
    cfg = configs.get_smoke("llama3.2-1b")
    it0 = train_iterator(cfg, batch=2, seq=8, seed=3)
    stream = [next(it0) for _ in range(6)]
    it1 = train_iterator(cfg, batch=2, seq=8, seed=3, start_step=4)
    np.testing.assert_array_equal(np.asarray(stream[4]["tokens"]),
                                  np.asarray(next(it1)["tokens"]))
    np.testing.assert_array_equal(np.asarray(stream[5]["tokens"]),
                                  np.asarray(next(it1)["tokens"]))


def test_labels_are_shifted_continuation():
    cfg = configs.get_smoke("llama3.2-1b")
    corpus = SyntheticCorpus(cfg.vocab_size)
    b = make_batch(cfg, corpus, 0, 0, batch=2, seq=16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_family_shapes():
    for arch, extra in [("musicgen-medium", "audio"),
                        ("llama-3.2-vision-90b", "vlm")]:
        cfg = configs.get_smoke(arch)
        corpus = SyntheticCorpus(cfg.vocab_size)
        b = make_batch(cfg, corpus, 0, 0, batch=2, seq=8)
        if extra == "audio":
            assert b["tokens"].shape == (2, 8, cfg.n_codebooks)
        if extra == "vlm":
            assert b["image_embeds"].shape == (2, cfg.n_image_tokens,
                                               cfg.d_model)


def test_corpus_has_learnable_structure():
    """Markov structure: bigram entropy must be well below uniform."""
    corpus = SyntheticCorpus(256, seed=0)
    rng = np.random.default_rng(0)
    stream = corpus.sample(rng, 4, 4000)
    # empirical conditional entropy via bigram counts
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in stream:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    ent, tot = 0.0, 0
    for a, cnt in succ.items():
        n = sum(cnt.values())
        for b, c in cnt.items():
            p = c / n
            ent -= c * np.log2(p)
        tot += n
    ent /= tot
    assert ent < 6.5          # uniform would be log2(256) = 8


def test_calib_batches_count():
    cfg = configs.get_smoke("llama3.2-1b")
    bs = calib_batches(cfg, n_samples=16, seq=32, batch=4)
    assert len(bs) == 4
    assert bs[0]["tokens"].shape == (4, 32)
