"""LB-ADMM (paper §3.2 Step 2-2, App. B) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.admm import ADMMConfig, lb_admm, _chol_solve_ridge
from repro.core.balance import magnitude_balance, reconstruct


def test_chol_solve_matches_direct():
    key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, (32, 8))
    gram = v.T @ v
    rhs = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    shift = 0.7
    x = _chol_solve_ridge(gram, rhs, shift)
    ref = jnp.linalg.solve(gram + (shift + 1e-8) * jnp.eye(8), rhs)
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(r=st.integers(2, 12), rho=st.floats(0.01, 10.0),
       lam=st.floats(0.0, 1.0), seed=st.integers(0, 50))
def test_subproblem_spd_and_conditioning_bound(r, rho, lam, seed):
    """App. B Lemma 2 + Corollary 2: H = VᵀV + (ρ+λ)I is SPD and
    κ(H) <= 1 + ‖V‖²/(ρ+λ)."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (3 * r, r))
    h = v.T @ v + (rho + lam) * jnp.eye(r)
    evals = jnp.linalg.eigvalsh(h)
    assert float(evals[0]) > 0.0
    kappa = float(evals[-1] / evals[0])
    bound = 1.0 + float(jnp.linalg.norm(v, 2) ** 2) / (rho + lam)
    assert kappa <= bound * (1 + 1e-4)


def test_lb_admm_recovers_planted_factorization():
    """W built exactly as s1 ⊙ (U±1 V±1ᵀ) ⊙ s2 must be recovered to high
    fidelity by LB-ADMM + magnitude balancing at the same rank."""
    key = jax.random.PRNGKey(7)
    ku, kv, k1, k2 = jax.random.split(key, 4)
    m, n, r = 48, 64, 8
    u = jnp.sign(jax.random.normal(ku, (m, r)))
    v = jnp.sign(jax.random.normal(kv, (n, r)))
    s1 = jnp.abs(jax.random.normal(k1, (m,))) + 0.5
    s2 = jnp.abs(jax.random.normal(k2, (n,))) + 0.5
    w = (s1[:, None] * u) @ (v.T * s2[None, :])

    res = lb_admm(w, ADMMConfig(rank=r, iters=60))
    ones = jnp.ones
    lu, lv, s1h, s2h = magnitude_balance(res["p_u"], res["p_v"],
                                         ones((m,)), ones((n,)))
    w_hat = reconstruct(lu, lv, s1h, s2h)
    rel = float(jnp.linalg.norm(w - w_hat) / jnp.linalg.norm(w))
    assert rel < 0.35, rel          # strong recovery of planted structure


def test_lb_admm_beats_sign_baseline():
    """On a random dense matrix, LB-ADMM's balanced reconstruction must
    beat naive full-rank XNOR-style binarization in weighted error at
    matched storage? — at rank r it must at least beat a random binary
    factorization of the same rank."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (40, 56))
    r = 12
    res = lb_admm(w, ADMMConfig(rank=r, iters=50))
    ones = jnp.ones
    lu, lv, s1, s2 = magnitude_balance(res["p_u"], res["p_v"],
                                       ones((40,)), ones((56,)))
    err = float(jnp.linalg.norm(w - reconstruct(lu, lv, s1, s2)))
    ku, kv = jax.random.split(key)
    ru = jnp.sign(jax.random.normal(ku, (40, r)))
    rv = jnp.sign(jax.random.normal(kv, (56, r)))
    alpha = jnp.mean(jnp.abs(w)) / r
    rand_err = float(jnp.linalg.norm(w - alpha * (ru @ rv.T)))
    assert err < rand_err


def test_consensus_engages():
    """The scale-free penalty ramp must pull the continuous factors onto
    the SVID (sign-value) structure by the final iterations: the
    consensus gap ‖U − Z_U‖/‖U‖ ends small, and the proxy product is a
    usable reconstruction (not the diverged duals of a mis-scaled ρ)."""
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (32, 32))
    res = lb_admm(w, ADMMConfig(rank=8, iters=40))
    tr = np.asarray(res["residual_trace"])
    assert np.isfinite(tr).all()
    gap_u = float(jnp.linalg.norm(res["u"] - res["z_u"])
                  / jnp.linalg.norm(res["u"]))
    gap_v = float(jnp.linalg.norm(res["v"] - res["z_v"])
                  / jnp.linalg.norm(res["v"]))
    assert gap_u < 0.25 and gap_v < 0.25, (gap_u, gap_v)
    proxy_err = float(jnp.linalg.norm(w - res["z_u"] @ res["z_v"].T)
                      / jnp.linalg.norm(w))
    cont_err = float(tr[-1])
    assert proxy_err < cont_err + 0.25, (proxy_err, cont_err)


# ---------------------------------------------------------------------------
# health monitoring (docs/quantization.md §ADMM guards)
# ---------------------------------------------------------------------------


def test_health_clean_on_wellposed_problem():
    """Healthy solve: no resets, rho untouched, not diverged — and the
    guards must not perturb the numerics of the accepted path."""
    w = jax.random.normal(jax.random.PRNGKey(3), (48, 64))
    res = lb_admm(w, ADMMConfig(rank=8, iters=30))
    h = res["health"]
    assert int(h["resets"]) == 0
    assert float(h["rho_scale"]) == 1.0
    assert not bool(h["diverged"])
    assert not bool(h["nonfinite"])
    assert np.isfinite(np.asarray(res["residual_trace"])).all()


def test_health_flags_nonfinite_input():
    """A poisoned W (NaN) must be detected — every step rejected, rho
    escalation bounded, diverged flagged — instead of NaN factors
    silently flowing into packing."""
    w = jnp.full((32, 32), jnp.nan)
    cfg = ADMMConfig(rank=4, iters=12, rho_scale_max=16.0)
    res = lb_admm(w, cfg)
    h = res["health"]
    assert bool(h["diverged"])
    assert bool(h["nonfinite"])
    assert int(h["resets"]) >= 1
    # bounded escalation: the adapted rho never exceeds the configured cap
    assert float(h["rho_scale"]) <= cfg.rho_scale_max


def test_quantization_error_is_structured():
    from repro.core.admm import QuantizationError
    e = QuantizationError(layer="attn.wq", block="layers[3]",
                          iteration=17, reason="objective diverged")
    assert e.layer == "attn.wq"
    assert e.block == "layers[3]"
    assert e.iteration == 17
    assert e.reason == "objective diverged"
    msg = str(e)
    assert "layers[3]" in msg and "attn.wq" in msg and "17" in msg
