"""Multi-device SPMD tests — run in a subprocess with 8 forced host
devices via the shared conftest harness (the main test process stays
single-device)."""
import pytest

from conftest import run_multidevice as _run


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    """Same batch, (2,2,2) pod mesh vs single device -> same loss."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.configs import shapes as SH
        from repro.data import SyntheticCorpus, make_batch
        from repro.launch.mesh import make_mesh
        from repro.launch.cells import _ns
        from repro.sharding import rules
        from repro.train import TrainConfig, init_train_state, make_train_step

        cfg = configs.get_smoke('llama3.2-1b')
        tcfg = TrainConfig(lr=1e-3, warmup=0, total_steps=5)
        state = init_train_state(cfg, tcfg)
        batch = make_batch(cfg, SyntheticCorpus(cfg.vocab_size), 0, 0, 8, 32)

        ref_step = jax.jit(make_train_step(cfg, tcfg))
        p1, o1, e1, m1 = ref_step(*jax.tree.map(lambda x: x, state), batch)

        mesh = make_mesh(2, 2, pod=2)
        from repro.models import layers as L
        L.set_activation_sharding(mesh, rules.data_axes(mesh), 'model')
        pspecs = rules.param_pspecs(cfg, state[0], mesh)
        sh_step = jax.jit(make_train_step(cfg, tcfg),
                          in_shardings=(_ns(mesh, pspecs), None, None, None))
        p2, o2, e2, m2 = sh_step(*state, batch)
        np.testing.assert_allclose(float(m1['loss']), float(m2['loss']),
                                   rtol=2e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=0.15, atol=0.02)
        print('SPMD == single-device OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_psum_matches_mean():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.train.grad_compress import CompressConfig, compressed_psum

        mesh = make_mesh(8, 1)
        ccfg = CompressConfig(rank=16, min_size=0, power_iters=10)
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 24))

        # output is replicated by construction (all-gather then identical
        # local math) but vma inference can't prove it -> disable the
        # replication check (kwarg renamed check_rep -> check_vma, and
        # shard_map moved out of jax.experimental, across jax releases)
        if hasattr(jax, 'shard_map'):
            shard_map, kw = jax.shard_map, {'check_vma': False}
        else:
            from jax.experimental.shard_map import shard_map
            kw = {'check_rep': False}
        f = shard_map(lambda gs: compressed_psum(gs[0], 'data', ccfg),
                      mesh=mesh, in_specs=P('data'), out_specs=P(), **kw)
        got = f(g)
        want = jnp.mean(g, axis=0)
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        assert rel < 0.25, rel   # rank-16 of 16x24 is near-exact per shard
        print('compressed_psum OK', rel)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_serving_cell_numerics_match_unsharded():
    """Quantized decode on a (2,2) mesh == unsharded decode."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.core.pipeline import QuantConfig, nanoquant_quantize
        from repro.data import calib_batches
        from repro.launch.mesh import make_mesh
        from repro.launch.cells import _ns
        from repro.models import transformer as T
        from repro.models import layers as L
        from repro.serve.engine import make_serve_step
        from repro.sharding import rules

        cfg = dataclasses.replace(configs.get_smoke('llama3.2-1b'),
                                  dtype='float32')
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        calib = calib_batches(cfg, 4, 32, batch=2)
        qcfg = QuantConfig(admm_iters=4, t_pre=0, t_post=0, t_glob=0,
                           rank_align=32, min_dim=32)
        qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)

        cache = T.init_cache(cfg, 4, 16)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0,
                                 cfg.vocab_size)
        step = make_serve_step(cfg)
        ref_logits, _ = jax.jit(step)(qp, tok, cache, jnp.asarray(0))

        mesh = make_mesh(2, 2)
        L.set_activation_sharding(mesh, rules.data_axes(mesh), 'model')
        pspecs = rules.param_pspecs(cfg, qp, mesh)
        cspecs = rules.cache_pspecs(cfg, cache, mesh)
        sh = jax.jit(step, in_shardings=(
            _ns(mesh, pspecs),
            _ns(mesh, rules.batch_pspecs(cfg, tok, mesh)),
            _ns(mesh, cspecs), None))
        got_logits, _ = sh(qp, tok, cache, jnp.asarray(0))
        np.testing.assert_allclose(np.asarray(got_logits),
                                   np.asarray(ref_logits), rtol=2e-4,
                                   atol=2e-4)
        print('sharded quantized decode OK')
    """)
    assert "OK" in out
