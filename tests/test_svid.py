"""SVID (paper Eq. 6) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.svid import svid, svid_factors


def _rand(m, n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, n))


def test_svid_preserves_signs():
    p = _rand(24, 40)
    z = svid(p)
    signs_match = jnp.sign(z) == jnp.sign(jnp.where(p == 0, 1.0, p))
    assert bool(signs_match.all())


def test_svid_magnitude_is_rank1():
    p = _rand(16, 32, seed=1)
    z = svid(p)
    mag = jnp.abs(z)
    # |Z| = a b^T exactly -> rank 1
    s = jnp.linalg.svd(mag, compute_uv=False)
    assert float(s[1] / s[0]) < 1e-5


def test_svid_matches_svd_of_abs():
    """Power iteration must find the leading singular pair of |P|
    (Perron–Frobenius: non-negative matrix -> non-negative pair)."""
    p = _rand(20, 28, seed=2)
    a, b = svid_factors(p, n_iter=50)
    ab = jnp.abs(p)
    u, s, vt = jnp.linalg.svd(ab, full_matrices=False)
    best = s[0] * jnp.outer(jnp.abs(u[:, 0]), jnp.abs(vt[0]))
    np.testing.assert_allclose(np.asarray(jnp.outer(a, b)), np.asarray(best),
                               rtol=1e-4, atol=1e-4)


def test_svid_is_best_sign_preserving_rank1():
    """Residual of SVID <= residual of random sign-preserving rank-1
    proxies (optimality, Pouransari'20)."""
    p = _rand(12, 18, seed=3)
    z = svid(p, n_iter=50)
    base = float(jnp.linalg.norm(p - z))
    key = jax.random.PRNGKey(4)
    for i in range(10):
        k1, k2, key = jax.random.split(key, 3)
        a = jnp.abs(jax.random.normal(k1, (12,)))
        b = jnp.abs(jax.random.normal(k2, (18,)))
        cand = jnp.sign(p) * jnp.outer(a, b)
        assert base <= float(jnp.linalg.norm(p - cand)) + 1e-5


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 24), n=st.integers(2, 24), seed=st.integers(0, 99))
def test_svid_residual_bounded(m, n, seed):
    p = _rand(m, n, seed)
    z = svid(p)
    assert float(jnp.linalg.norm(p - z)) <= float(jnp.linalg.norm(p)) + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_svid_exact_on_rank1_sign_value(seed):
    """If P already has the sign-value structure, SVID recovers it."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jnp.abs(jax.random.normal(k1, (10,))) + 0.1
    b = jnp.abs(jax.random.normal(k2, (14,))) + 0.1
    s = jnp.sign(jax.random.normal(k3, (10, 14)))
    p = s * jnp.outer(a, b)
    z = svid(p, n_iter=60)
    np.testing.assert_allclose(np.asarray(z), np.asarray(p), rtol=1e-4,
                               atol=1e-5)
