"""Magnitude balancing (paper Eq. 7–9, App. A) property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.balance import magnitude_balance


def _factors(m, n, r, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (m, r)) + 0.01,
            jax.random.normal(k2, (n, r)) + 0.01)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 32), n=st.integers(4, 32), r=st.integers(1, 8),
       seed=st.integers(0, 99))
def test_balanced_norms_equal(m, n, r, seed):
    """Prop. 1: after balancing, ‖U‖_F == ‖V‖_F (with identity
    preconditioners)."""
    pu, pv = _factors(m, n, r, seed)
    lu, lv, _, _ = magnitude_balance(pu, pv, jnp.ones((m,)), jnp.ones((n,)))
    nu, nv = float(jnp.linalg.norm(lu)), float(jnp.linalg.norm(lv))
    assert abs(nu - nv) / max(nu, nv) < 1e-3


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 24), n=st.integers(4, 24), r=st.integers(1, 6),
       seed=st.integers(0, 99))
def test_product_invariance(m, n, r, seed):
    """Eq. 12: balancing never changes U Vᵀ (scale ambiguity only)."""
    pu, pv = _factors(m, n, r, seed)
    lu, lv, _, _ = magnitude_balance(pu, pv, jnp.ones((m,)), jnp.ones((n,)))
    np.testing.assert_allclose(np.asarray(lu @ lv.T), np.asarray(pu @ pv.T),
                               rtol=2e-3, atol=2e-4)


def test_eta_minimizes_energy():
    """Prop. 1: η* minimizes ½(‖ηU‖² + ‖η⁻¹V‖²) over η > 0."""
    pu, pv = _factors(12, 20, 4, 5)
    nu = float(jnp.linalg.norm(pu))
    nv = float(jnp.linalg.norm(pv))
    eta_star = np.sqrt(nv / nu)

    def J(eta):
        return 0.5 * ((eta * nu) ** 2 + (nv / eta) ** 2)

    for eta in [eta_star * f for f in (0.25, 0.5, 0.9, 1.1, 2.0, 4.0)]:
        assert J(eta_star) <= J(eta) + 1e-9


def test_preconditioner_removal():
    """Latents are D⁻¹-unscaled proxies (Eq. 9): with diagonal
    preconditioners d, balance(d ⊙ P) == balance(P) up to the η scale."""
    pu, pv = _factors(10, 14, 3, 8)
    d_out = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (10,))) + 0.5
    d_in = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (14,))) + 0.5
    lu1, lv1, s1a, s2a = magnitude_balance(d_out[:, None] * pu,
                                           d_in[:, None] * pv, d_out, d_in)
    lu2, lv2, s1b, s2b = magnitude_balance(pu, pv, jnp.ones((10,)),
                                           jnp.ones((14,)))
    np.testing.assert_allclose(np.asarray(lu1), np.asarray(lu2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2a), np.asarray(s2b), rtol=1e-4,
                               atol=1e-5)


def test_scales_are_row_mean_abs():
    pu, pv = _factors(9, 11, 4, 9)
    lu, lv, s1, s2 = magnitude_balance(pu, pv, jnp.ones((9,)),
                                       jnp.ones((11,)))
    np.testing.assert_allclose(np.asarray(s1),
                               np.asarray(jnp.mean(jnp.abs(lu), axis=1)),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s2),
                               np.asarray(jnp.mean(jnp.abs(lv), axis=1)),
                               rtol=1e-5)
