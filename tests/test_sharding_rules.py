"""Sharding rules for quantized (packed low-rank binary) leaves:
Megatron col/row pairing, divisibility fallback (uneven mesh ->
replicated spec, never raises), and agreement between the rules and the
shapes ``quant.surgery`` actually produces.

These tests run single-device: ``rules`` only reads ``mesh.axis_names``
and ``mesh.shape``, so a duck-typed stand-in mesh lets us exercise any
axis size without forcing host devices (cf. tests/test_sharding_spmd.py
for the executed multi-device paths)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.quant.surgery import abstract_quantized_params
from repro.sharding import rules


class FakeMesh:
    """Duck-typed mesh: the rule tables only need axis_names + shape."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def _packed_linears(tree, path=()):
    """[(path, dict)] for every packed linear in a (SDS or spec) tree."""
    out = []
    if isinstance(tree, dict):
        if "qu_t" in tree:
            out.append((path, tree))
        else:
            for k, v in tree.items():
                out += _packed_linears(v, path + (k,))
    return out


@pytest.fixture(scope="module")
def qtree():
    cfg = configs.get_smoke("llama3.2-1b")
    return cfg, abstract_quantized_params(cfg)


def test_tp_role_mapping():
    assert rules.tp_role("wq") == "col"
    assert rules.tp_role("attn.wo") == "row"
    assert rules.tp_role("layers/ffn/w_down") == "row"
    assert rules.tp_role("wqkv") == "col"
    assert rules.tp_role("mixer.wx") == "col"
    assert rules.tp_role("lm_head") is None
    assert rules.tp_role(None) is None


def test_uneven_mesh_falls_back_to_replicated(qtree):
    """A model axis that divides nothing must yield fully replicated
    specs for every packed leaf — and must never raise."""
    cfg, params = qtree
    mesh = FakeMesh(data=1, model=7)   # 7 divides no dim in the smoke cfg
    pspecs = rules.param_pspecs(cfg, params, mesh, rules.SERVE)
    for path, spec in _packed_linears(pspecs):
        for name in ("qu_t", "qv", "s1", "s2"):
            assert spec[name] == P(*(None,) * len(spec[name])), \
                (path, name, spec[name])


def test_specs_never_shard_uneven_dims(qtree):
    """Every sharded dim in every emitted spec divides the axis size
    (the .lower().compile() determinism contract in the module doc)."""
    cfg, params = qtree
    for model in (2, 3, 4, 5, 8):
        mesh = FakeMesh(data=2, model=model)
        pspecs = rules.param_pspecs(cfg, params, mesh, rules.DEFAULT)

        def check(kp, leaf):
            spec = pspecs
            for p in kp:
                spec = spec[p.key]
            assert len(spec) <= len(leaf.shape), (kp, spec)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is not None:
                    size = rules._axis_size(mesh, ax)
                    assert dim % size == 0, (kp, spec, dim, ax)

        jax.tree_util.tree_map_with_path(check, params)


def test_megatron_pairing_matches_surgery_shapes(qtree):
    """Col linears shard U/s1 on d_out; row linears shard V/s2 on
    (packed) d_in with U/s1 replicated — on the exact shapes surgery
    emits, with the paired leaves never sharded inconsistently."""
    cfg, params = qtree
    mesh = FakeMesh(data=1, model=2)
    pspecs = rules.param_pspecs(cfg, params, mesh, rules.SERVE)
    shapes = dict(_packed_linears(params))
    checked = {"col": 0, "row": 0}
    for path, spec in _packed_linears(pspecs):
        role = rules.tp_role(path[-1])
        if role is None:
            continue
        sds = shapes[path]
        if role == "col":
            if sds["qu_t"].shape[-1] % 2 == 0:
                assert spec["qu_t"][-1] == "model", (path, spec["qu_t"])
                assert spec["s1"][-1] == "model", (path, spec["s1"])
            # SERVE keeps V replicated so each device runs the whole
            # fused kernel on its output shard
            assert spec["qv"] == P(*(None,) * len(sds["qv"].shape))
            assert spec["s2"] == P(*(None,) * len(sds["s2"].shape))
        else:
            if sds["qv"].shape[-2] % 2 == 0:
                assert spec["qv"][-2] == "model", (path, spec["qv"])
                assert spec["s2"][-1] == "model", (path, spec["s2"])
            assert spec["qu_t"] == P(*(None,) * len(sds["qu_t"].shape))
            assert spec["s1"] == P(*(None,) * len(sds["s1"].shape))
        # the pair (U, s1) / (V, s2) shards together or not at all
        assert (spec["qu_t"][-1] is None) == (spec["s1"][-1] is None), path
        assert (spec["qv"][-2] is None) == (spec["s2"][-1] is None) \
            or role == "col", path
        checked[role] += 1
    assert checked["col"] and checked["row"], checked


def test_roleless_packed_linears_stay_replicated():
    """Packed linears whose parent has no Megatron role (MLA w_dkv /
    w_kr, mamba wB/wC/wdt) must be fully replicated: layers.dense
    launches them with tp=None (single-device), so sharding them would
    make placement and launch disagree."""
    seen = 0
    for arch in ("deepseek-v2-lite-16b", "mamba2-370m"):
        # full-scale configs: the smoke variants shrink w_dkv / wB / wC
        # below min_dim, filtering exactly the linears under test (the
        # tree is abstract ShapeDtypeStructs — no weights materialize)
        cfg = configs.get_config(arch)
        params = abstract_quantized_params(cfg)
        mesh = FakeMesh(data=1, model=2)
        pspecs = rules.param_pspecs(cfg, params, mesh, rules.SERVE)
        shapes = dict(_packed_linears(params))
        for path, spec in _packed_linears(pspecs):
            if rules.tp_role(path[-1]) is not None:
                continue
            seen += 1
            for name in ("qu_t", "qv", "s1", "s2"):
                rank = len(shapes[path][name].shape)
                assert spec[name] == P(*(None,) * rank), (path, name)
    assert seen, "expected at least one role-less packed linear"


def test_spec_rank_matches_leaf_rank(qtree):
    """param_pspecs mirrors the tree: every packed leaf gets a spec of
    exactly its own rank (shard_map in_specs are built from these)."""
    cfg, params = qtree
    mesh = FakeMesh(data=2, model=2)
    pspecs = rules.param_pspecs(cfg, params, mesh, rules.DEFAULT)
    for path, spec in _packed_linears(pspecs):
        sds = dict(_packed_linears(params))[path]
        for name in ("qu_t", "qv", "s1", "s2"):
            assert len(spec[name]) == len(sds[name].shape), (path, name)
