"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The property tests (`test_admm`, `test_balance`, `test_packing`,
`test_svid`) only use ``@settings(...) @given(ints/floats)``; on boxes
without hypothesis this shim runs each property over a fixed,
seed-deterministic sample of the same parameter space (a handful of
examples instead of shrinking search), so tier-1 collection and the
properties themselves still execute everywhere.

Usage (drop-in): ``from _hypothesis_compat import given, settings, st``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    st = _St()

    def settings(*args, **kwargs):
        """Honors ``max_examples`` (other knobs — deadline, shrinking
        phases — have no fallback equivalent and are ignored). Works in
        either decorator order: the attribute is read at call time off
        whichever function object the test runner actually invokes."""
        max_examples = kwargs.get("max_examples")

        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = int(max_examples)
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper():
                # seeded off the test name: deterministic across runs,
                # decorrelated across tests
                seed = _np.frombuffer(
                    fn.__name__.encode().ljust(8, b"x")[:8],
                    dtype=_np.uint32).sum()
                rng = _np.random.default_rng(int(seed))
                n = getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _FALLBACK_EXAMPLES))
                for _ in range(n):
                    kwargs = {k: s.sample(rng)
                              for k, s in strategies.items()}
                    fn(**kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
