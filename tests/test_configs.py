"""Config registry + input-spec cells."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import shapes as SH
from repro.quant.surgery import abstract_quantized_params, \
    packed_model_bytes


def test_registry_complete():
    assert len(configs.list_archs()) == 10


def test_shape_cells_assignment():
    """long_500k only for sub-quadratic families (DESIGN.md §5)."""
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        shapes = configs.shapes_for(arch)
        assert "train_4k" in shapes
        assert "decode_32k" in shapes
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
    total = sum(len(configs.shapes_for(a)) for a in configs.list_archs())
    assert total == 32


@pytest.mark.parametrize("arch", configs.list_archs())
def test_input_specs_no_allocation(arch):
    for shape in configs.shapes_for(arch):
        specs = SH.input_specs(configs.get_config(arch), shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_train_specs_grad_accum_split():
    cfg = configs.get_config("qwen1.5-110b")
    specs = SH.input_specs(cfg, "train_4k", grad_accum=8)
    assert specs["batch"]["tokens"].shape == (8, 32, 4096)


def test_decode_specs_have_cache():
    cfg = configs.get_config("qwen3-4b")
    specs = SH.input_specs(cfg, "decode_32k")
    assert specs["token"].shape == (128, 1)
    k = specs["cache"]["layers"]["k"]
    assert k.shape == (36, 128, 32768, 8, 128)


def test_ssm_decode_state_o1():
    cfg = configs.get_config("mamba2-370m")
    specs = SH.input_specs(cfg, "long_500k")
    ssm = specs["cache"]["layers"]["ssm"]
    assert ssm.shape == (48, 1, 32, 64, 128)        # no 500k dimension


def test_packed_model_compression_factors():
    """Paper-scale check transposed to the pool: 1-bit packing of a
    dense arch lands near the paper's ~10-24x whole-model factor."""
    rep = packed_model_bytes(configs.get_config("qwen1.5-110b"), 1.0)
    assert rep["compression_x"] > 10
    assert rep["linears_bpw"] <= 1.0 + 1e-6
    small = packed_model_bytes(configs.get_config("qwen1.5-0.5b"), 1.0)
    assert small["compression_x"] > 1.5        # embedding-dominated


@pytest.mark.parametrize("arch", configs.list_archs())
def test_abstract_quantized_tree_builds(arch):
    tree = abstract_quantized_params(configs.get_config(arch))
    leaves = jax.tree.leaves(tree)
    assert any(l.dtype == jnp.uint32 for l in leaves)
