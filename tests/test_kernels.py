"""Pallas binary-matmul kernel vs pure-jnp oracle (interpret mode on CPU).

Shape/dtype sweep per the deliverable: GEMV (M=1) through GEMM, ragged
M, K/N at and off block boundaries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.binary_matmul import (
    lowrank_binary_matmul_pallas, packed_matmul)


def _assert_close(got, want, dtype):
    """f32: elementwise-exact-ish. bf16: normalized-RMS — the kernel
    keeps f32 internals while the oracle rounds (x*s_k) and the
    inter-stage t to bf16, so isolated cancellation-heavy elements can
    differ by several ulps; aggregate fidelity is the meaningful bound."""
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    if dtype == jnp.bfloat16:
        rms = float(np.sqrt(np.mean((g - w) ** 2)))
        ref_rms = float(np.sqrt(np.mean(w ** 2))) + 1e-9
        assert rms / ref_rms < 0.02, rms / ref_rms
    else:
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def _mk(m, k, n, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kw, k1, k2 = jax.random.split(key, 4)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    w = jnp.sign(jax.random.normal(kw, (k, n)))
    w = jnp.where(w == 0, 1.0, w)
    packed = ref.pack_signs(w)
    s_k = jnp.abs(jax.random.normal(k1, (k,))) + 0.1
    s_n = jnp.abs(jax.random.normal(k2, (n,))) + 0.1
    return x, packed, s_k, s_n


@pytest.mark.parametrize("m", [1, 7, 64, 130])
@pytest.mark.parametrize("k,n", [(32, 32), (64, 96), (512, 128), (96, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_matmul_matches_ref(m, k, n, dtype):
    x, packed, s_k, s_n = _mk(m, k, n, dtype)
    got = packed_matmul(x, packed, s_k, s_n, interpret=True,
                        bm=64, bn=64, bk=64)
    want = ref.packed_matmul_ref(x, packed, s_k, s_n)
    _assert_close(got, want, dtype)


@pytest.mark.parametrize("shape", [(1, 64), (3, 64), (2, 5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_chain_matches_ref(shape, dtype):
    d_in, r, d_out = 64, 32, 96
    key = jax.random.PRNGKey(3)
    kx, ku, kv, k1, k2 = jax.random.split(key, 5)
    x = jax.random.normal(kx, shape + (0,)[:0], jnp.float32)
    x = jax.random.normal(kx, shape, jnp.float32).astype(dtype)
    u = jnp.where(jnp.sign(jax.random.normal(ku, (d_out, r))) == 0, 1.0,
                  jnp.sign(jax.random.normal(ku, (d_out, r))))
    v = jnp.where(jnp.sign(jax.random.normal(kv, (d_in, r))) == 0, 1.0,
                  jnp.sign(jax.random.normal(kv, (d_in, r))))
    qu_t = ref.pack_signs(u.T)
    qv = ref.pack_signs(v)
    s1 = jnp.abs(jax.random.normal(k1, (d_out,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (d_in,))) + 0.1
    got = lowrank_binary_matmul_pallas(x, qv, qu_t, s1, s2, interpret=True,
                                       bm=32, bn=32, bk=32)
    want = ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)
    _assert_close(got, want, dtype)


def test_kernel_mode_switch(monkeypatch):
    from repro.kernels import ops
    x, packed, s_k, s_n = _mk(4, 64, 32, jnp.float32)
    qv = packed[:, :32]
    with ops.kernel_policy("ref"):
        y1 = ops.lowrank_binary_matmul(
            x, packed[:, :32], ref.pack_signs(jnp.ones((32, 96))),
            jnp.ones((96,)), s_k)
    with ops.kernel_policy("pallas"):
        y2 = ops.lowrank_binary_matmul(
            x, packed[:, :32], ref.pack_signs(jnp.ones((32, 96))),
            jnp.ones((96,)), s_k)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


def test_gemv_decode_shape():
    """decode regime: M=1 row through both stages (paper App. E GEMV)."""
    x, packed, s_k, s_n = _mk(1, 128, 64, jnp.bfloat16, seed=9)
    got = packed_matmul(x, packed, s_k, s_n, interpret=True)
    want = ref.packed_matmul_ref(x, packed, s_k, s_n)
    _assert_close(got, want, jnp.bfloat16)
