"""Pallas binary-matmul kernels vs pure-jnp oracles (interpret mode on
CPU).

Covers the fused single-pass kernel (bit-exactness vs the fused oracle
across a shape sweep: minimum rank, K/N off block boundaries, bf16
activations, M=1 GEMV), merged-QKV equality vs separate calls, the
expert-grid kernel, block-size fitting (divisor tiles -> no pad ops in
the jitted decode trace), pack-time K alignment, and engine decode
token-identity under the fused policy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tuning
from repro.kernels.binary_matmul import (
    fused_lowrank_matmul, fused_lowrank_matmul_grouped,
    lowrank_binary_matmul_twocall, packed_matmul)


def _assert_close(got, want, dtype, f32_tol=1e-4):
    """f32: elementwise-exact up to partial-sum reassociation (pass
    f32_tol=1e-3 for fused-vs-unfused comparisons, where the kernel's
    tiled K reduction reassociates against the single-dot oracle and
    isolated cancellation-heavy elements move by a few ulps). bf16:
    normalized-RMS — the kernel keeps f32 internals while the oracle
    input rounding differs elementwise; aggregate fidelity is the
    meaningful bound."""
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    if dtype == jnp.bfloat16:
        rms = float(np.sqrt(np.mean((g - w) ** 2)))
        ref_rms = float(np.sqrt(np.mean(w ** 2))) + 1e-9
        assert rms / ref_rms < 0.02, rms / ref_rms
    else:
        np.testing.assert_allclose(g, w, rtol=f32_tol, atol=f32_tol)


def _mk(m, k, n, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kw, k1, k2 = jax.random.split(key, 4)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    w = jnp.sign(jax.random.normal(kw, (k, n)))
    w = jnp.where(w == 0, 1.0, w)
    packed = ref.pack_signs(w)
    s_k = jnp.abs(jax.random.normal(k1, (k,))) + 0.1
    s_n = jnp.abs(jax.random.normal(k2, (n,))) + 0.1
    return x, packed, s_k, s_n


def _mk_lowrank(m, k, n, r, dtype=jnp.float32, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ku, kv, k1, k2 = jax.random.split(key, 5)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    u = jnp.sign(jax.random.normal(ku, (n, r)))
    v = jnp.sign(jax.random.normal(kv, (k, r)))
    qu_t = ref.pack_signs(jnp.where(u == 0, 1.0, u).T)
    qv = ref.pack_signs(jnp.where(v == 0, 1.0, v))
    s1 = jnp.abs(jax.random.normal(k1, (n,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (k,))) + 0.1
    return x, qv, qu_t, s1, s2


# ---------------------------------------------------------------------------
# two-call building block (legacy path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 7, 64, 130])
@pytest.mark.parametrize("k,n", [(32, 32), (64, 96), (512, 128), (96, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_matmul_matches_ref(m, k, n, dtype):
    x, packed, s_k, s_n = _mk(m, k, n, dtype)
    got = packed_matmul(x, packed, s_k, s_n, interpret=True,
                        bm=64, bn=64, bk=64)
    want = ref.packed_matmul_ref(x, packed, s_k, s_n)
    _assert_close(got, want, dtype)


# ---------------------------------------------------------------------------
# fused single-pass kernel
# ---------------------------------------------------------------------------

# (m, k, n, r, bm, bn, bk): minimum rank r=32 (rank_align floor), K/N at
# and off the block boundary, rank off the 128-lane boundary, M=1 GEMV
_FUSED_SWEEP = [
    (1, 64, 96, 32, 8, 32, 32),            # GEMV, min rank
    (7, 96, 160, 32, 8, 64, 32),           # ragged M, N % bn != 0
    (64, 512, 128, 64, 32, 64, 128),       # multi-tile K reduction
    (130, 96, 96, 64, 64, 32, 32),         # M off block boundary
    (3, 160, 96, 96, 8, 96, 64),           # bk refit to a K divisor, odd rank
    (1, 128, 64, 32, 8, 64, 128),          # GEMV, single K tile
]


@pytest.mark.parametrize("m,k,n,r,bm,bn,bk", _FUSED_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_fused_ref(m, k, n, r, bm, bn, bk, dtype):
    x, qv, qu_t, s1, s2 = _mk_lowrank(m, k, n, r, dtype)
    got = fused_lowrank_matmul(x, qv, qu_t, s1, s2, interpret=True,
                               bm=bm, bn=bn, bk=bk)
    want = ref.lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2)
    _assert_close(got, want, dtype, f32_tol=1e-3)


@pytest.mark.parametrize("m,k,n,r,bm,bn,bk", _FUSED_SWEEP[:3])
def test_fused_matches_two_stage_ref(m, k, n, r, bm, bn, bk):
    """Against the *two-stage* oracle too (f32: the stage boundary does
    not round, so both agree)."""
    x, qv, qu_t, s1, s2 = _mk_lowrank(m, k, n, r, jnp.float32)
    got = fused_lowrank_matmul(x, qv, qu_t, s1, s2, interpret=True,
                               bm=bm, bn=bn, bk=bk)
    want = ref.lowrank_binary_matmul_ref(x, qv, qu_t, s1, s2)
    _assert_close(got, want, jnp.float32, f32_tol=1e-3)


def test_fused_matches_twocall_kernel():
    x, qv, qu_t, s1, s2 = _mk_lowrank(5, 96, 128, 64)
    got = fused_lowrank_matmul(x, qv, qu_t, s1, s2, interpret=True,
                               bm=8, bn=64, bk=32)
    want = lowrank_binary_matmul_twocall(x, qv, qu_t, s1, s2,
                                         interpret=True, bm=8, bn=64, bk=32)
    _assert_close(got, want, jnp.float32, f32_tol=1e-3)


def test_gemv_decode_shape():
    """decode regime: M=1 row through the fused chain (paper App. E
    GEMV) in the serving dtype."""
    x, qv, qu_t, s1, s2 = _mk_lowrank(1, 128, 64, 32, jnp.bfloat16, seed=9)
    got = fused_lowrank_matmul(x, qv, qu_t, s1, s2, interpret=True)
    want = ref.lowrank_binary_matmul_fused_ref(x, qv, qu_t, s1, s2)
    _assert_close(got, want, jnp.bfloat16)


# ---------------------------------------------------------------------------
# merged projections / expert grid
# ---------------------------------------------------------------------------


def _merged_group(projs):
    from repro.quant.surgery import _stack_group
    return _stack_group([{"qv": qv, "qu_t": qu, "s1": s1, "s2": s2}
                         for (qv, qu, s1, s2) in projs])


def test_merged_qkv_equals_separate_calls():
    """Grouped QKV launch == three separate fused calls (ragged ranks
    and output widths, i.e. GQA-shaped)."""
    k = 96
    x = jax.random.normal(jax.random.PRNGKey(5), (6, k))
    shapes = [(128, 64), (64, 32), (64, 32)]          # (n_i, r_i)
    projs = [_mk_lowrank(6, k, n, r, seed=i)[1:]
             for i, (n, r) in enumerate(shapes)]
    mp = _merged_group(projs)
    assert mp["qv"].shape == (3, k // 32, 64)
    assert mp["qu_t"].shape == (3, 2, 128)
    pol = ops.KernelPolicy(mode="pallas", interpret=True)
    ys = ops.lowrank_binary_matmul_merged(
        x, mp, tuple(n for n, _ in shapes), policy=pol)
    for (n, _), y, (qv, qu, s1, s2) in zip(shapes, ys, projs):
        assert y.shape == (6, n)
        want = ops.lowrank_binary_matmul(x, qv, qu, s1, s2, policy=pol)
        _assert_close(y, want, jnp.float32, f32_tol=1e-3)


def test_merged_ref_fallback_matches():
    k = 64
    x = jax.random.normal(jax.random.PRNGKey(2), (4, k))
    projs = [_mk_lowrank(4, k, 96, 32, seed=i)[1:] for i in range(2)]
    mp = _merged_group(projs)
    ys = ops.lowrank_binary_matmul_merged(
        x, mp, (96, 96), policy=ops.KernelPolicy(mode="ref"))
    for y, (qv, qu, s1, s2) in zip(ys, projs):
        want = ref.lowrank_binary_matmul_fused_ref(x, qv, qu, s1, s2)
        _assert_close(y, want, jnp.float32, f32_tol=1e-3)


def test_expert_grid_matches_vmap_ref():
    """Expert axis as a kernel grid dimension == per-expert oracle."""
    E, C, k, n, r = 3, 8, 64, 96, 32
    xs = jax.random.normal(jax.random.PRNGKey(11), (E, C, k))
    opsl = [_mk_lowrank(C, k, n, r, seed=20 + e)[1:] for e in range(E)]
    qv = jnp.stack([o[0] for o in opsl])
    qu = jnp.stack([o[1] for o in opsl])
    s1 = jnp.stack([o[2] for o in opsl])
    s2 = jnp.stack([o[3] for o in opsl])
    got = ops.lowrank_binary_matmul_expert(
        xs, qv, qu, s1, s2, policy=ops.KernelPolicy(mode="pallas",
                                                    interpret=True))
    want = jax.vmap(ref.lowrank_binary_matmul_ref)(xs, qv, qu, s1, s2)
    _assert_close(got, want, jnp.float32, f32_tol=1e-3)


# ---------------------------------------------------------------------------
# policy / dispatch
# ---------------------------------------------------------------------------


def test_kernel_policy_switch():
    x, qv, qu_t, s1, s2 = _mk_lowrank(4, 64, 96, 32)
    with ops.kernel_policy("ref"):
        y1 = ops.lowrank_binary_matmul(x, qv, qu_t, s1, s2)
    with ops.kernel_policy(ops.KernelPolicy(mode="pallas", interpret=True)):
        y2 = ops.lowrank_binary_matmul(x, qv, qu_t, s1, s2)
    with ops.kernel_policy(ops.KernelPolicy(mode="pallas", interpret=True,
                                            fused=False)):
        y3 = ops.lowrank_binary_matmul(x, qv, qu_t, s1, s2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-4,
                               atol=1e-4)


def test_policy_block_table_override():
    table = ((100_000, 100_000, 100_000, 100_000, 16, 64, 64),)
    pol = ops.KernelPolicy(block_table=table)
    bm, bn, bk = pol.block_sizes(8, 256, 192, 32)
    assert (bm, bn, bk) == (8, 64, 64)     # bm covers M=8 at sublane 8
    # default policy: decode shape gets a sublane-sized M tile
    bm, bn, bk = ops.KernelPolicy().block_sizes(8, 2048, 2048, 512)
    assert bm == 8
    assert 2048 % bk == 0 and 2048 % bn == 0


def test_block_size_fitting_divisors():
    # K=2816 (llama-style d_ff) misaligns to the old fixed bk=512; the
    # fitter must pick a divisor tile so no weight padding is traced
    bm, bn, bk = tuning.fit_block_sizes(8, 2816, 1024, 256)
    assert 2816 % bk == 0 and bk % 32 == 0
    assert 1024 % bn == 0
    # bf16 activations need 16 sublanes
    bm16, _, _ = tuning.fit_block_sizes(4, 256, 256, 32, jnp.bfloat16)
    assert bm16 == 16


def test_no_pad_ops_in_decode_trace():
    """The jitted decode-step kernel call must trace zero pad ops for
    pack-aligned operands (the old path re-padded packed_w/s_k/s_n on
    every call for K % bk != 0)."""
    x, qv, qu_t, s1, s2 = _mk_lowrank(8, 704, 128, 32)   # K=704=32*22
    with ops.kernel_policy(ops.KernelPolicy(mode="pallas", interpret=True)):
        jaxpr = jax.make_jaxpr(
            lambda *a: ops.lowrank_binary_matmul(*a))(x, qv, qu_t, s1, s2)
    assert "pad[" not in str(jaxpr)


def test_prealigned_pack_matches_unaligned():
    """pack_quantized(k_align=...) stores tile-aligned operands; results
    are identical on both the ref and the fused pallas path (the ops
    layer zero-extends x to the stored K)."""
    from repro.core.packing import pack_quantized
    key = jax.random.PRNGKey(4)
    ku, kv, k1, k2, kx = jax.random.split(key, 5)
    d_in, d_out, r = 96, 64, 32
    lu = jax.random.normal(ku, (d_out, r))
    lv = jax.random.normal(kv, (d_in, r))
    s1 = jnp.abs(jax.random.normal(k1, (d_out,))) + 0.1
    s2 = jnp.abs(jax.random.normal(k2, (d_in,))) + 0.1
    x = jax.random.normal(kx, (5, d_in))
    q0 = pack_quantized(lu, lv, s1, s2)                  # k_align=32
    qa = pack_quantized(lu, lv, s1, s2, k_align=128)
    assert qa["qv"].shape == (4, r) and qa["s2"].shape == (128,)
    for pol in (ops.KernelPolicy(mode="ref"),
                ops.KernelPolicy(mode="pallas", interpret=True)):
        y0 = ops.lowrank_binary_matmul(x, q0["qv"], q0["qu_t"], q0["s1"],
                                       q0["s2"], policy=pol)
        ya = ops.lowrank_binary_matmul(x, qa["qv"], qa["qu_t"], qa["s1"],
                                       qa["s2"], policy=pol)
        _assert_close(ya, y0, jnp.float32, f32_tol=1e-3)


# ---------------------------------------------------------------------------
# engine decode token-identity under the fused policy
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_engine_token_identity_fused_policy():
    """Greedy engine outputs are token-identical between the ref policy
    and the fused+merged pallas policy (interpret mode on CPU)."""
    from repro import configs
    from repro.core.pipeline import QuantConfig, nanoquant_quantize
    from repro.data import calib_batches
    from repro.models import transformer as T
    from repro.serve import InferenceEngine, Request, ServeConfig

    cfg = dataclasses.replace(configs.get_smoke("qwen1.5-0.5b"),
                              dtype="float32")      # qkv_bias covered
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, 2, 32, batch=2)
    qcfg = QuantConfig(admm_iters=2, t_pre=0, t_post=0, t_glob=0,
                       min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)

    prompts = [np.arange(5, dtype=np.int32) % cfg.vocab_size,
               np.arange(7, dtype=np.int32) % cfg.vocab_size]

    def run(policy):
        with ops.kernel_policy(policy):
            eng = InferenceEngine(qp, cfg, ServeConfig(max_new_tokens=4,
                                                       greedy=True),
                                  max_batch=2, max_len=32)
            for uid, pr in enumerate(prompts):
                eng.submit(Request(uid, pr, max_new_tokens=4))
            done = eng.run()
        return [done[uid].output for uid in range(len(prompts))]

    outs_ref = run(ops.KernelPolicy(mode="ref"))
    outs_fused = run(ops.KernelPolicy(mode="pallas", interpret=True,
                                      fused=True, merge_projections=True))
    for a, b in zip(outs_ref, outs_fused):
        np.testing.assert_array_equal(a, b)
