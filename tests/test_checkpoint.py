"""Checkpoint manager: atomicity, retention, resume, dtype round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "bf": jax.random.normal(k, (4, 4)).astype(jnp.bfloat16),
        "packed": jax.random.randint(k, (2, 3), 0, 2**31 - 1,
                                     dtype=jnp.int32).astype(jnp.uint32)
        + jnp.uint32(0x80000000),
        "nested": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s)
    step, restored = mgr.restore_latest(template=s)
    assert step == 3
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_atomic_no_partial_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    # a crashed tmp dir from a dead writer must be swept, not restored
    crashed = os.path.join(str(tmp_path), ".tmp-9-12345")
    os.makedirs(crashed)
    with open(os.path.join(crashed, "garbage"), "w") as f:
        f.write("partial")
    assert mgr.latest_step() == 1
    mgr.save(2, _state(1))
    assert not os.path.exists(crashed)


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = {"only": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="structure mismatch"):
        mgr.restore(1, bad)


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    bad = jax.tree.map(lambda x: x, s)
    bad["w"] = jnp.zeros((9, 16))
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, bad)


def test_reshard_on_load_single_device(tmp_path):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    # Mesh directly: jax.make_mesh(axis_types=...) post-dates the oldest
    # jax this repo supports
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    s = {"w": jnp.arange(8.0)}
    mgr.save(1, s)
    shardings = {"w": NamedSharding(mesh, P("data"))}
    restored = mgr.restore(1, s, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_sharded_files_split(tmp_path):
    mgr = CheckpointManager(str(tmp_path), shard_mb=1)
    big = {"a": jnp.zeros((600, 600), jnp.float32),
           "b": jnp.zeros((600, 600), jnp.float32)}
    mgr.save(1, big)
    d = mgr._step_dir(1)
    shards = [f for f in os.listdir(d) if f.startswith("arrays-")]
    assert len(shards) >= 2
    _, restored = mgr.restore_latest(template=big)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.zeros((600, 600)))
