"""Checkpoint manager: atomicity, retention, resume, dtype round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "bf": jax.random.normal(k, (4, 4)).astype(jnp.bfloat16),
        "packed": jax.random.randint(k, (2, 3), 0, 2**31 - 1,
                                     dtype=jnp.int32).astype(jnp.uint32)
        + jnp.uint32(0x80000000),
        "nested": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s)
    step, restored = mgr.restore_latest(template=s)
    assert step == 3
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_atomic_no_partial_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    # a crashed tmp dir from a dead writer must be swept, not restored
    crashed = os.path.join(str(tmp_path), ".tmp-9-12345")
    os.makedirs(crashed)
    with open(os.path.join(crashed, "garbage"), "w") as f:
        f.write("partial")
    assert mgr.latest_step() == 1
    mgr.save(2, _state(1))
    assert not os.path.exists(crashed)


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = {"only": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="structure mismatch"):
        mgr.restore(1, bad)


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    bad = jax.tree.map(lambda x: x, s)
    bad["w"] = jnp.zeros((9, 16))
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, bad)


def test_reshard_on_load_single_device(tmp_path):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    # Mesh directly: jax.make_mesh(axis_types=...) post-dates the oldest
    # jax this repo supports
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    s = {"w": jnp.arange(8.0)}
    mgr.save(1, s)
    shardings = {"w": NamedSharding(mesh, P("data"))}
    restored = mgr.restore(1, s, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_sharded_files_split(tmp_path):
    mgr = CheckpointManager(str(tmp_path), shard_mb=1)
    big = {"a": jnp.zeros((600, 600), jnp.float32),
           "b": jnp.zeros((600, 600), jnp.float32)}
    mgr.save(1, big)
    d = mgr._step_dir(1)
    shards = [f for f in os.listdir(d) if f.startswith("arrays-")]
    assert len(shards) >= 2
    _, restored = mgr.restore_latest(template=big)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.zeros((600, 600)))


# ---- corruption detection (per-leaf checksums in the manifest) ----------


def _tamper(tmp_path, mutate):
    """Save a state, then rewrite shard 0 through `mutate(arrays)`."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    shard = os.path.join(mgr._step_dir(1), "arrays-0.npz")
    with np.load(shard) as z:
        arrays = {n: z[n].copy() for n in z.files}
    mutate(arrays)
    np.savez(shard, **arrays)
    return mgr, s


def test_bitflip_names_the_bad_leaf(tmp_path):
    def flip(arrays):
        a = arrays["leaf_000000"]
        a.view(np.uint8).reshape(-1)[3] ^= 0x40
    mgr, s = _tamper(tmp_path, flip)
    with pytest.raises(ValueError, match="leaf 0 checksum mismatch"):
        mgr.restore(1, s)


def test_missing_leaf_names_the_leaf(tmp_path):
    mgr, s = _tamper(tmp_path,
                     lambda arrays: arrays.pop("leaf_000001"))
    with pytest.raises(ValueError,
                       match=r"leaf 1 \(leaf_000001\) missing"):
        mgr.restore(1, s)


def test_truncated_leaf_names_the_leaf(tmp_path):
    def truncate(arrays):
        arrays["leaf_000000"] = arrays["leaf_000000"][:2]
    mgr, s = _tamper(tmp_path, truncate)
    with pytest.raises(ValueError, match="leaf 0 has stored shape"):
        mgr.restore(1, s)


def test_missing_shard_is_reported(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    os.unlink(os.path.join(mgr._step_dir(1), "arrays-0.npz"))
    with pytest.raises(ValueError, match="arrays-0.npz missing"):
        mgr.restore(1, s)


def test_pre_checksum_artifact_still_loads(tmp_path):
    """Manifests written before per-leaf checksums (no "checksums" key)
    must keep restoring — shape checks still run, crc is skipped."""
    import json
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    meta_path = os.path.join(mgr._step_dir(1), "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["checksums"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    _, restored = mgr.restore_latest(template=s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_load_reports_corrupt_artifact(tmp_path):
    """NanoQuantModel.load on a bit-flipped artifact raises a clear
    corrupt/truncated error naming the bad leaf instead of a downstream
    unpack crash."""
    from repro import configs
    from repro.api import NanoQuantModel
    from repro.models import transformer as T
    cfg = configs.get_smoke("qwen1.5-0.5b")
    model = NanoQuantModel.from_fp(
        T.init_params(jax.random.PRNGKey(0), cfg), cfg)
    d = os.path.join(str(tmp_path), "artifact")
    model.save(d)
    shard = os.path.join(d, "step_00000000", "arrays-0.npz")
    with np.load(shard) as z:
        arrays = {n: z[n].copy() for n in z.files}
    arrays["leaf_000000"].view(np.uint8).reshape(-1)[0] ^= 0xFF
    np.savez(shard, **arrays)
    with pytest.raises(ValueError, match="corrupt/truncated artifact"):
        NanoQuantModel.load(d)


def test_keyed_save_restores_without_template(tmp_path):
    """save(keyed=True) records leaf key paths so restore_keyed
    rebuilds the nested dict exactly — no template needed (what the
    quantization journal's block store relies on)."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(5, s, keyed=True)
    restored = mgr.restore_keyed(5)
    assert jax.tree.structure(restored) == jax.tree.structure(s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "keypaths" in mgr.meta(5)


def test_restore_keyed_refuses_unkeyed_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    with pytest.raises(ValueError, match="not saved keyed"):
        mgr.restore_keyed(1)
