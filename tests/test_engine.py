"""Continuous-batching InferenceEngine: scheduler invariants, token
identity with the host-driven generate loop, bucketed prefill
compilation, streaming, the BatchServer compatibility shim, and the
tensor-parallel engine (mesh=...) vs its unsharded twin."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from conftest import run_multidevice

from repro import configs
from repro.models import transformer as T
from repro.serve import (BatchServer, InferenceEngine, Request, ServeConfig,
                         bucket_length)
from repro.serve.engine import generate


@pytest.fixture(scope="module")
def served_model():
    # f32 so greedy argmax is identical across batch compositions
    cfg = dataclasses.replace(configs.get_smoke("llama3.2-1b"),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _ref(params, cfg, prompt, budget):
    gen, _ = generate(params, cfg, prompt[None],
                      ServeConfig(max_new_tokens=budget, greedy=True))
    return np.asarray(gen[0])


def test_bucket_length():
    assert bucket_length(5, 512) == 8
    assert bucket_length(8, 512) == 8
    assert bucket_length(9, 512) == 16
    assert bucket_length(70, 96) == 96       # capped at max_len
    assert bucket_length(2, 512) == 8        # floored


def test_submit_rejects_overlong_prompt(served_model):
    """A prompt at/over max_len used to crash step_wave with an empty
    np.concatenate; it is now rejected at submit time."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(), max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, np.arange(16, dtype=np.int32)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(1, np.zeros((0,), np.int32)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        srv = BatchServer(params, cfg, ServeConfig(), max_batch=2,
                          max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        srv.submit(Request(2, np.arange(20, dtype=np.int32)))


def test_budget_truncated_to_capacity(served_model):
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=1, max_len=16)
    h = eng.submit(Request(0, np.arange(12, dtype=np.int32),
                           max_new_tokens=50))
    out = h.result()
    assert len(out) == 4                     # max_len - prompt_len


def test_prefill_compiles_once_per_bucket(served_model):
    """Two waves with different prompt lengths in the same power-of-two
    bucket reuse one prefill compilation (no per-wave retracing)."""
    cfg, params = served_model
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        srv = BatchServer(params, cfg, ServeConfig(max_new_tokens=2,
                                                   greedy=True),
                          max_batch=2, max_len=32)
    for uid, p in enumerate(_prompts(cfg, [5, 6])):
        srv.submit(Request(uid, p, max_new_tokens=2))
    srv.step_wave()                          # wave 1: prompt lens 5, 6
    for uid, p in enumerate(_prompts(cfg, [7, 8]), start=2):
        srv.submit(Request(uid, p, max_new_tokens=2))
    srv.step_wave()                          # wave 2: lens 7, 8 — same bucket
    assert sorted(srv.done) == [0, 1, 2, 3]
    assert srv.engine.stats["prefill_traces"] == 1
    assert srv.engine.stats["decode_traces"] == 1


def test_greedy_token_identity_vs_generate(served_model):
    """Per request, the continuous engine (with mid-flight admission and
    bucketed right-padded prefill) is token-identical to the unpadded
    host-driven generate loop."""
    cfg, params = served_model
    lens, budgets = [5, 9, 12, 6], [6, 3, 8, 5]
    prompts = _prompts(cfg, lens)
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=2, max_len=32)
    for uid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(uid, p, max_new_tokens=b))
    done = eng.run()
    assert eng.stats["admissions"] == 4
    for uid, (p, b) in enumerate(zip(prompts, budgets)):
        np.testing.assert_array_equal(done[uid].output, _ref(params, cfg,
                                                             p, b))


def test_wave_and_continuous_identical(served_model):
    cfg, params = served_model
    prompts = _prompts(cfg, [4, 11, 7, 9])
    budgets = [5, 2, 7, 4]
    outs = {}
    for mode in ("continuous", "wave"):
        eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                              max_batch=2, max_len=32, admission=mode)
        for uid, (p, b) in enumerate(zip(prompts, budgets)):
            eng.submit(Request(uid, p, max_new_tokens=b))
        outs[mode] = eng.run()
    for uid in range(len(prompts)):
        np.testing.assert_array_equal(outs["wave"][uid].output,
                                      outs["continuous"][uid].output)


def test_midflight_admission_fills_freed_slot(served_model):
    """A freed slot is refilled while its neighbor is still decoding."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=2, max_len=32)
    for uid, b in enumerate([2, 10, 2]):
        eng.submit(Request(uid, np.arange(1, 6, dtype=np.int32) + uid,
                           max_new_tokens=b))
    eng.run()
    # request 2 reuses the slot request 0 freed, and is admitted before
    # request 1 (budget 10) completes — continuous, not wave, admission.
    assert eng.slot_of[2] == eng.slot_of[0]
    assert eng.slot_of[2] != eng.slot_of[1]
    assert eng.admission_step[2] < eng.completion_step[1]


def test_per_slot_eos_stops_slot_without_disturbing_neighbors(served_model):
    """EOS finishes one slot early; every neighbor still produces its
    exact solo-generate output."""
    cfg, params = served_model
    prompts = _prompts(cfg, [6, 8, 10], seed=3)
    budgets = [8, 8, 8]
    # pick the eos for request 1 = its 3rd greedy token -> stops early
    ref1 = _ref(params, cfg, prompts[1], 8)
    eos = int(ref1[2])
    if eos in (int(ref1[0]), int(ref1[1])):
        pytest.skip("greedy output repeats; eos would hit earlier")
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=3, max_len=32)
    for uid, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(Request(uid, p, max_new_tokens=b,
                           eos_id=eos if uid == 1 else None))
    done = eng.run()
    np.testing.assert_array_equal(done[1].output, ref1[:3])
    assert int(done[1].output[-1]) == eos
    for uid in (0, 2):
        np.testing.assert_array_equal(done[uid].output,
                                      _ref(params, cfg, prompts[uid], 8))


def test_streaming_iterator_and_callback(served_model):
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=2, max_len=32)
    seen = []
    h0 = eng.submit(Request(0, np.arange(5, dtype=np.int32),
                            max_new_tokens=4),
                    on_token=lambda uid, tok: seen.append((uid, int(tok))))
    h1 = eng.submit(Request(1, np.arange(7, dtype=np.int32),
                            max_new_tokens=6))
    streamed = [int(t) for t in h0]          # pumps eng.step() itself
    assert h0.done and len(streamed) == 4
    assert streamed == [t for uid, t in seen if uid == 0]
    np.testing.assert_array_equal(h0.result(), np.asarray(streamed,
                                                          np.int32))
    assert len(h1.result()) == 6             # drains the rest
    assert h0.latency is not None and h1.latency is not None


def test_raising_callback_leaves_engine_consistent(served_model):
    """on_token callbacks fire after per-tick state commit: a raising
    callback propagates but the engine resumes cleanly and neighbors'
    outputs are untouched."""
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_batch=2, max_len=32)
    calls = []

    def bad_cb(uid, tok):
        calls.append(int(tok))
        if len(calls) == 2:
            raise RuntimeError("flaky consumer")

    eng.submit(Request(0, np.arange(5, dtype=np.int32),
                       max_new_tokens=4), on_token=bad_cb)
    p1 = np.arange(7, dtype=np.int32)
    eng.submit(Request(1, p1, max_new_tokens=6))
    with pytest.raises(RuntimeError, match="flaky"):
        eng.run()
    done = eng.run()                         # resume after the exception
    assert sorted(done) == [0, 1]
    np.testing.assert_array_equal(done[1].output, _ref(params, cfg, p1, 6))


def test_submit_rejects_nonpositive_budget(served_model):
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(), max_len=16)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(0, np.arange(4, dtype=np.int32),
                           max_new_tokens=0))


def test_duplicate_uid_rejected_until_finished(served_model):
    cfg, params = served_model
    eng = InferenceEngine(params, cfg, ServeConfig(greedy=True),
                          max_len=16)
    eng.submit(Request(0, np.arange(4, dtype=np.int32), max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(Request(0, np.arange(4, dtype=np.int32),
                           max_new_tokens=2))
    eng.run()
    # a finished uid may be reused; old bookkeeping is dropped
    h = eng.submit(Request(0, np.arange(5, dtype=np.int32),
                           max_new_tokens=3))
    assert len(h.result()) == 3
    eng.clear_finished()
    assert not eng.done and not eng.handles


@pytest.mark.slow
def test_sharded_engine_token_identity():
    """Tensor-parallel engine (mesh=(data=1, model=2), packed weights
    placed per sharding.rules, shard_map kernel launches) produces
    greedy outputs token-identical to the unsharded engine. Runs in a
    subprocess with forced host devices (the launch/dryrun.py trick) so
    the main test process stays single-device."""
    out = run_multidevice("""
        import jax, numpy as np
        from repro.core.pipeline import QuantConfig, nanoquant_quantize
        from repro.data import calib_batches
        from repro.launch.mesh import make_serving_mesh
        from repro.models import transformer as T
        from repro.models.config import ModelConfig
        from repro.serve.engine import InferenceEngine, ServeConfig
        from repro.serve.scheduler import Request

        # f32 so greedy argmax cannot flip on partitioned-reduction
        # reordering noise; dims chosen so col (d_out 64/32) AND row
        # (packed d_in 2/4 words) linears both divide the 2-way axis.
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=256, loss_chunk=0, remat=False,
                          dtype="float32")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        calib = calib_batches(cfg, 2, 32, batch=2)
        qcfg = QuantConfig(admm_iters=2, t_pre=0, t_post=0, t_glob=0,
                           rank_align=32, min_dim=32)
        qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)

        prompts = [np.arange(1, 7, dtype=np.int32),
                   np.arange(3, 12, dtype=np.int32),
                   np.arange(2, 10, dtype=np.int32)]
        budgets = [6, 3, 5]

        def run(mesh):
            eng = InferenceEngine(qp, cfg, ServeConfig(greedy=True),
                                  max_batch=2, max_len=32, mesh=mesh)
            for uid, (p, b) in enumerate(zip(prompts, budgets)):
                eng.submit(Request(uid, p, max_new_tokens=b))
            done = eng.run()
            return {u: r.output for u, r in done.items()}, eng

        ref, _ = run(None)
        got, eng = run(make_serving_mesh(2))
        assert eng.mesh is not None and eng.params is not None
        # packed U really is d_out-sharded on the model axis
        qu = eng.params["layers"]["attn"]["wq"]["qu_t"]
        spec = qu.sharding.spec
        assert spec[-1] == "model", spec
        for u in ref:
            np.testing.assert_array_equal(ref[u], got[u])
        print("sharded engine token-identity OK")
    """, devices=2)
    assert "OK" in out


def test_quantized_model_serves_on_engine(served_model):
    """Packed params are a drop-in for the engine (paper deployment)."""
    from repro.core.pipeline import QuantConfig, nanoquant_quantize
    from repro.data import calib_batches
    cfg, params = served_model
    calib = calib_batches(cfg, 4, 32, batch=2)
    qcfg = QuantConfig(admm_iters=4, t_pre=0, t_post=2, t_glob=0,
                       rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    eng = InferenceEngine(qp, cfg, ServeConfig(max_new_tokens=4),
                          max_batch=2, max_len=16)
    eng.submit(Request(0, np.arange(6, dtype=np.int32)))
    eng.submit(Request(1, np.arange(4, dtype=np.int32)))
    done = eng.run()
    assert len(done) == 2
    for r in done.values():
        assert np.isfinite(r.output).all()
