"""Effective-BPW / storage accounting (paper App. F, Tables 13–14)."""
import math

import pytest

from repro.core import bpw

# Llama-2-7B decoder linears: (n=d_out, m=d_in) per layer x 32
_L27 = 32 * [(4096, 4096)] * 4 + 32 * [(11008, 4096)] * 2 + 32 * [(4096, 11008)]


def _l27_shapes():
    per_layer = [(4096, 4096)] * 4 + [(11008, 4096)] * 2 + [(4096, 11008)]
    return per_layer * 32


def test_paper_bpw_bounds_llama2_7b():
    """Table 14 row L2-7: BiLLM (2.88, 2.89), STBLLM 4:8 (3.50, 3.51),
    6:8 (4.00, 4.01), 8:8 (4.13, 4.14), ARB (2.51, 2.52), HBLLM_R
    (3.25, 3.27). c ranges over [0, 50]."""
    shapes = _l27_shapes()
    checks = {
        "billm": (2.88, 2.89),
        "stbllm_4:8": (3.50, 3.51),
        "stbllm_6:8": (4.00, 4.01),
        "stbllm_8:8": (4.13, 4.14),
        "arbllm_rc": (2.51, 2.52),
        "hbllm_row": (3.25, 3.27),
    }
    for method, (lo, hi) in checks.items():
        got = bpw.model_bpw(shapes, method)
        assert lo - 0.02 <= got <= hi + 0.02, (method, got)


def test_nanoquant_bpw_hits_target():
    shapes = _l27_shapes()
    for target in (1.0, 0.8, 0.55):
        got = bpw.model_bpw(shapes, "nanoquant", bpw=target)
        assert got <= target + 1e-6, (target, got)
        assert got >= target * 0.93, (target, got)   # alignment slack


def test_nanoquant_model_size_llama2_7b():
    """Table 13: NanoQuant L2-7 = 1.33 GB at 1 bit (FP16 residue =
    embeddings + head + norms ~ 0.53 GB)."""
    shapes = _l27_shapes()
    fp_params = 2 * 32000 * 4096 + 33 * 4096     # embed + head + rmsnorms
    size = bpw.model_size_gb(shapes, "nanoquant", fp_params=fp_params,
                             bpw=1.0)
    assert 1.25 <= size <= 1.42, size


def test_dbf_has_extra_rank_scale():
    n, m, r = 4096, 4096, 1024
    assert bpw.dbf_bits(n, m, r) - bpw.nanoquant_bits(n, m, r) == 16 * r


def test_rank_for_bpw_inverse():
    for (n, m) in [(4096, 4096), (11008, 4096), (1536, 8192)]:
        for target in (1.0, 0.8, 0.55, 2.0):
            r = bpw.rank_for_bpw(n, m, target, align=32)
            if r > 32:       # not clamped
                assert bpw.nanoquant_bpw(n, m, r) <= target + 1e-9
                assert bpw.nanoquant_bpw(n, m, r + 32) > target


def test_rank_alignment_and_floor():
    r = bpw.rank_for_bpw(64, 64, 1.0, align=32, r_min=32)
    assert r == 32
    assert bpw.rank_for_bpw(8192, 8192, 1.0, align=128) % 128 == 0


def test_sub1bit_is_sub1bit():
    """The headline claim: NanoQuant reaches < 1 bit per weight where
    in-place binary PTQ methods structurally cannot."""
    shapes = _l27_shapes()
    nq = bpw.model_bpw(shapes, "nanoquant", bpw=0.8)
    assert nq < 1.0
    for method in ("billm", "arbllm_rc", "hbllm_row", "hbllm_col"):
        assert bpw.model_bpw(shapes, method) >= 2.0
