"""Robust diagonal K-FAC preconditioners (paper Alg. 1 Phase 1, Eq. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precond
from repro.models import transformer as T


def test_robust_diag_shrinkage_formula():
    ms = np.array([4.0, 1.0, 0.25])
    d_raw = np.sqrt(ms)                      # [2, 1, .5]
    gamma = 0.4
    want = (1 - gamma) * d_raw + gamma * d_raw.mean()
    want = want / want.mean()
    got = np.asarray(precond.robust_diag(ms, gamma))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_robust_diag_gamma1_is_uniform():
    d = np.asarray(precond.robust_diag(np.array([9.0, 1.0, 4.0]), 1.0))
    np.testing.assert_allclose(d, np.ones(3), rtol=1e-6)


def test_robust_diag_clipping():
    d = np.asarray(precond.robust_diag(
        np.array([1e12, 1.0]), 0.0, tau_max=10.0))
    assert d.max() / d.min() <= 11.0


def test_collect_stats_matches_manual(tiny_dense_cfg, tiny_params):
    """Forward taps must accumulate E[x²] per input channel of each
    linear, measured against a manual recomputation of the wq input."""
    cfg, params = tiny_dense_cfg, tiny_params
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batches = [{"tokens": toks, "labels": toks}]
    stats = precond.collect_stats(T.loss_fn, params, cfg, batches)

    got = stats.mean_sq("layers", "attn.wq", "in", 0)
    assert got is not None and got.shape == (cfg.d_model,)

    # manual: wq input of layer 0 = rms_norm(embed(tokens), ln1)
    from repro.models import layers as L
    x = T.embed_tokens(params, cfg, toks)
    lp = jax.tree.map(lambda l: l[0], params["layers"])
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps).astype(jnp.float32)
    want = np.asarray(jnp.mean(h * h, axis=(0, 1)))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-2, atol=1e-4)

    # gradient taps exist for the same layer
    gout = stats.mean_sq("layers", "attn.wq", "out", 0)
    assert gout is not None and gout.shape == (cfg.n_heads * cfg.head_dim,)
    assert np.isfinite(np.asarray(gout)).all()


def test_preconditioners_fallback_identity(tiny_dense_cfg):
    c = precond.StatCollector()
    d_in, d_out = precond.preconditioners_for(c, "layers", "nope", 0,
                                              8, 12, 0.2)
    np.testing.assert_array_equal(np.asarray(d_in), np.ones(8))
    np.testing.assert_array_equal(np.asarray(d_out), np.ones(12))
