"""Prefix cache subsystem (serve.prefix + the allocator's sharing
primitives): chained-chunk index semantics, refcount/COW/eviction
allocator invariants, cost-aware preemption victim selection, scheduler
fairness for requeued resumes, uid-reuse/eviction aliasing, and the
engine-level greedy token-identity guarantees (shared prompts,
full-cover duplicates, page-boundary off-by-ones, speculative rollback
over shared pages)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant.surgery import abstract_quantized_params
from repro.serve import (InferenceEngine, PagedKVState, PrefixCache,
                         Request, ServeConfig, pick_preemption_victim)
from repro.serve.scheduler import SlotScheduler

PS = 8


@pytest.fixture()
def kv(tiny_dense_cfg):
    return PagedKVState(tiny_dense_cfg, max_batch=3, max_len=48,
                        page_size=PS, n_pages=20)


def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=(n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# index semantics: chained chunk hashing, partial chunks, left context
# ---------------------------------------------------------------------------


def test_chunk_index_match_register(kv):
    pc = PrefixCache(kv)
    prompt = _toks(20)                       # 2 full chunks + 4 tokens
    row = kv.admit(0, 20)["linear"]
    assert pc.register(prompt, 20, row) == 2
    assert len(pc) == 2                      # the partial chunk is not indexed
    p, pages, keys = pc.match(prompt)
    assert p == 16 and pages == [int(row[0]), int(row[1])] and len(keys) == 2
    # same first chunk, different second chunk: one-chunk match
    other = prompt.copy()
    other[12] = (other[12] + 1) % 256
    assert pc.match(other)[0] == PS
    # a chunk is keyed in its left context: the second chunk's tokens at
    # the START of a prompt must not resolve the indexed entry
    assert pc.match(prompt[PS:])[0] == 0
    # sub-chunk prompts never match (full chunks only)
    assert pc.match(prompt[:PS - 1])[0] == 0
    # registering the same prompt again adopts nothing new
    assert pc.register(prompt, 20, row) == 0


def test_refcount_sharing_release_and_eviction(kv):
    pc = PrefixCache(kv)
    prompt = _toks(20, seed=1)
    row0 = kv.admit(0, 20)["linear"]
    pc.register(prompt, 20, row0)
    shared = [int(row0[0]), int(row0[1])]
    assert all(kv.ref[p] == 1 and kv.cached[p] for p in shared)
    # second slot maps the indexed pages read-only: refs bump, only the
    # suffix page is fresh
    ids = kv.admit(1, 20, shared=shared)["linear"]
    assert list(ids[:2]) == shared
    assert all(kv.ref[p] == 2 for p in shared)
    assert kv.shared_page_count == 2
    # owner leaves: shared pages survive with the sharer's ref; its
    # private partial-chunk page frees
    free0 = kv.free_pages
    kv.release(0)
    assert all(kv.ref[p] == 1 for p in shared)
    assert kv.free_pages == free0 + 1
    # last sharer leaves: refcount zero, but index-held pages must NOT
    # hit the free list — they are evictable-on-demand instead
    kv.release(1)
    assert all(kv.ref[p] == 0 and kv.cached[p] for p in shared)
    assert kv.used_pages == kv.cached_page_count == 2
    assert kv.available_pages == kv.free_pages + 2
    # reclaim evicts leaf-first (the chain stays rooted), LRU order
    assert pc.reclaim(1) == 1
    assert len(pc) == 1 and kv.cached_page_count == 1
    assert pc.reclaim(1) == 1
    assert len(pc) == 0 and kv.used_pages == 0
    assert pc.stats["evicted_pages"] == 2


def test_protected_entries_are_not_evictable(kv):
    pc = PrefixCache(kv)
    prompt = _toks(16, seed=2)
    row = kv.admit(0, 16)["linear"]
    pc.register(prompt, 16, row)
    kv.release(0)
    _, _, keys = pc.match(prompt)
    assert pc.evictable_count() == 2        # leaf + transitively its parent
    pc.protect(keys)
    assert pc.evictable_count() == 0
    assert pc.reclaim(2) == 0 and len(pc) == 2
    pc.unprotect_all()
    assert pc.reclaim(2) == 2 and len(pc) == 0


def test_interior_entry_outlives_indexed_extensions(kv):
    pc = PrefixCache(kv)
    prompt = _toks(24, seed=3)              # chain of 3 chunks
    row = kv.admit(0, 24)["linear"]
    pc.register(prompt, 24, row)
    kv.release(0)
    # only the chain tail is a leaf; one reclaim step must take it, not
    # an interior entry (a surviving key keeps its whole chain behind it)
    pc.reclaim(1)
    assert pc.match(prompt)[0] == 16
    pc.reclaim(1)
    assert pc.match(prompt)[0] == PS


def test_cow_rewires_writer_only(kv):
    pc = PrefixCache(kv)
    prompt = _toks(16, seed=4)
    row0 = kv.admit(0, 16)["linear"]
    pc.register(prompt, 16, row0)
    shared = [int(row0[0]), int(row0[1])]
    kv.admit(1, 16, shared=shared)
    # slot 1's next write lands in row 15 -> logical page 1, shared
    assert kv.next_shared_write_page(1, 15, 16) == 1
    assert kv.next_shared_write_page(1, 0, 8) == 0
    src, dst = kv.cow(1, 1)
    assert src == shared[1] and dst not in shared
    assert kv.tables["linear"][1][1] == dst
    assert kv.tables["linear"][0][1] == src      # owner untouched
    assert kv.ref[src] == 1 and kv.ref[dst] == 1
    assert kv.next_shared_write_page(1, 15, 16) is None
    # pool dry (all pages mapped or cached, nothing evictable): cow
    # fails gracefully instead of handing out a live page
    while kv.free_pages:
        kv._alloc(1)
    assert kv.cow(1, 0) is None


def test_lru_eviction_order_and_probe_neutrality(kv):
    pc = PrefixCache(kv)
    a, b = _toks(PS, seed=5), _toks(PS, seed=6)
    row0 = kv.admit(0, PS)["linear"]
    pc.register(a, PS, row0)
    row1 = kv.admit(1, PS)["linear"]
    pc.register(b, PS, row1)
    kv.release(0)
    kv.release(1)
    pc.match(a)                             # a is now most-recently used
    pc.reclaim(1)
    assert pc.match(a)[0] == PS and pc.match(b)[0] == 0
    # match_len is a probe: costing preemption victims must not distort
    # recency, so b2 (probed last) is still evicted before a
    row1 = kv.admit(1, PS)["linear"]
    b2 = _toks(PS, seed=7)
    pc.register(b2, PS, row1)
    kv.release(1)
    pc.match(a)
    assert pc.match_len(b2) == PS
    pc.reclaim(1)
    assert pc.match(a)[0] == PS and pc.match(b2)[0] == 0


# ---------------------------------------------------------------------------
# cost-aware preemption
# ---------------------------------------------------------------------------


def test_pick_preemption_victim_policy():
    # minimum recompute cost wins
    assert pick_preemption_victim([(0, 30, 1), (1, 4, 0), (2, 12, 2)]) == 1
    # equal costs degenerate to youngest-first (largest admission step)
    assert pick_preemption_victim([(0, 8, 1), (1, 8, 5), (2, 8, 3)]) == 1
    # full tie: highest slot
    assert pick_preemption_victim([(0, 8, 2), (2, 8, 2)]) == 2
    with pytest.raises(AssertionError):
        pick_preemption_victim([])


def test_engine_victim_prefers_cheap_recompute(tiny_dense_cfg, tiny_params):
    """The slot whose resume the index already covers (page-aligned
    prompt: only emitted tokens would re-prefill) is preempted before an
    older slot with an uncovered tail — even though youngest-first would
    pick the opposite."""
    cfg, params = tiny_dense_cfg, tiny_params
    eng = InferenceEngine(params, cfg,
                          ServeConfig(greedy=True, page_size=PS),
                          max_batch=2, max_len=48)
    expensive = _toks(23, seed=8)     # 2 chunks indexed + 7-token tail
    cheap = _toks(32, seed=9)         # fully indexed (page-aligned)
    eng.submit(Request(0, expensive, max_new_tokens=8))
    eng.step()                        # admit 0 (older)
    eng.submit(Request(1, cheap, max_new_tokens=8))
    eng.step()                        # admit 1 (younger)
    assert eng.active.sum() == 2
    assert eng._select_victim() == eng.slot_of[1]
    # without the index every resume is fully recomputed, and the longer
    # fully-covered prompt is now the EXPENSIVE one -> victim flips
    eng.prefix = None
    assert eng._select_victim() == eng.slot_of[0]


# ---------------------------------------------------------------------------
# scheduler fairness: requeued resumes stay at the head
# ---------------------------------------------------------------------------


def test_resume_keeps_front_of_queue_on_gate_reject():
    sched = SlotScheduler(2)
    fresh_a, fresh_b, resume = object(), object(), object()
    sched.submit(fresh_a)
    sched.submit(fresh_b)
    sched.requeue(resume)
    assert list(sched.pending) == [resume, fresh_a, fresh_b]
    # head-of-line gating: a rejected resume blocks later fresh admits
    # (no starvation by smaller requests) and stays at the front
    assert sched.admit_batch(gate=lambda item: item is not resume) == []
    assert list(sched.pending) == [resume, fresh_a, fresh_b]
    out = sched.admit_batch(gate=lambda item: True)
    assert [item for _, item in out] == [resume, fresh_a]


# ---------------------------------------------------------------------------
# engine-level token identity
# ---------------------------------------------------------------------------


def _serve(params, cfg, prompts, budgets, scfg, max_batch=3, max_len=48,
           uids=None, eng=None):
    eng = eng or InferenceEngine(params, cfg, scfg, max_batch=max_batch,
                                 max_len=max_len)
    for uid, (p, b) in zip(uids or range(len(prompts)),
                           zip(prompts, budgets)):
        eng.submit(Request(uid, p, max_new_tokens=b))
    done = eng.run()
    return {u: r.output for u, r in done.items()}, eng


def _assert_prefix_matches_plain(params, cfg, prompts, budgets, scfg=None,
                                 **kw):
    scfg = scfg or ServeConfig(greedy=True, page_size=PS)
    plain, _ = _serve(params, cfg, prompts, budgets,
                      dataclasses.replace(scfg, prefix_cache=False), **kw)
    shared, eng = _serve(params, cfg, prompts, budgets, scfg, **kw)
    assert eng.prefix is not None
    for u in plain:
        np.testing.assert_array_equal(plain[u], shared[u])
    assert not eng.kv.ref.any(), "drained engine must hold no mappings"
    assert eng.kv.used_pages == eng.kv.cached_page_count
    return eng


def test_engine_shared_prompt_identity(tiny_dense_cfg, tiny_params):
    cfg, params = tiny_dense_cfg, tiny_params
    sys_p = _toks(16, seed=10)
    prompts = [np.concatenate([sys_p, _toks(n, seed=20 + n)])
               for n in (3, 7, 5, 11)]
    eng = _assert_prefix_matches_plain(params, cfg, prompts, [6, 8, 5, 7])
    assert eng.stats["prefix_hit_tokens"] > 0
    assert eng.stats["shared_pages"] > 0


def test_engine_full_cover_duplicate_cow_identity(tiny_dense_cfg,
                                                  tiny_params):
    """Exact page-aligned duplicates: a full-cover match re-emits from
    the last prompt token, so the tail page is copy-on-written at
    admission — and outputs still match the no-sharing engine."""
    cfg, params = tiny_dense_cfg, tiny_params
    prompt = _toks(16, seed=11)
    eng = _assert_prefix_matches_plain(
        params, cfg, [prompt, prompt.copy(), prompt.copy()], [6, 6, 6])
    assert eng.stats["cow_copies"] >= 2
    assert eng.stats["prefix_hit_tokens"] >= 32


def test_engine_page_boundary_off_by_ones(tiny_dense_cfg, tiny_params):
    """Prompt lengths straddling every page boundary around the shared
    chunk: ps-1 (no full chunk), ps, ps+1, 2ps, 2ps+1."""
    cfg, params = tiny_dense_cfg, tiny_params
    base = _toks(2 * PS + 1, seed=12)
    prompts = [base[:PS - 1], base[:PS], base[:PS + 1],
               base[:2 * PS], base]
    _assert_prefix_matches_plain(params, cfg, prompts, [5, 5, 5, 5, 5],
                                 max_batch=2, max_len=32)


def test_uid_reuse_after_eviction_cannot_alias(tiny_dense_cfg, tiny_params):
    """A tiny pool forces index eviction mid-trace; the SAME engine then
    re-serves reused uids with different prompts. The index keys on
    token content (raw bytes compared on every lookup), so neither the
    reused uids nor the recycled pages can resolve stale entries —
    outputs must match a sharing-free engine exactly."""
    cfg, params = tiny_dense_cfg, tiny_params
    scfg = ServeConfig(greedy=True, page_size=PS, kv_pool_pages=10)
    first = [np.concatenate([_toks(16, seed=13), _toks(4, seed=30 + i)])
             for i in range(4)]
    second = [np.concatenate([_toks(16, seed=14), _toks(4, seed=40 + i)])
              for i in range(4)]
    plain, _ = _serve(params, cfg, first + second, [6] * 8,
                      dataclasses.replace(scfg, prefix_cache=False),
                      max_batch=3, max_len=32)
    out1, eng = _serve(params, cfg, first, [6] * 4, scfg,
                       max_batch=3, max_len=32)
    out2, _ = _serve(params, cfg, second, [6] * 4, scfg, eng=eng,
                     uids=range(4))
    for u in range(4):
        np.testing.assert_array_equal(plain[u], out1[u])
        np.testing.assert_array_equal(plain[4 + u], out2[u])
    assert eng.stats["evicted_pages"] > 0, \
        "pool never pressured the index — the test lost its premise"
    assert not eng.kv.ref.any()


def test_prefix_clear_requires_drained_and_empties(tiny_dense_cfg,
                                                   tiny_params):
    cfg, params = tiny_dense_cfg, tiny_params
    prompt = _toks(16, seed=15)
    _, eng = _serve(params, cfg, [prompt], [4],
                    ServeConfig(greedy=True, page_size=PS))
    assert eng.kv.cached_page_count == 2
    assert eng.prefix.clear() == 2
    assert len(eng.prefix) == 0 and eng.kv.used_pages == 0


# ---------------------------------------------------------------------------
# speculative rollback over shared pages
# ---------------------------------------------------------------------------


def _random_packed(cfg, seed=0):
    """Random packed params (unit scales) — the rank-truncated draft
    genuinely disagrees with the verifier, so rollback fires (same
    construction as test_speculative)."""
    tpl = abstract_quantized_params(cfg, target_bpw=2.0)
    rng = np.random.default_rng(seed)

    def fill(path, s):
        last = getattr(path[-1], "key", str(path[-1]))
        if s.dtype == jnp.uint32:
            return jnp.asarray(rng.integers(
                0, 2**32, size=s.shape, dtype=np.uint64).astype(np.uint32))
        if last in ("s1", "s2"):
            return jnp.ones(s.shape, s.dtype)
        return jnp.asarray(rng.normal(0, 0.05, s.shape).astype(s.dtype))

    return jax.tree_util.tree_map_with_path(fill, tpl)


def test_spec_rollback_on_shared_pages_is_safe(tiny_dense_cfg):
    """Speculative drafts write past the committed frontier into pages a
    prefix hit may share; the reserve path COWs them first and rollback
    only unrefs — so cached pages survive rejected drafts intact, and a
    second trace served through the warmed index stays token-identical
    to the sharing-free engine."""
    cfg = tiny_dense_cfg
    params = _random_packed(cfg, seed=16)
    sys_p = _toks(16, seed=17)
    prompts = [sys_p.copy(),
               np.concatenate([sys_p, _toks(5, seed=18)]),
               np.concatenate([sys_p, _toks(9, seed=19)])]
    budgets = [8, 10, 8]
    scfg = ServeConfig(greedy=True, page_size=PS, spec_rank_frac=0.5,
                       spec_k=4)
    plain, _ = _serve(params, cfg, prompts, budgets,
                      ServeConfig(greedy=True, page_size=PS,
                                  prefix_cache=False))
    out1, eng = _serve(params, cfg, prompts, budgets, scfg)
    out2, _ = _serve(params, cfg, prompts, budgets, scfg, eng=eng,
                     uids=[10, 11, 12])
    for u in plain:
        np.testing.assert_array_equal(plain[u], out1[u])
        np.testing.assert_array_equal(plain[u], out2[10 + u])
    assert eng.stats["spec_rollback_tokens"] > 0, \
        "draft never rejected — rollback path untested"
    assert eng.stats["prefix_hit_tokens"] > 0
    assert not eng.kv.ref.any()
    assert eng.kv.used_pages == eng.kv.cached_page_count
