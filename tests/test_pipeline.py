"""End-to-end NanoQuant pipeline (paper Alg. 1) integration tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.baselines import rtn_binarize, xnor_binarize
from repro.core.pipeline import QuantConfig, nanoquant_quantize
from repro.data import SyntheticCorpus, calib_batches
from repro.data.synthetic import eval_perplexity
from repro.models import transformer as T

_FAST = dict(admm_iters=8, t_pre=4, t_post=6, t_glob=4, rank_align=32,
             min_dim=32)


@pytest.fixture(scope="module")
def quantized_tiny(tiny_dense_cfg_mod):
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, **_FAST)
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    return cfg, params, calib, qp, report


@pytest.fixture(scope="module")
def tiny_dense_cfg_mod():
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      loss_chunk=0, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, n_samples=8, seq=48, batch=4)
    return cfg, params, calib


def test_quantized_structure_and_forward(quantized_tiny):
    cfg, params, calib, qp, report = quantized_tiny
    # every attention/ffn linear packed
    lp0 = jax.tree.map(lambda l: l[0], qp["layers"])
    for path in ("attn", "ffn"):
        assert path in lp0
    assert "qu_t" in lp0["attn"]["wq"] and "qv" in lp0["attn"]["wq"]
    assert lp0["attn"]["wq"]["qu_t"].dtype == jnp.uint32
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert len(report["blocks"]) == cfg.n_layers
    assert all(np.isfinite(b["block_err"]) for b in report["blocks"])


def test_quantized_beats_inplace_binarization(quantized_tiny):
    """Paper Table 2 ordering at tiny scale: NanoQuant PPL must be
    dramatically below RTN / XNOR in-place binarization."""
    cfg, params, calib, qp, _ = quantized_tiny
    evalb = calib_batches(cfg, 8, 48, seed=123)
    ppl_q = eval_perplexity(T.loss_fn, qp, cfg, evalb)

    def binarize_all(params, fn):
        def walk(d):
            out = {}
            for k, v in d.items():
                if isinstance(v, dict):
                    if "w" in v and not isinstance(v["w"], dict):
                        out[k] = dict(v, w=fn(v["w"]).astype(v["w"].dtype))
                    else:
                        out[k] = walk(v)
                else:
                    out[k] = v
            return out
        new = dict(params)
        new["layers"] = walk(params["layers"])
        return new

    for fn in (rtn_binarize, xnor_binarize):
        ppl_b = eval_perplexity(T.loss_fn, binarize_all(params, fn), cfg,
                                evalb)
        # random-init teacher: both sit near noise level; require
        # NanoQuant to be at-least-competitive (the trained-teacher
        # orderings live in benchmarks/table2 + EXPERIMENTS.md)
        assert ppl_q < ppl_b * 1.10, (ppl_q, ppl_b)


def test_component_ablation_orderings(tiny_dense_cfg_mod):
    """Paper Table 6 direction: init-only must be far better than
    nothing; the full pipeline must beat init-only."""
    cfg, params, calib = tiny_dense_cfg_mod
    evalb = calib_batches(cfg, 8, 48, seed=321)

    def run(**kw):
        qcfg = QuantConfig(target_bpw=1.0, **_FAST, **kw)
        qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
        return eval_perplexity(T.loss_fn, qp, cfg, evalb)

    full = run()
    init_only = run(skip_tune_fp=True, skip_ste=True, skip_kd=True)
    assert np.isfinite(full) and np.isfinite(init_only)
    assert full <= init_only * 1.10          # refinement helps (or ties)


def test_init_method_ablation_runs(tiny_dense_cfg_mod):
    """Table 5: all three initializers must run through the pipeline."""
    cfg, params, calib = tiny_dense_cfg_mod
    for method in ("lb_admm", "dual_svid", "dbf_admm"):
        qcfg = QuantConfig(target_bpw=1.0, init_method=method, **_FAST)
        qp, _ = nanoquant_quantize(params, cfg, calib,
                                   dataclasses.replace(qcfg, t_pre=2,
                                                       t_post=2, t_glob=2),
                                   verbose=False)
        logits = T.forward(qp, cfg, calib[0]["tokens"])
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), method


def test_sub1bit_target(tiny_dense_cfg_mod):
    """bpw=0.8 quantization runs and packs below 1 bit/weight."""
    from repro.core.packing import packed_nbytes
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=0.8, admm_iters=6, t_pre=2, t_post=2,
                       t_glob=2, rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    lp0 = jax.tree.map(lambda l: l[0], qp["layers"])
    q = lp0["ffn"]["w_gate"]
    nbits = 8 * packed_nbytes(q)
    nweights = cfg.d_model * cfg.d_ff
    # scales are fp16-accounted; tiny dims make the floor dominate —
    # just require strictly below in-place binarization's 1 bit + scales
    assert nbits / nweights < 1.6
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_quantize_hybrid_family():
    """Shared-attention (zamba2-style) block path through the pipeline."""
    cfg = dataclasses.replace(configs.get_smoke("zamba2-1.2b"),
                              n_layers=2, attn_every=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, 4, 32, batch=2)
    qcfg = QuantConfig(target_bpw=1.0, admm_iters=4, t_pre=2, t_post=2,
                       t_glob=2, rank_align=32, min_dim=16)
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    assert "qu_t" in qp["shared_attn"]["attn"]["wq"]
    mix0 = jax.tree.map(lambda l: l[0], qp["layers"])["mixer"]
    assert "qu_t" in mix0["wx"]
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_abstract_surgery_matches_pipeline_structure(tiny_dense_cfg_mod):
    """The dry-run's abstract quantized tree must match the real
    pipeline output exactly (structure, shapes, dtypes)."""
    from repro.quant.surgery import abstract_quantized_params
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, admm_iters=4, t_pre=0, t_post=0,
                       t_glob=0, rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    abstract = abstract_quantized_params(cfg, target_bpw=1.0, min_dim=32,
                                         rank_align=32)
    real_td = jax.tree.structure(qp)
    abs_td = jax.tree.structure(abstract)
    assert real_td == abs_td
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(abstract),
            jax.tree_util.tree_leaves_with_path(qp)):
        assert tuple(a.shape) == tuple(b.shape), (kp, a.shape, b.shape)
        assert a.dtype == b.dtype, (kp, a.dtype, b.dtype)


# ---------------------------------------------------------------------------
# fault tolerance: journaling, resume, fallback ladder (docs/quantization.md)
# ---------------------------------------------------------------------------

_RESUME_FAST = dict(admm_iters=4, t_pre=2, t_post=2, t_glob=2,
                    rank_align=32, min_dim=32)


@pytest.fixture(scope="module")
def journaled_tiny(tiny_dense_cfg_mod, tmp_path_factory):
    """One journaled baseline run every resume edge case compares to."""
    from repro.checkpoint.journal import _crc_leaves
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, **_RESUME_FAST)
    d = str(tmp_path_factory.mktemp("journal_base"))
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg,
                                    verbose=False, journal_dir=d)
    return cfg, params, calib, qcfg, d, _crc_leaves(qp), report


def _journal_copy(src, tmp_path):
    import shutil
    dst = str(tmp_path / "journal")
    shutil.copytree(src, dst)
    return dst


@pytest.mark.chaos_quant
def test_crash_between_save_and_journal_resumes_bit_identical(
        journaled_tiny, tmp_path):
    """A crash in the orphan-checkpoint window (block saved, journal
    entry not yet appended) must resume to a bit-identical artifact."""
    from repro.checkpoint.journal import _crc_leaves
    from repro.quant.faults import (InjectedPipelineCrash, QuantFault,
                                    QuantFaultPlan)
    cfg, params, calib, qcfg, _, crc0, rep0 = journaled_tiny
    d = str(tmp_path / "j")
    plan = QuantFaultPlan([QuantFault(block=1, kind="crash_after_save")])
    with pytest.raises(InjectedPipelineCrash):
        nanoquant_quantize(params, cfg, calib, qcfg, verbose=False,
                           journal_dir=d, faults=plan)
    qp, rep = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False,
                                 journal_dir=d, resume=True)
    assert _crc_leaves(qp) == crc0
    strip = lambda r: {k: v for k, v in r.items() if k != "wall_s"}
    assert strip(rep) == strip(rep0)


def test_resume_refuses_different_run(journaled_tiny):
    """A journal must never be resumed against a different model /
    quant config / calibration set."""
    from repro.checkpoint.journal import (JournalError, QuantJournal,
                                          run_fingerprint)
    cfg, params, calib, qcfg, d, _, _ = journaled_tiny
    other = dataclasses.replace(qcfg, target_bpw=0.8)
    fp = run_fingerprint(params, cfg, other, calib, 2)
    with pytest.raises(JournalError, match="quant_config"):
        QuantJournal(d).entries_for_resume(fp)
    fp2 = run_fingerprint(params, cfg, qcfg, calib[:1], 2)
    with pytest.raises(JournalError, match="calib_crc"):
        QuantJournal(d).entries_for_resume(fp2)


@pytest.mark.chaos_quant
def test_corrupt_journal_entry_names_block(journaled_tiny, tmp_path):
    from repro.checkpoint.journal import (JournalError, QuantJournal,
                                          run_fingerprint)
    from repro.quant.faults import _corrupt_last_line
    cfg, params, calib, qcfg, d0, _, _ = journaled_tiny
    d = _journal_copy(d0, tmp_path)
    j = QuantJournal(d)
    _corrupt_last_line(j.path)          # last line = block 1's entry
    fp = run_fingerprint(params, cfg, qcfg, calib, 2)
    with pytest.raises(JournalError, match=r"layers\[1\]") as ei:
        j.entries_for_resume(fp)
    assert ei.value.block == "layers[1]"


def test_missing_block_checkpoint_names_block(journaled_tiny, tmp_path):
    import shutil
    from repro.checkpoint.journal import (JournalError, QuantJournal,
                                          run_fingerprint)
    cfg, params, calib, qcfg, d0, _, _ = journaled_tiny
    d = _journal_copy(d0, tmp_path)
    shutil.rmtree(f"{d}/blocks/step_00000000")
    fp = run_fingerprint(params, cfg, qcfg, calib, 2)
    with pytest.raises(JournalError, match=r"layers\[0\]") as ei:
        QuantJournal(d).entries_for_resume(fp)
    assert ei.value.block == "layers[0]"


def test_torn_final_append_tolerated(journaled_tiny, tmp_path):
    """A truncated trailing line (crash mid-append) is dropped and the
    file truncated back to the valid prefix — not an error."""
    from repro.checkpoint.journal import QuantJournal, run_fingerprint
    cfg, params, calib, qcfg, d0, _, _ = journaled_tiny
    d = _journal_copy(d0, tmp_path)
    j = QuantJournal(d)
    with open(j.path, "ab") as f:
        f.write(b'{"payload": {"kind": "block", "bi"')   # torn append
    fp = run_fingerprint(params, cfg, qcfg, calib, 2)
    done = j.entries_for_resume(fp)
    assert sorted(done) == [0, 1]
    with open(j.path, "rb") as f:
        assert f.read().endswith(b"}\n")                 # truncated back


@pytest.mark.chaos_quant
def test_nan_init_walks_fallback_ladder(tiny_dense_cfg_mod):
    """Injected NaN latents at block 0 must fall back down the init
    ladder and record the switch in the report row."""
    from repro.quant.faults import QuantFault, QuantFaultPlan
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, **_RESUME_FAST)
    plan = QuantFaultPlan([QuantFault(block=0, kind="nan_init",
                                      linear=1, iteration=5)])
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg,
                                    verbose=False, faults=plan)
    row = report["blocks"][0]
    assert row["init_method"] == "dbf_admm"
    assert row["fallbacks"][0]["method"] == "lb_admm"
    assert row["fallbacks"][0]["iteration"] == 5
    assert report["blocks"][1]["fallbacks"] == []
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.chaos_quant
def test_fallback_ladder_exhaustion_is_structured(tiny_dense_cfg_mod):
    """With fallbacks disabled, a poisoned block raises a structured
    QuantizationError naming block/layer/reason — never NaN packing."""
    from repro.core.admm import QuantizationError
    from repro.quant.faults import QuantFault, QuantFaultPlan
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, fallback_inits="", **_RESUME_FAST)
    plan = QuantFaultPlan([QuantFault(block=0, kind="nan_init",
                                      linear=0, iteration=2)])
    with pytest.raises(QuantizationError) as ei:
        nanoquant_quantize(params, cfg, calib, qcfg, verbose=False,
                           faults=plan)
    e = ei.value
    assert e.block == "layers[0]"
    assert "exhausted" in e.reason
    assert e.iteration == 2


def test_resume_without_journal_dir_rejected(tiny_dense_cfg_mod):
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, **_RESUME_FAST)
    with pytest.raises(ValueError, match="journal_dir"):
        nanoquant_quantize(params, cfg, calib, qcfg, verbose=False,
                           resume=True)


# ---------------------------------------------------------------------------
# preflight validation (quant.preflight)
# ---------------------------------------------------------------------------


def test_preflight_accepts_good_inputs(tiny_dense_cfg_mod):
    from repro.quant.preflight import preflight
    cfg, params, calib = tiny_dense_cfg_mod
    info = preflight(params, cfg, calib)
    assert info["n_batches"] == len(calib)
    assert info["est_block_bytes"] > 0


def test_preflight_rejects_bad_inputs(tiny_dense_cfg_mod):
    from repro.quant.preflight import PreflightError, preflight
    cfg, params, calib = tiny_dense_cfg_mod
    with pytest.raises(PreflightError, match="no calibration"):
        preflight(params, cfg, [])
    bad = [dict(calib[0],
                tokens=np.asarray(calib[0]["tokens"]) + cfg.vocab_size)]
    with pytest.raises(PreflightError, match="vocab_size"):
        preflight(params, cfg, bad)
    mixed = [calib[0],
             {k: np.asarray(v)[:, :16] for k, v in calib[0].items()}]
    with pytest.raises(PreflightError, match="sequence lengths"):
        preflight(params, cfg, mixed)
    nan_params = dict(params)
    nan_params["embed"] = jax.tree.map(
        lambda a: (jnp.full_like(a, jnp.nan)
                   if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating)
                   else a), params["embed"])
    with pytest.raises(PreflightError, match="non-finite"):
        preflight(nan_params, cfg, calib)
