"""End-to-end NanoQuant pipeline (paper Alg. 1) integration tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.baselines import rtn_binarize, xnor_binarize
from repro.core.pipeline import QuantConfig, nanoquant_quantize
from repro.data import SyntheticCorpus, calib_batches
from repro.data.synthetic import eval_perplexity
from repro.models import transformer as T

_FAST = dict(admm_iters=8, t_pre=4, t_post=6, t_glob=4, rank_align=32,
             min_dim=32)


@pytest.fixture(scope="module")
def quantized_tiny(tiny_dense_cfg_mod):
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, **_FAST)
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    return cfg, params, calib, qp, report


@pytest.fixture(scope="module")
def tiny_dense_cfg_mod():
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      loss_chunk=0, remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, n_samples=8, seq=48, batch=4)
    return cfg, params, calib


def test_quantized_structure_and_forward(quantized_tiny):
    cfg, params, calib, qp, report = quantized_tiny
    # every attention/ffn linear packed
    lp0 = jax.tree.map(lambda l: l[0], qp["layers"])
    for path in ("attn", "ffn"):
        assert path in lp0
    assert "qu_t" in lp0["attn"]["wq"] and "qv" in lp0["attn"]["wq"]
    assert lp0["attn"]["wq"]["qu_t"].dtype == jnp.uint32
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert len(report["blocks"]) == cfg.n_layers
    assert all(np.isfinite(b["block_err"]) for b in report["blocks"])


def test_quantized_beats_inplace_binarization(quantized_tiny):
    """Paper Table 2 ordering at tiny scale: NanoQuant PPL must be
    dramatically below RTN / XNOR in-place binarization."""
    cfg, params, calib, qp, _ = quantized_tiny
    evalb = calib_batches(cfg, 8, 48, seed=123)
    ppl_q = eval_perplexity(T.loss_fn, qp, cfg, evalb)

    def binarize_all(params, fn):
        def walk(d):
            out = {}
            for k, v in d.items():
                if isinstance(v, dict):
                    if "w" in v and not isinstance(v["w"], dict):
                        out[k] = dict(v, w=fn(v["w"]).astype(v["w"].dtype))
                    else:
                        out[k] = walk(v)
                else:
                    out[k] = v
            return out
        new = dict(params)
        new["layers"] = walk(params["layers"])
        return new

    for fn in (rtn_binarize, xnor_binarize):
        ppl_b = eval_perplexity(T.loss_fn, binarize_all(params, fn), cfg,
                                evalb)
        # random-init teacher: both sit near noise level; require
        # NanoQuant to be at-least-competitive (the trained-teacher
        # orderings live in benchmarks/table2 + EXPERIMENTS.md)
        assert ppl_q < ppl_b * 1.10, (ppl_q, ppl_b)


def test_component_ablation_orderings(tiny_dense_cfg_mod):
    """Paper Table 6 direction: init-only must be far better than
    nothing; the full pipeline must beat init-only."""
    cfg, params, calib = tiny_dense_cfg_mod
    evalb = calib_batches(cfg, 8, 48, seed=321)

    def run(**kw):
        qcfg = QuantConfig(target_bpw=1.0, **_FAST, **kw)
        qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
        return eval_perplexity(T.loss_fn, qp, cfg, evalb)

    full = run()
    init_only = run(skip_tune_fp=True, skip_ste=True, skip_kd=True)
    assert np.isfinite(full) and np.isfinite(init_only)
    assert full <= init_only * 1.10          # refinement helps (or ties)


def test_init_method_ablation_runs(tiny_dense_cfg_mod):
    """Table 5: all three initializers must run through the pipeline."""
    cfg, params, calib = tiny_dense_cfg_mod
    for method in ("lb_admm", "dual_svid", "dbf_admm"):
        qcfg = QuantConfig(target_bpw=1.0, init_method=method, **_FAST)
        qp, _ = nanoquant_quantize(params, cfg, calib,
                                   dataclasses.replace(qcfg, t_pre=2,
                                                       t_post=2, t_glob=2),
                                   verbose=False)
        logits = T.forward(qp, cfg, calib[0]["tokens"])
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), method


def test_sub1bit_target(tiny_dense_cfg_mod):
    """bpw=0.8 quantization runs and packs below 1 bit/weight."""
    from repro.core.packing import packed_nbytes
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=0.8, admm_iters=6, t_pre=2, t_post=2,
                       t_glob=2, rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    lp0 = jax.tree.map(lambda l: l[0], qp["layers"])
    q = lp0["ffn"]["w_gate"]
    nbits = 8 * packed_nbytes(q)
    nweights = cfg.d_model * cfg.d_ff
    # scales are fp16-accounted; tiny dims make the floor dominate —
    # just require strictly below in-place binarization's 1 bit + scales
    assert nbits / nweights < 1.6
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_quantize_hybrid_family():
    """Shared-attention (zamba2-style) block path through the pipeline."""
    cfg = dataclasses.replace(configs.get_smoke("zamba2-1.2b"),
                              n_layers=2, attn_every=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    calib = calib_batches(cfg, 4, 32, batch=2)
    qcfg = QuantConfig(target_bpw=1.0, admm_iters=4, t_pre=2, t_post=2,
                       t_glob=2, rank_align=32, min_dim=16)
    qp, report = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    assert "qu_t" in qp["shared_attn"]["attn"]["wq"]
    mix0 = jax.tree.map(lambda l: l[0], qp["layers"])["mixer"]
    assert "qu_t" in mix0["wx"]
    logits = T.forward(qp, cfg, calib[0]["tokens"])
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_abstract_surgery_matches_pipeline_structure(tiny_dense_cfg_mod):
    """The dry-run's abstract quantized tree must match the real
    pipeline output exactly (structure, shapes, dtypes)."""
    from repro.quant.surgery import abstract_quantized_params
    cfg, params, calib = tiny_dense_cfg_mod
    qcfg = QuantConfig(target_bpw=1.0, admm_iters=4, t_pre=0, t_post=0,
                       t_glob=0, rank_align=32, min_dim=32)
    qp, _ = nanoquant_quantize(params, cfg, calib, qcfg, verbose=False)
    abstract = abstract_quantized_params(cfg, target_bpw=1.0, min_dim=32,
                                         rank_align=32)
    real_td = jax.tree.structure(qp)
    abs_td = jax.tree.structure(abstract)
    assert real_td == abs_td
    for (kp, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(abstract),
            jax.tree_util.tree_leaves_with_path(qp)):
        assert tuple(a.shape) == tuple(b.shape), (kp, a.shape, b.shape)
        assert a.dtype == b.dtype, (kp, a.dtype, b.dtype)
